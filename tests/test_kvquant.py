"""int8 KV-cache quantization: round-trip error bounds + attention accuracy
+ footprint accounting."""
import jax
import jax.numpy as jnp
import numpy as np
from _hyp import given, settings, st  # optional-hypothesis shim

from repro.kernels.ref import decode_attention_ref
from repro.serving import kvquant

RNG = np.random.default_rng(21)


@given(scale=st.floats(0.01, 100.0), seed=st.integers(0, 50))
@settings(max_examples=40, deadline=None)
def test_quant_roundtrip_error_bound(scale, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(4, 8, 16)) * scale, jnp.float32)
    q, s = kvquant.quantize_kv(x)
    back = kvquant.dequantize_kv(q, s, jnp.float32)
    # symmetric int8: error <= scale/2 per element = max|row|/254
    bound = np.asarray(jnp.max(jnp.abs(x), axis=-1))[..., None] / 254 + 1e-6
    assert (np.abs(np.asarray(back - x)) <= bound * 1.01).all()


def test_quant_attention_close_to_fp():
    b, s, h, kv, d = 2, 64, 8, 2, 32
    q = jnp.asarray(RNG.normal(size=(b, h, d)), jnp.bfloat16)
    k = jnp.asarray(RNG.normal(size=(b, s, kv, d)), jnp.bfloat16)
    v = jnp.asarray(RNG.normal(size=(b, s, kv, d)), jnp.bfloat16)
    clen = jnp.full((b,), s, jnp.int32)

    cache = kvquant.init_quant_cache(b, s, kv, d)
    for t in range(s):
        cache = kvquant.write_token(cache, k[:, t], v[:, t],
                                    jnp.full((b,), t, jnp.int32))
    out_q = kvquant.quant_decode_attention(q, cache, clen)
    out_f = decode_attention_ref(q, k, v, clen)
    err = float(jnp.abs(out_q.astype(jnp.float32)
                        - out_f.astype(jnp.float32)).max())
    scale = float(jnp.abs(out_f.astype(jnp.float32)).max()) + 1e-9
    assert err < 0.05 * scale, (err, scale)   # int8 KV keeps logits within 5%


def test_footprint_halves():
    full = kvquant.cache_bytes(128, 32768, 8, 128, quantized=False)
    quant = kvquant.cache_bytes(128, 32768, 8, 128, quantized=True)
    assert quant < 0.52 * full                # ~2x minus scale overhead
