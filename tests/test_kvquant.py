"""int8 KV-cache quantization primitives (DESIGN.md §15): round-trip error
bounds, attention accuracy through the integrated packed path, and footprint
accounting via the engine's eval_shape-derived per-token byte rate."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from _hyp import given, settings, st  # optional-hypothesis shim

from repro.configs import get_config
from repro.kernels import ops
from repro.serving import kvquant
from repro.serving.engine import kv_bytes_per_token

RNG = np.random.default_rng(21)


@given(scale=st.floats(0.01, 100.0), seed=st.integers(0, 50))
@settings(max_examples=40, deadline=None)
def test_quant_roundtrip_error_bound(scale, seed):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=(4, 8, 16)) * scale, jnp.float32)
    q, s = kvquant.quantize_kv(x)
    back = kvquant.dequantize_kv(q, s, jnp.float32)
    # symmetric int8: error <= scale/2 per element = max|row|/254
    bound = np.asarray(jnp.max(jnp.abs(x), axis=-1))[..., None] / 254 + 1e-6
    assert (np.abs(np.asarray(back - x)) <= bound * 1.01).all()


def test_quant_attention_close_to_fp():
    """Quantize a K/V cache with the integrated primitive and attend through
    the packed-attention ref (the serving path): logits stay within 5%."""
    n, s, h, kv, d = 4, 64, 8, 2, 32
    t = n                                          # one decode token per slot
    q = jnp.asarray(RNG.normal(size=(t, h, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(n, s, kv, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(n, s, kv, d)), jnp.float32)
    token_slot = jnp.arange(t, dtype=jnp.int32)
    lengths = jnp.full((t,), s, jnp.int32)

    kq, ks = kvquant.quantize_kv(k)
    vq, vs = kvquant.quantize_kv(v)
    out_q = ops.packed_attention(q, kq, vq, token_slot, lengths,
                                 k_scale=ks, v_scale=vs, impl="ref")
    out_f = ops.packed_attention(q, k, v, token_slot, lengths, impl="ref")
    err = float(jnp.abs(out_q.astype(jnp.float32)
                        - out_f.astype(jnp.float32)).max())
    scale = float(jnp.abs(out_f.astype(jnp.float32)).max()) + 1e-9
    assert err < 0.05 * scale, (err, scale)   # int8 KV keeps logits within 5%


def test_footprint_nearly_halves():
    """eval_shape-derived per-token rate: int8 storage (values + f32 scales)
    costs ~half the native bf16 layout, i.e. ~2x requests fit at a fixed
    kv_budget_bytes (DESIGN.md §15).  Scale overhead is 4/head_dim per
    element, so head_dim=128 (production shape) lands under 0.52x while
    tiny-toy's head_dim=64 sits at 0.532x."""
    cfg = get_config("tiny-toy")                   # bf16-native config
    assert cfg.dtype == "bfloat16"
    full = kv_bytes_per_token(cfg)
    quant = kv_bytes_per_token(cfg, "int8")
    assert quant < 0.54 * full, (quant, full)      # ~2x minus scale overhead

    wide = dataclasses.replace(cfg, head_dim=128)
    full, quant = kv_bytes_per_token(wide), kv_bytes_per_token(wide, "int8")
    assert quant < 0.52 * full, (quant, full)
    assert full / quant >= 1.9                     # >=1.9x admitted tokens


def test_footprint_mla_family():
    """Absorbed MLA: only the latent + rope leaves store per token, and only
    those quantize; the int8 rate still lands near half of native."""
    from repro.configs import scale_down
    cfg = scale_down(get_config("deepseek-v2-236b"))
    assert cfg.mla is not None
    full = kv_bytes_per_token(cfg)
    quant = kv_bytes_per_token(cfg, "int8")
    assert quant < 0.62 * full, (quant, full)
