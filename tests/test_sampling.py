"""Direct unit tests for the packed-step sampling helpers
(serving/sampling.py): the §10 device-resident feedback pair
``substitute_last`` / ``scatter_last`` (including the §13 token-ring
generalization) and the temperature/top-k samplers behind
``EngineConfig.temperature`` / ``top_k``.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.serving import sampling


def _arr(x, dt=jnp.int32):
    return jnp.asarray(np.asarray(x), dt)


# ---------------------------------------------------------------------------
# substitute_last
# ---------------------------------------------------------------------------
def test_substitute_last_1d_buffer():
    tokens = _arr([[10, 0, 30, 0]])
    last = _arr([7, 8])
    slot = _arr([0, 1, 0, 0])
    mask = _arr([False, True, False, True], jnp.bool_)
    out = sampling.substitute_last(tokens, last, slot, mask)
    assert out.shape == tokens.shape
    np.testing.assert_array_equal(np.asarray(out[0]), [10, 8, 30, 7])


def test_substitute_last_all_from_last():
    """A decode-only iteration: every position is a placeholder."""
    tokens = jnp.zeros((1, 3), jnp.int32)
    last = _arr([4, 5, 6])
    slot = _arr([2, 0, 1])
    mask = jnp.ones((3,), bool)
    out = sampling.substitute_last(tokens, last, slot, mask)
    np.testing.assert_array_equal(np.asarray(out[0]), [6, 4, 5])


def test_substitute_last_ring_selects_newest_accepted():
    """(n_slots, W) ring: the fed token is ring[slot, accept_len-1]."""
    tokens = jnp.zeros((1, 2), jnp.int32)
    ring = _arr([[11, 12, 13], [21, 22, 23]])
    slot = _arr([0, 1])
    mask = jnp.ones((2,), bool)
    acc = _arr([2, 3])
    out = sampling.substitute_last(tokens, ring, slot, mask, accept_len=acc)
    np.testing.assert_array_equal(np.asarray(out[0]), [12, 23])
    # accept_len is clipped into the ring (0 -> column 0, >W -> last)
    acc2 = _arr([0, 9])
    out2 = sampling.substitute_last(tokens, ring, slot, mask,
                                    accept_len=acc2)
    np.testing.assert_array_equal(np.asarray(out2[0]), [11, 23])
    # no accept_len -> column 0 (the §10 single-token behaviour)
    out3 = sampling.substitute_last(tokens, ring, slot, mask)
    np.testing.assert_array_equal(np.asarray(out3[0]), [11, 21])


def test_substitute_last_multicodebook_broadcast():
    tokens = jnp.zeros((1, 2, 3), jnp.int32)     # (1, T, K)
    last = _arr([9, 4])
    slot = _arr([1, 0])
    mask = _arr([True, False], jnp.bool_)
    out = sampling.substitute_last(tokens, last, slot, mask)
    np.testing.assert_array_equal(np.asarray(out[0, 0]), [4, 4, 4])
    np.testing.assert_array_equal(np.asarray(out[0, 1]), [0, 0, 0])


# ---------------------------------------------------------------------------
# scatter_last
# ---------------------------------------------------------------------------
def test_scatter_last_1d():
    last = _arr([1, 2, 3])
    sample_slot = _arr([3, 1, 3])          # n_slots == 3 -> OOB -> dropped
    sampled = _arr([10, 20, 30])
    out = sampling.scatter_last(last, sample_slot, sampled)
    np.testing.assert_array_equal(np.asarray(out), [1, 20, 3])


def test_scatter_last_empty_sample_slot_is_noop():
    """All-OOB sample points (e.g. a mid-prompt prefill-only iteration):
    the buffer must come back unchanged, 1-D and ring alike."""
    sample_slot = _arr([2, 2])
    sampled = _arr([10, 20])
    last1 = _arr([5, 6])
    np.testing.assert_array_equal(
        np.asarray(sampling.scatter_last(last1, sample_slot, sampled)),
        [5, 6])
    ring = _arr([[1, 2], [3, 4]])
    np.testing.assert_array_equal(
        np.asarray(sampling.scatter_last(ring, sample_slot, sampled)),
        [[1, 2], [3, 4]])


def test_scatter_last_ring_writes_column_zero_only():
    ring = _arr([[1, 2, 3], [4, 5, 6]])
    out = sampling.scatter_last(ring, _arr([1, 2]), _arr([40, 99]))
    np.testing.assert_array_equal(np.asarray(out), [[1, 2, 3], [40, 5, 6]])


def test_scatter_last_multicodebook_keeps_codebook0():
    ring = jnp.zeros((2, 2), jnp.int32)
    sampled = _arr([[7, 8], [9, 10]])       # (T, K)
    out = sampling.scatter_last(ring, _arr([0, 1]), sampled)
    np.testing.assert_array_equal(np.asarray(out), [[7, 0], [9, 0]])


# ---------------------------------------------------------------------------
# packed_keys / sample_tokens
# ---------------------------------------------------------------------------
def test_packed_keys_unique_per_slot_pos():
    key = jax.random.PRNGKey(0)
    slot = _arr([0, 0, 1, 1])
    pos = _arr([0, 1, 0, 1])
    keys = np.asarray(sampling.packed_keys(key, slot, pos, stride=100))
    assert len({tuple(k) for k in keys}) == 4


def test_sample_tokens_greedy_at_zero_temperature():
    logits = jnp.asarray(np.random.default_rng(0).normal(size=(5, 17)),
                         jnp.float32)
    out = sampling.sample_tokens(logits, None, temp=0.0)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(sampling.greedy(logits)))


def test_sample_tokens_topk1_is_greedy():
    logits = jnp.asarray(np.random.default_rng(1).normal(size=(6, 9)),
                         jnp.float32)
    keys = sampling.packed_keys(jax.random.PRNGKey(3), _arr(range(6)),
                                _arr([0] * 6), stride=8)
    out = sampling.sample_tokens(logits, keys, temp=1.0, topk=1)
    np.testing.assert_array_equal(np.asarray(out),
                                  np.asarray(sampling.greedy(logits)))


def test_sample_tokens_deterministic_and_in_range():
    logits = jnp.asarray(np.random.default_rng(2).normal(size=(8, 13)),
                         jnp.float32)
    keys = sampling.packed_keys(jax.random.PRNGKey(5), _arr(range(8)),
                                _arr([3] * 8), stride=10)
    a = np.asarray(sampling.sample_tokens(logits, keys, temp=0.7, topk=4))
    b = np.asarray(sampling.sample_tokens(logits, keys, temp=0.7, topk=4))
    np.testing.assert_array_equal(a, b)
    assert a.dtype == np.int32 and (a >= 0).all() and (a < 13).all()
    # top-k actually constrains support: every pick is within the top 4
    top4 = np.argsort(-np.asarray(logits), axis=-1)[:, :4]
    assert all(a[i] in top4[i] for i in range(8))
