"""§Perf HC1 regression coverage: the chunkwise-parallel mLSTM must stay
bit-compatible with the sequential reference for all chunk/shape/state
combinations (including ragged tails and carried-in state)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # optional-hypothesis shim

from repro.kernels.ref import mlstm_chunk_ref
from repro.models.xlstm import mlstm_chunkwise

RNG = np.random.default_rng(11)


@pytest.mark.parametrize("b,s,h,dqk,dv,chunk,init", [
    (2, 32, 2, 16, 16, 8, False),
    (1, 100, 4, 32, 8, 16, True),
    (2, 64, 1, 8, 24, 64, True),
    (1, 17, 2, 16, 16, 4, False),
    (1, 7, 1, 8, 8, 64, True),       # chunk > seq
])
def test_chunkwise_matches_sequential(b, s, h, dqk, dv, chunk, init):
    q = jnp.asarray(RNG.normal(size=(b, s, h, dqk)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, s, h, dqk)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, s, h, dv)), jnp.float32)
    ig = jnp.asarray(RNG.normal(size=(b, s, h)) * 2, jnp.float32)
    fg = jnp.asarray(-np.abs(RNG.normal(size=(b, s, h))), jnp.float32)
    st0 = None
    if init:
        st0 = (jnp.asarray(RNG.normal(size=(b, h, dqk, dv)), jnp.float32),
               jnp.asarray(np.abs(RNG.normal(size=(b, h, dqk))), jnp.float32),
               jnp.asarray(RNG.normal(size=(b, h)), jnp.float32))
    y1, (c1, n1, m1) = mlstm_chunkwise(q, k, v, ig, fg, chunk=chunk,
                                       initial=st0)
    y2, (c2, n2, m2) = mlstm_chunk_ref(q, k, v, ig, fg, initial=st0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4,
                               atol=2e-4)
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c2), rtol=2e-4,
                               atol=2e-3)
    np.testing.assert_allclose(np.asarray(m1), np.asarray(m2), rtol=1e-5,
                               atol=1e-5)


@given(s=st.integers(2, 48), chunk=st.sampled_from([4, 8, 16]),
       seed=st.integers(0, 100))
@settings(max_examples=25, deadline=None)
def test_chunkwise_property(s, chunk, seed):
    rng = np.random.default_rng(seed)
    b, h, d = 1, 2, 8
    q = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, s, h, d)), jnp.float32)
    ig = jnp.asarray(rng.normal(size=(b, s, h)), jnp.float32)
    fg = jnp.asarray(-np.abs(rng.normal(size=(b, s, h))), jnp.float32)
    y1, _ = mlstm_chunkwise(q, k, v, ig, fg, chunk=chunk)
    y2, _ = mlstm_chunk_ref(q, k, v, ig, fg)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=5e-4,
                               atol=5e-4)


def test_chunkwise_is_differentiable():
    b, s, h, d = 1, 24, 2, 8
    q = jnp.asarray(RNG.normal(size=(b, s, h, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, s, h, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, s, h, d)), jnp.float32)
    ig = jnp.asarray(RNG.normal(size=(b, s, h)), jnp.float32)
    fg = jnp.asarray(-np.abs(RNG.normal(size=(b, s, h))), jnp.float32)
    g = jax.grad(lambda q_: jnp.sum(
        mlstm_chunkwise(q_, k, v, ig, fg, chunk=8)[0] ** 2))(q)
    assert np.isfinite(np.asarray(g)).all() and float(jnp.abs(g).max()) > 0
