"""Per-architecture smoke tests (deliverable f): reduced same-family configs,
one forward/train step on CPU, asserting output shapes + no NaNs, plus
prefill->decode consistency."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, scale_down
from repro.models import model

ARCHS = [
    "jamba-1.5-large-398b", "xlstm-1.3b", "qwen3-4b", "minitron-4b",
    "qwen3-8b", "starcoder2-7b", "llava-next-34b", "musicgen-medium",
    "arctic-480b", "deepseek-v2-236b",
]


def _batch(cfg, key, b=2, s=12):
    if cfg.frontend == "audio":
        toks = jax.random.randint(key, (b, s, cfg.num_codebooks), 0,
                                  cfg.vocab_size)
    else:
        toks = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": toks}
    if cfg.frontend == "vision":
        batch["patches"] = jax.random.normal(key, (b, 4, cfg.d_model),
                                             jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch):
    cfg = scale_down(get_config(arch))
    params = model.init(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(1))

    logits, aux = model.forward_full(cfg, params, batch["tokens"],
                                     patches=batch.get("patches"))
    b, s = batch["tokens"].shape[:2]
    s_total = s + (batch["patches"].shape[1] if "patches" in batch else 0)
    if cfg.frontend == "audio":
        assert logits.shape == (b, s_total, cfg.num_codebooks, cfg.vocab_size)
    else:
        assert logits.shape == (b, s_total, cfg.vocab_size)
    assert not bool(jnp.isnan(logits.astype(jnp.float32)).any())

    loss, metrics = model.loss_fn(cfg, params, batch)
    assert np.isfinite(float(loss))
    grads = jax.grad(lambda p: model.loss_fn(cfg, p, batch)[0])(params)
    gsq = jax.tree.reduce(
        jnp.add, jax.tree.map(
            lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))), grads))
    assert np.isfinite(float(gsq)) and float(gsq) > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_consistency(arch):
    """decode(prefill(prompt[:-1]), prompt[-1]) == full_forward(prompt)[-1].

    MoE archs use a large capacity factor so no tokens drop (capacity drops
    legitimately differ between the paths — verified exact when dropless)."""
    cfg = scale_down(get_config(arch))
    if cfg.moe is not None:
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, capacity_factor=16.0))
    params = model.init(cfg, jax.random.PRNGKey(0))
    b, s = 2, 10
    key = jax.random.PRNGKey(2)
    if cfg.frontend == "audio":
        toks = jax.random.randint(key, (b, s, cfg.num_codebooks), 0,
                                  cfg.vocab_size)
    else:
        toks = jax.random.randint(key, (b, s), 0, cfg.vocab_size)
    logits, _ = model.forward_full(cfg, params, toks)
    _, cache, clen = model.prefill(cfg, params, toks[:, : s - 1], max_len=s)
    dec, _ = model.forward_decode(cfg, params, toks[:, s - 1: s], cache, clen)
    err = float(jnp.abs(dec.astype(jnp.float32)
                        - logits[:, -1].astype(jnp.float32)).max())
    scale = float(jnp.abs(logits[:, -1].astype(jnp.float32)).max()) + 1e-6
    # bf16 recurrent paths accumulate a few ulps across layers
    assert err <= max(0.08 * scale, 1e-4), (err, scale)


@pytest.mark.parametrize("arch", ["qwen3-4b", "deepseek-v2-236b"])
def test_remat_matches_no_remat(arch):
    cfg = scale_down(get_config(arch))
    params = model.init(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg, jax.random.PRNGKey(3))
    l0, _ = model.loss_fn(cfg, params, batch, remat="none")
    l1, _ = model.loss_fn(cfg, params, batch, remat="full")
    assert abs(float(l0) - float(l1)) < 1e-3
