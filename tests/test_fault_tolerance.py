"""Fault-tolerant multi-replica serving (DESIGN.md §14).

Covers the tentpole guarantees:
  * chaos exactness — a seeded ``FaultPlan`` killing one of two replicas
    mid-stream loses no request, and every request's generated stream is
    f32 token-exact vs the same workload on an unperturbed pool (committed
    tokens replayed as forced prefix), across GQA/MLA and async depth 0/1,
    under greedy and (rid,pos)-keyed stochastic sampling;
  * graceful degradation — under over-saturation with admission control on,
    shed requests carry explicit ``REJECTED`` + reason, nothing deadlocks
    (bounded ticks), and every submitted request lands in exactly one of
    results/shed;
  * timeout/retry — a stalled replica's queued requests time out, back off,
    and retry elsewhere; with nowhere to go they are shed at
    ``retry_limit``, never parked forever;
  * elastic join/leave — zero dropped requests across a mid-stream rescale;
  * ``ElasticManager`` decision coverage (data/model axes, ``min_data``
    halt floor, capacity adds) and the pool snapshot counter schema.
"""
import dataclasses

import jax
import pytest

from repro.configs import get_config, scale_down
from repro.distributed.elastic import ClusterState, ElasticManager
from repro.models import model
from repro.serving.config import EngineConfig, PoolConfig
from repro.serving.engine import ServeEngine
from repro.serving.faults import FaultEvent, FaultPlan
from repro.serving.pool import ReplicaPool
from repro.serving.request import Request, State

SIZES = (16, 8)
ENGINE_FAMILIES = ["tiny-toy", "deepseek-v2-236b"]   # GQA and (absorbed) MLA


@pytest.fixture(scope="module", params=ENGINE_FAMILIES)
def family(request):
    cfg = get_config(request.param) if request.param == "tiny-toy" \
        else scale_down(get_config(request.param))
    if cfg.moe is not None:
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, capacity_factor=16.0))
    cfg = dataclasses.replace(cfg, dtype="float32")
    params = model.init(cfg, jax.random.PRNGKey(0))
    return cfg, params


@pytest.fixture(scope="module")
def toy():
    cfg = dataclasses.replace(get_config("tiny-toy"), dtype="float32")
    return cfg, model.init(cfg, jax.random.PRNGKey(0))


def _ecfg(depth, **kw):
    return EngineConfig(max_slots=4, max_len=64, kv_block_size=8,
                        discrete_sizes=SIZES, async_depth=depth,
                        avg_decode_len=4.0, **kw)


def _arrivals(n, stagger=2):
    return [(i // stagger, Request(rid=i,
                                   prompt=list(range(5 + i, 15 + i)),
                                   max_new_tokens=8))
            for i in range(n)]


def _run_pool(cfg, params, ecfg, plan, n=8, pcfg=None, max_ticks=500):
    def mk():
        return ServeEngine(cfg, params, ecfg)
    pool = ReplicaPool([mk(), mk()], pcfg or PoolConfig(replicas=2),
                       fault_plan=plan, virtual_dt=0.01, engine_factory=mk)
    results = pool.run_ticked(_arrivals(n), max_ticks=max_ticks)
    return pool, {rid: tuple(r.generated) for rid, r in results.items()}


# ---------------------------------------------------------------------------
# chaos exactness (tentpole acceptance)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("depth", [0, 1])
def test_chaos_kill_exactness(family, depth):
    """Kill replica 1-of-2 mid-stream: every request completes and the
    generated streams match the unperturbed pool token-for-token — the
    committed prefix replayed on the survivor resumes the exact
    trajectory (greedy sampling depends only on the prefix, and
    per-request f32 outputs are batching-invariant)."""
    cfg, params = family
    ecfg = _ecfg(depth)
    _, base = _run_pool(cfg, params, ecfg, None)
    pool, chaos = _run_pool(cfg, params, ecfg, FaultPlan.parse("kill@3:r1"))
    assert pool.stats.faults_injected == 1
    assert not pool.shed and set(chaos) == set(range(8)), \
        [(r.rid, r.reject_reason) for r in pool.shed]
    assert chaos == base, (cfg.name, depth)
    # the kill must actually have interrupted work: something on replica 1
    # was evacuated and re-entered the dispatch path
    assert pool.stats.redispatched_requests > 0
    assert pool.router.redispatched == pool.stats.redispatched_requests
    assert not pool.router.replicas[1].alive


def test_chaos_kill_exactness_stochastic(toy):
    """Same guarantee under temperature sampling: the packed sampler's keys
    fold (rid, pos) only and both replicas share the engine seed, so the
    replayed positions redraw the identical randomness."""
    cfg, params = toy
    ecfg = _ecfg(1, temperature=0.8, seed=7)
    _, base = _run_pool(cfg, params, ecfg, None)
    pool, chaos = _run_pool(cfg, params, ecfg, FaultPlan.parse("kill@3:r1"))
    assert not pool.shed and chaos == base
    assert pool.stats.redispatched_requests > 0


def test_evacuated_eos_request_not_regenerated(toy):
    """A request whose committed output already holds EOS at kill time is
    finalized by the checkpoint, not re-dispatched — re-running it would
    generate past EOS and break exactness."""
    r = Request(rid=0, prompt=[1, 2, 3], max_new_tokens=6, eos_id=9)
    r.output = [4, 9, 5]          # EOS committed, one §5.3 overshoot token
    r.state = State.DECODE
    folded = r.checkpoint_redispatch()
    assert folded == 0 and r.state == State.FINISHED
    assert r.generated == [4, 9]  # stripped to EOS, overshoot dropped
    assert r.prompt == [1, 2, 3, 4, 9]


# ---------------------------------------------------------------------------
# graceful degradation (SLO admission, bounded, never hangs)
# ---------------------------------------------------------------------------
def test_slo_admission_sheds_explicitly(toy):
    """2x-saturation burst with a backlog cap: the overflow is rejected
    with an explicit reason at submit time, admitted requests all finish,
    and the run is bounded — submitted == completed + shed, no deadlock."""
    cfg, params = toy
    pcfg = PoolConfig(replicas=2, shed_backlog_tokens=30,
                      slo_ttft_ms=500.0)

    def mk():
        return ServeEngine(cfg, params, _ecfg(1))
    pool = ReplicaPool([mk(), mk()], pcfg, virtual_dt=0.01)
    # one burst far above what a 30-token backlog cap admits
    arrivals = [(0, Request(rid=i, prompt=list(range(3 + i, 19 + i)),
                            max_new_tokens=6)) for i in range(12)]
    results = pool.run_ticked(arrivals, max_ticks=400)
    assert pool.stats.ticks < 400, "deadlocked until the deadline"
    assert pool.stats.shed_requests > 0
    assert len(results) + len(pool.shed) == pool.stats.submitted == 12
    for r in pool.shed:
        assert r.state == State.REJECTED and r.reject_reason == "backlog"
    # admitted requests all completed
    assert all(len(r.output) > 0 for r in results.values())


def test_slo_ttft_admission_keeps_p99_within_slo(toy):
    """With a TTFT SLO and the service-rate estimator warmed up, the pool
    under-admits (slo_safety) so completed requests' p99 TTFT respects the
    SLO in virtual time; the overflow is shed with reason ttft_slo."""
    cfg, params = toy
    slo_ms = 80.0
    pcfg = PoolConfig(replicas=2, slo_ttft_ms=slo_ms, slo_safety=0.5)

    def mk():
        return ServeEngine(cfg, params, _ecfg(1))
    pool = ReplicaPool([mk(), mk()], pcfg, virtual_dt=0.01)
    # warm-up: a light wave measures the virtual service rate
    warm = [(0, Request(rid=100 + i, prompt=list(range(4, 12)),
                        max_new_tokens=4)) for i in range(2)]
    pool.run_ticked(warm, max_ticks=100)
    assert pool._rate is not None and pool._rate > 0
    # flood: far more work than slo_ttft_ms of backlog
    flood = [(pool.tick_count, Request(
        rid=i, prompt=list(range(3 + i, 19 + i)), max_new_tokens=6))
        for i in range(16)]
    pool.run_ticked(flood, max_ticks=pool.tick_count + 400)
    shed_flood = [r for r in pool.shed if r.rid < 100]
    assert shed_flood, "2x saturation never tripped admission"
    assert all(r.reject_reason == "ttft_slo" for r in shed_flood)
    done = [r for rid, r in pool.results.items()
            if rid < 100 and r.first_token_at is not None]
    assert done
    ttft = sorted((r.first_token_at - r.arrival) * 1e3 for r in done)
    assert ttft[-1] <= slo_ms, f"admitted p99 TTFT {ttft[-1]:.1f}ms > SLO"
    assert pool.stats.slo_violations == 0


# ---------------------------------------------------------------------------
# timeout / retry-with-backoff
# ---------------------------------------------------------------------------
def test_stall_timeout_retries_on_other_replica(toy):
    """Replica 0 stalls before its first step: its queued requests time
    out, back off, and complete on replica 1 — retries recorded, nothing
    lost."""
    cfg, params = toy
    pcfg = PoolConfig(replicas=2, request_timeout_s=0.05,
                      retry_limit=3, backoff_base_s=0.01)

    def mk():
        return ServeEngine(cfg, params, _ecfg(0))
    pool = ReplicaPool([mk(), mk()], pcfg,
                       fault_plan=FaultPlan.parse("stall@0:r0:10000"),
                       virtual_dt=0.01)
    results = pool.run_ticked(_arrivals(6, stagger=6), max_ticks=300)
    assert len(results) == 6 and not pool.shed
    assert pool.stats.timeouts > 0 and pool.stats.retries > 0
    moved = [r for r in results.values() if r.retries > 0]
    assert moved and all(r.replica == 1 for r in moved)


def test_retry_limit_sheds_never_hangs(toy):
    """Single replica stalled forever: the request cycles timeout -> backoff
    -> re-dispatch until retry_limit, then is shed with an explicit reason
    — bounded, not parked forever."""
    cfg, params = toy
    pcfg = PoolConfig(replicas=1, request_timeout_s=0.03,
                      retry_limit=2, backoff_base_s=0.01)
    pool = ReplicaPool([ServeEngine(cfg, params, _ecfg(0))], pcfg,
                       fault_plan=FaultPlan.parse("stall@0:r0:100000"),
                       virtual_dt=0.01)
    pool.run_ticked([(0, Request(rid=0, prompt=[1, 2, 3, 4],
                                 max_new_tokens=4))], max_ticks=300)
    assert pool.stats.ticks < 300
    assert len(pool.shed) == 1
    assert pool.shed[0].reject_reason == "retry_limit"
    assert pool.shed[0].retries == 3   # initial + retry_limit attempts


# ---------------------------------------------------------------------------
# elastic join / leave (zero dropped requests across a rescale)
# ---------------------------------------------------------------------------
def test_pool_join_leave_zero_drop(toy):
    """Scale up at tick 2, gracefully retire replica 0 at tick 4: every
    request completes token-exact vs an unperturbed pool (the drained
    pipeline commits, the remainder replays its committed prefix)."""
    cfg, params = toy
    ecfg = _ecfg(1)
    _, base = _run_pool(cfg, params, ecfg, None)
    pool, out = _run_pool(cfg, params, ecfg,
                          FaultPlan.parse("join@2,leave@4:r0"))
    assert pool.stats.joins == 1 and pool.stats.leaves == 1
    assert not pool.shed and set(out) == set(range(8))
    assert out == base
    assert len(pool.router.replicas) == 3
    assert not pool.router.replicas[0].alive
    assert pool.elastic.state.data == 2    # 2 + 1 join - 1 leave
    # a graceful leave is planned, not a failure
    assert pool.elastic.state.failed_hosts == 0


def test_leave_refuses_last_replica(toy):
    cfg, params = toy
    pool = ReplicaPool([ServeEngine(cfg, params, _ecfg(0))], PoolConfig())
    assert pool.leave_replica(0) == []
    assert pool.router.replicas[0].alive


def test_all_replicas_dead_sheds_instead_of_hanging(toy):
    cfg, params = toy
    pool = ReplicaPool([ServeEngine(cfg, params, _ecfg(0))], PoolConfig(),
                       virtual_dt=0.01)
    pool.fail_replica(0)
    assert pool.halted    # min_data floor: 1 -> 0 is a halt
    ok = pool.submit(Request(rid=0, prompt=[1, 2], max_new_tokens=2))
    assert not ok and pool.shed[0].reject_reason == "pool_halted"
    assert pool.shed[0].state == State.REJECTED


# ---------------------------------------------------------------------------
# ElasticManager decision coverage (satellite)
# ---------------------------------------------------------------------------
def test_elastic_data_axis_rescale():
    mgr = ElasticManager(ClusterState(data=4, model=2))
    d = mgr.on_failure("data", 1)
    assert d.action == "rescale" and d.new_state.data == 3
    assert mgr.state.data == 3 and mgr.state.failed_hosts == 1


def test_elastic_min_data_halt_floor():
    mgr = ElasticManager(ClusterState(data=2, model=1), min_data=2)
    d = mgr.on_failure("data", 1)
    assert d.action == "halt"
    assert mgr.state.data == 2          # halt does not mutate the state


def test_elastic_model_axis_drops_pod_or_halts():
    mgr = ElasticManager(ClusterState(data=2, model=4, pods=3))
    d = mgr.on_failure("model", 1)
    assert d.action == "rescale" and d.new_state.pods == 2
    solo = ElasticManager(ClusterState(data=2, model=4, pods=1))
    d = solo.on_failure("model", 1)
    assert d.action == "halt" and "TP shard" in d.reason


def test_elastic_on_leave_planned_not_failed():
    mgr = ElasticManager(ClusterState(data=3, model=2), min_data=2)
    d = mgr.on_leave(1)
    assert d.action == "rescale" and mgr.state.data == 2
    assert mgr.state.failed_hosts == 0      # voluntary, not a failure
    assert mgr.on_leave(1).action == "halt"  # min_data floor applies too
    assert mgr.state.data == 2


def test_elastic_on_capacity_scales_up():
    mgr = ElasticManager(ClusterState(data=2, model=2))
    d = mgr.on_capacity(2)
    assert d.action == "rescale" and mgr.state.data == 4


# ---------------------------------------------------------------------------
# FaultPlan determinism + pool snapshot schema (satellites)
# ---------------------------------------------------------------------------
def test_fault_plan_parse_roundtrip():
    plan = FaultPlan.parse("kill@40:r1, stall@10:r0:20, degrade@5:r1:3,"
                           "join@60, leave@80:r0")
    assert len(plan) == 5
    assert plan.events[0] == FaultEvent(tick=5, kind="degrade",
                                        replica=1, arg=3)
    assert FaultPlan.parse(plan.describe()).events == plan.events
    # each event fires exactly once, at-or-after its tick
    assert [e.kind for e in plan.due(10)] == ["degrade", "stall"]
    assert plan.due(10) == []
    with pytest.raises(ValueError):
        FaultPlan.parse("explode@3:r0")


def test_fault_plan_seeded_deterministic():
    a = FaultPlan.seeded(seed=3, n_events=6, horizon=50, n_replicas=2)
    b = FaultPlan.seeded(seed=3, n_events=6, horizon=50, n_replicas=2)
    assert a.events == b.events and len(a) == 6
    assert a.events != FaultPlan.seeded(4, 6, 50, 2).events


def test_pool_snapshot_counter_schema(toy):
    cfg, params = toy
    pool, _ = _run_pool(cfg, params, _ecfg(0),
                        FaultPlan.parse("kill@3:r1"), n=4)
    snap = pool.snapshot()
    for k in ("submitted", "completed", "shed_requests", "retries",
              "redispatched_requests", "redispatched_tokens",
              "slo_violations", "timeouts", "faults_injected", "replicas",
              "service_rate_tok_s"):
        assert k in snap, k
    assert len(snap["replicas"]) == 2
    for rep in snap["replicas"]:
        for k in ("queue_depth", "queued_tokens", "inflight_tokens",
                  "kv_used_frac", "alive"):
            assert k in rep, k
    # engine-side evacuation counters surface in the engine snapshot too
    esnap = pool.router.replicas[1].engine.stats.snapshot()
    assert esnap["evacuated_requests"] == pool.stats.redispatched_requests
    assert esnap["evacuated_tokens"] == pool.stats.redispatched_tokens
