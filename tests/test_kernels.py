"""Per-kernel allclose sweeps: Pallas (interpret=True) vs the pure-jnp
oracles in kernels/ref.py, across shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import decode_attention, paged_decode_attention
from repro.kernels.flash_attention import flash_attention
from repro.kernels.fused_overlap import fused_overlap
from repro.kernels.ssm_scan import ssm_scan

RNG = np.random.default_rng(42)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=1e-5, atol=2e-5)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,sq,skv,h,kv,d,causal,qoff", [
    (2, 64, 64, 4, 2, 32, True, 0),
    (1, 37, 53, 6, 2, 16, True, 16),      # ragged + chunked-prefill offset
    (2, 128, 128, 8, 8, 64, True, 0),     # MHA
    (1, 16, 16, 4, 1, 8, False, 0),       # MQA, non-causal
    (1, 96, 96, 16, 2, 128, True, 0),     # MXU-width head_dim
])
def test_flash_attention(b, sq, skv, h, kv, d, causal, qoff, dtype):
    q = jnp.asarray(RNG.normal(size=(b, sq, h, d)), dtype)
    k = jnp.asarray(RNG.normal(size=(b, skv, kv, d)), dtype)
    v = jnp.asarray(RNG.normal(size=(b, skv, kv, d)), dtype)
    out = flash_attention(q, k, v, causal=causal, q_offset=qoff,
                          block_q=32, block_k=32, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal, q_offset=qoff)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("b,s,h,kv,d", [
    (2, 64, 4, 2, 32), (3, 100, 8, 8, 16), (1, 256, 16, 2, 64),
    (4, 48, 8, 1, 128),
])
def test_decode_attention(b, s, h, kv, d, dtype):
    q = jnp.asarray(RNG.normal(size=(b, h, d)), dtype)
    k = jnp.asarray(RNG.normal(size=(b, s, kv, d)), dtype)
    v = jnp.asarray(RNG.normal(size=(b, s, kv, d)), dtype)
    clen = jnp.asarray(RNG.integers(1, s + 1, size=(b,)), jnp.int32)
    out = decode_attention(q, k, v, clen, block_k=32, interpret=True)
    want = ref.decode_attention_ref(q, k, v, clen)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("b,npages,ps,maxp,h,kv,d", [
    (2, 16, 8, 6, 4, 2, 32), (3, 32, 16, 4, 8, 4, 16), (1, 8, 4, 8, 2, 1, 64),
])
def test_paged_decode_attention(b, npages, ps, maxp, h, kv, d):
    q = jnp.asarray(RNG.normal(size=(b, h, d)), jnp.float32)
    kp = jnp.asarray(RNG.normal(size=(npages, ps, kv, d)), jnp.float32)
    vp = jnp.asarray(RNG.normal(size=(npages, ps, kv, d)), jnp.float32)
    pt = np.full((b, maxp), -1, np.int32)
    clen = []
    for i in range(b):
        n = int(RNG.integers(1, maxp + 1))
        pt[i, :n] = RNG.choice(npages, size=n, replace=False)
        clen.append(int(RNG.integers((n - 1) * ps + 1, n * ps + 1)))
    pt, clen = jnp.asarray(pt), jnp.asarray(clen, jnp.int32)
    out = paged_decode_attention(q, kp, vp, pt, clen, interpret=True)
    want = ref.paged_decode_attention_ref(q, kp, vp, pt, clen)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=2e-5)


@pytest.mark.parametrize("frac", [0.25, 0.5, 1.0])
@pytest.mark.parametrize("m,k,n,b,s,h,kv,d", [
    (128, 64, 96, 2, 64, 4, 2, 32),
    (64, 32, 512, 1, 256, 4, 1, 64),
])
def test_fused_overlap(m, k, n, b, s, h, kv, d, frac):
    x = jnp.asarray(RNG.normal(size=(m, k)), jnp.float32)
    w = jnp.asarray(RNG.normal(size=(k, n)), jnp.float32)
    q = jnp.asarray(RNG.normal(size=(b, h, d)), jnp.float32)
    kc = jnp.asarray(RNG.normal(size=(b, s, kv, d)), jnp.float32)
    vc = jnp.asarray(RNG.normal(size=(b, s, kv, d)), jnp.float32)
    clen = jnp.asarray(RNG.integers(1, s + 1, size=(b,)), jnp.int32)
    go, ao = fused_overlap(x, w, q, kc, vc, clen, gemm_fraction=frac,
                           block_n=64, block_s=32, interpret=True)
    rg, ra = ref.fused_overlap_ref(x, w, q, kc, vc, clen)
    np.testing.assert_allclose(np.asarray(go), np.asarray(rg), rtol=1e-4,
                               atol=1e-3)
    np.testing.assert_allclose(np.asarray(ao), np.asarray(ra), rtol=1e-5,
                               atol=2e-5)


@pytest.mark.parametrize("bsz,s,c,n,chunk,bc,h0", [
    (2, 32, 16, 4, 8, 8, False),
    (1, 100, 64, 16, 16, 32, True),
    (3, 64, 48, 8, 64, 48, True),
])
def test_ssm_scan(bsz, s, c, n, chunk, bc, h0):
    x = jnp.asarray(RNG.normal(size=(bsz, s, c)), jnp.float32)
    dt = jnp.asarray(np.abs(RNG.normal(size=(bsz, s, c))) * 0.1, jnp.float32)
    a = -jnp.asarray(np.abs(RNG.normal(size=(c, n))) + 0.1, jnp.float32)
    b = jnp.asarray(RNG.normal(size=(bsz, s, n)), jnp.float32)
    cm = jnp.asarray(RNG.normal(size=(bsz, s, n)), jnp.float32)
    d = jnp.asarray(RNG.normal(size=(c,)), jnp.float32)
    h0a = jnp.asarray(RNG.normal(size=(bsz, c, n)), jnp.float32) if h0 else None
    y, hf = ssm_scan(x, dt, a, b, cm, d, h0a, chunk=chunk, block_c=bc,
                     interpret=True)
    yr, hr = ref.ssm_scan_ref(x, dt, a, b, cm, d, h0a)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr), rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(hf), np.asarray(hr), rtol=1e-4,
                               atol=1e-4)


def test_ssm_scan_vs_step_consistency():
    """Chunked kernel == sequential single-step recurrence."""
    bsz, s, c, n = 1, 12, 8, 4
    x = jnp.asarray(RNG.normal(size=(bsz, s, c)), jnp.float32)
    dt = jnp.asarray(np.abs(RNG.normal(size=(bsz, s, c))) * 0.1, jnp.float32)
    a = -jnp.asarray(np.abs(RNG.normal(size=(c, n))) + 0.1, jnp.float32)
    b = jnp.asarray(RNG.normal(size=(bsz, s, n)), jnp.float32)
    cm = jnp.asarray(RNG.normal(size=(bsz, s, n)), jnp.float32)
    d = jnp.asarray(RNG.normal(size=(c,)), jnp.float32)
    y, hf = ssm_scan(x, dt, a, b, cm, d, chunk=4, block_c=8, interpret=True)
    h = jnp.zeros((bsz, c, n), jnp.float32)
    ys = []
    for t in range(s):
        yt, h = ref.ssm_step_ref(x[:, t], dt[:, t], a, b[:, t], cm[:, t], d, h)
        ys.append(yt)
    np.testing.assert_allclose(np.asarray(y), np.stack(ys, 1), rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(hf), np.asarray(h), rtol=1e-4,
                               atol=1e-4)


@pytest.mark.parametrize("m,k,n,bm,bn,bk", [
    (64, 32, 48, 32, 16, 16),
    (100, 64, 128, 32, 64, 32),     # ragged M
    (16, 128, 16, 16, 16, 32),      # K-major sweep
])
def test_swiglu_fused(m, k, n, bm, bn, bk):
    from repro.kernels.swiglu import swiglu, swiglu_ref
    x = jnp.asarray(RNG.normal(size=(m, k)), jnp.float32)
    wg = jnp.asarray(RNG.normal(size=(k, n)), jnp.float32)
    wu = jnp.asarray(RNG.normal(size=(k, n)), jnp.float32)
    out = swiglu(x, wg, wu, block_m=bm, block_n=bn, block_k=bk,
                 interpret=True)
    want = swiglu_ref(x, wg, wu)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)
