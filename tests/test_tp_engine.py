"""Tensor-parallel packed serving (DESIGN.md §11).

The TP equivalence suite: ``tp=2`` must be f32 token-exact against ``tp=1``
across GQA, MLA (+MoE) and the recurrent families, on a mixed
prefill+decode workload, at ``async_depth`` 0 and 1 — while keeping the
packed step's 1 model dispatch + 1 host sync per iteration and the
(|T buckets| + 1) × |kv buckets| compile-cache bound.

These tests need ≥ 2 visible devices, so they run in CI's
``tp-host-devices`` job (``XLA_FLAGS=--xla_force_host_platform_device_count
=2``) and skip on the single-device tier-1 run; a subprocess smoke in
``tests/test_distributed.py`` keeps the default pipeline covering the TP
path too.  Equivalence compares in f32 (see DESIGN.md §9: bf16
accumulation-order diffs flip MoE routing) — "token-exact" means identical
sampled tokens, which f32 preserves because the TP all-reduce only reorders
ulp-level partial sums.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, scale_down
from repro.models import model
from repro.serving.config import EngineConfig
from repro.serving.engine import ServeEngine
from repro.serving.request import Request

needs_devices = pytest.mark.skipif(
    jax.device_count() < 2,
    reason="needs >=2 devices "
           "(XLA_FLAGS=--xla_force_host_platform_device_count=2)")

# GQA, MLA(+MoE, shared experts, first dense layer), mLSTM+sLSTM,
# Mamba-hybrid (+attention, MoE) — every mixer family's TP layout
FAMILIES = ["tiny-toy", "deepseek-v2-236b", "xlstm-1.3b",
            "jamba-1.5-large-398b"]

SIZES = (16, 8)


def _cfg(name):
    cfg = get_config(name) if name == "tiny-toy" else scale_down(
        get_config(name))
    if cfg.moe is not None:
        # dropless so tp=1 and tp=2 route identically at capacity edges
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, capacity_factor=16.0))
    return dataclasses.replace(cfg, dtype="float32")


@pytest.fixture(scope="module", params=FAMILIES)
def family(request):
    cfg = _cfg(request.param)
    params = model.init(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _run(cfg, params, tp, depth):
    eng = ServeEngine(cfg, params, max_slots=2, max_len=48,
                      discrete_sizes=SIZES, avg_decode_len=4, tp=tp,
                      async_depth=depth)
    rng = np.random.default_rng(7)
    # mixed workload: prompts long enough to chunk across iterations plus
    # short ones that decode while others still prefill, through slot reuse
    for i, n in enumerate([3, 11, 5, 9, 4]):
        eng.submit(Request(
            rid=i, prompt=list(map(int, rng.integers(0, cfg.vocab_size,
                                                     size=n))),
            max_new_tokens=4))
    done = eng.run()
    assert len(done) == 5
    return eng, {r.rid: tuple(r.output) for r in done}


@needs_devices
@pytest.mark.parametrize("depth", [0, 1])
def test_tp2_token_exact_vs_tp1(family, depth):
    cfg, params = family
    e1, out1 = _run(cfg, params, 1, depth)
    e2, out2 = _run(cfg, params, 2, depth)
    assert out1 == out2, (cfg.name, depth, out1, out2)
    # the TP step is still one dispatch + one (deferred) sync per iteration
    assert e2.stats.dispatches_per_iter == 1.0
    assert e2.stats.syncs_per_iter == 1.0
    # compile-cache bound unchanged under TP: (|T buckets| + 1) × |kv b.|
    bound = (len(SIZES) + 1) * len(e2.kv_buckets)
    assert e2._packed_step._cache_size() <= bound
    assert e2._packed_step._cache_size() == e1._packed_step._cache_size()
    # the collective-traffic model reports real traffic only under TP
    assert e2.stats.tp_collective_bytes > 0
    assert e1.stats.tp_collective_bytes == 0


def _run_prefix(cfg, params, tp, prefix):
    eng = ServeEngine(cfg, params, EngineConfig(
        max_slots=2, max_len=48, kv_block_size=8, discrete_sizes=SIZES,
        avg_decode_len=4.0, tp=tp, prefix_caching=prefix))
    base = list(range(11, 21))
    outs = {}
    # wave 1 completes (and registers its blocks) before wave 2 arrives,
    # so wave 2 can actually hit the shared prefix
    for wave in ([(0, base + [30])],
                 [(i, base + [30 + i]) for i in range(1, 3)]):
        for rid, prompt in wave:
            eng.submit(Request(rid=rid, prompt=prompt, max_new_tokens=6))
        for r in eng.run():
            outs[r.rid] = tuple(r.output)
    return eng, outs


@needs_devices
def test_tp2_prefix_caching_token_exact():
    """Prefix caching composes with TP: block ids index the (shard-local
    head/channel, replicated slot·seq) cache layout identically on every
    device, so shared-prefix serving stays f32 token-exact at tp=2 and the
    dispatch/sync/compile-cache invariants hold."""
    cfg = _cfg("tiny-toy")
    params = model.init(cfg, jax.random.PRNGKey(0))
    _, out_np = _run_prefix(cfg, params, 2, False)
    eng, out_pc = _run_prefix(cfg, params, 2, True)
    assert out_np == out_pc
    assert eng.kv.stats.prefix_hit_tokens == 20      # 2 requests x 10 tokens
    assert eng.kv.stats.cow_copies == 2
    # tp=1 with sharing agrees too (same engine, different mesh)
    _, out_t1 = _run_prefix(cfg, params, 1, True)
    assert out_t1 == out_pc
    assert eng.stats.dispatches_per_iter == 1.0
    assert eng.stats.syncs_per_iter == 1.0
    assert eng._packed_step._cache_size() <= (len(SIZES) + 1) * len(
        eng.kv_buckets)


@needs_devices
def test_tp_param_and_cache_are_sharded():
    """The mesh actually shards: a head-sharded param leaf and a KV cache
    leaf must be distributed over both devices, while last_token stays
    replicated (the §10 feedback loop closes without a collective)."""
    cfg = _cfg("tiny-toy")
    params = model.init(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_slots=2, max_len=32,
                      discrete_sizes=SIZES, avg_decode_len=4, tp=2)
    wq = eng.params["group0"]["sub0"]["mixer"]["wq"]
    assert not wq.sharding.is_fully_replicated
    k = eng.cache[0]["sub0"]["k"]
    assert not k.sharding.is_fully_replicated
    assert eng.last_token.sharding.is_fully_replicated
    # local shard of the head axis is half the global width
    assert wq.addressable_shards[0].data.shape[2] == wq.shape[2] // 2


def test_tp1_is_default_and_unsharded():
    cfg = get_config("tiny-toy")
    params = model.init(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_slots=2, max_len=32,
                      discrete_sizes=SIZES, avg_decode_len=4)
    assert eng.tp == 1 and eng._mesh is None


def test_tp_requires_packed_step_and_divisible_widths():
    cfg = get_config("tiny-toy")
    params = model.init(cfg, jax.random.PRNGKey(0))
    with pytest.raises(AssertionError):
        ServeEngine(cfg, params, step_mode="legacy", tp=2)
    # validation precedes mesh construction, so it raises even deviceless
    bad = dataclasses.replace(cfg, n_heads=3, n_kv_heads=3, head_dim=64)
    with pytest.raises(ValueError, match="n_heads"):
        ServeEngine(bad, params, tp=2)


def _run_spec(cfg, params, tp, spec_k, depth=1):
    eng = ServeEngine(cfg, params, EngineConfig(
        max_slots=2, max_len=64, discrete_sizes=SIZES, avg_decode_len=4.0,
        tp=tp, spec_k=spec_k, async_depth=depth, async_harvest=False))
    motif = [5, 9, 3, 7]
    for i, p in enumerate([motif * 5, ([2, 4] * 6)[:11], motif * 3]):
        eng.submit(Request(rid=i, prompt=list(p), max_new_tokens=8))
    done = eng.run()
    assert len(done) == 3 and eng.in_flight == 0
    return eng, {r.rid: tuple(r.output) for r in done}


@needs_devices
@pytest.mark.parametrize("depth", [0, 1])
def test_tp2_spec_decode_token_exact(depth):
    """Speculative decoding (DESIGN.md §13) composes with TP: the verify
    segment's acceptance/rollback runs on replicated metadata inside the
    shard_map body, so tp=2 spec serving is f32 token-exact against both
    tp=1 spec and the plain (spec_k=0) engine, with the 1-dispatch /
    1-deferred-sync invariant and the compile-cache bound intact."""
    cfg = _cfg("tiny-toy")
    params = model.init(cfg, jax.random.PRNGKey(0))
    _, base = _run_spec(cfg, params, 1, 0, depth)
    e1, out1 = _run_spec(cfg, params, 1, 3, depth)
    e2, out2 = _run_spec(cfg, params, 2, 3, depth)
    assert out1 == base
    assert out2 == base
    assert e2.stats.dispatches_per_iter == 1.0
    assert e2.stats.syncs_per_iter == 1.0
    assert e2.stats.spec_verify_segments > 0
    assert e2.stats.spec_accepted_tokens == e1.stats.spec_accepted_tokens
    bound = (len(SIZES) + 1) * len(e2.kv_buckets)
    assert e2._packed_step._cache_size() <= bound


def _run_int8(cfg, params, tp, kv_dtype):
    eng = ServeEngine(cfg, params, max_slots=2, max_len=48,
                      discrete_sizes=SIZES, avg_decode_len=4, tp=tp,
                      kv_dtype=kv_dtype)
    rng = np.random.default_rng(1)
    for i, n in enumerate([3, 11, 5, 9, 4]):
        eng.submit(Request(
            rid=i, prompt=list(map(int, rng.integers(0, cfg.vocab_size,
                                                     size=n))),
            max_new_tokens=4))
    done = eng.run()
    assert len(done) == 5
    return eng, {r.rid: tuple(r.output) for r in done}


@needs_devices
@pytest.mark.parametrize("arch", ["tiny-toy", "deepseek-v2-236b"])
def test_tp2_int8_kv_token_exact(arch):
    """int8 KV (DESIGN.md §15) composes with TP: GQA scale leaves shard on
    the kv-head axis next to their values (MLA latent scales replicate), so
    tp=2 quantized serving is f32 token-exact vs tp=1 quantized serving."""
    cfg = _cfg(arch)
    params = model.init(cfg, jax.random.PRNGKey(0))
    e1, out1 = _run_int8(cfg, params, 1, "int8")
    e2, out2 = _run_int8(cfg, params, 2, "int8")
    assert out1 == out2, (cfg.name, out1, out2)
    assert e2.stats.dispatches_per_iter == 1.0
    assert e2.stats.syncs_per_iter == 1.0
    assert e2.stats.kv_quant_bytes_saved > 0
    # quantization adds no retrace keys: the tp=2 compile cache is exactly
    # the native engine's on the same workload
    e2_bf, out2_bf = _run_int8(cfg, params, 2, "bf16")
    assert out2_bf == out2, cfg.name
    assert e2._packed_step._cache_size() == \
        e2_bf._packed_step._cache_size()
    if cfg.mla is None:
        # GQA: int8 value leaf AND its f32 scale leaf shard across devices
        sub = e2.cache[0]["sub0"]
        assert sub["k"].dtype == jnp.int8
        assert not sub["k"].sharding.is_fully_replicated
        assert not sub["k_s"].sharding.is_fully_replicated
    else:
        # absorbed MLA: latent cache + scales replicate (head-dim sharding
        # happens in the absorbed projections, not the cache)
        sub = e2.cache[0]["sub0"]
        assert sub["c_kv"].dtype == jnp.int8
        assert sub["c_kv_s"].sharding.is_fully_replicated
