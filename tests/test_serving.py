"""Serving-engine tests: end-to-end correctness vs naive decoding, scheduler
invariants (hypothesis), KV manager accounting, async EOS semantics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # optional-hypothesis shim

from repro.configs import get_config
from repro.models import model
from repro.serving.engine import ServeEngine
from repro.serving.kvcache import PagedKVManager
from repro.serving.request import Request
from repro.serving.scheduler import GlobalBatchScheduler


@pytest.fixture(scope="module")
def toy():
    cfg = get_config("tiny-toy")
    params = model.init(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_engine_matches_naive_greedy(toy):
    cfg, params = toy
    eng = ServeEngine(cfg, params, max_slots=4, max_len=64,
                      discrete_sizes=(32, 16, 8), avg_decode_len=6)
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=list(rng.integers(0, cfg.vocab_size,
                                             size=int(rng.integers(3, 14)))),
                    max_new_tokens=5) for i in range(6)]
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    assert len(done) == len(reqs)
    for r in done[:3]:
        toks = list(r.prompt)
        want = []
        for _ in range(r.max_new_tokens):
            logits, _ = model.forward_full(
                cfg, params, jnp.asarray(toks, jnp.int32)[None])
            t = int(np.argmax(np.asarray(logits[0, -1])))
            want.append(t)
            toks.append(t)
        assert r.output == want, (r.rid, r.output, want)


def test_async_eos_one_extra_iteration(toy):
    """EOS acts one iteration late (§5.3) and the post-EOS token is
    stripped from the final output."""
    cfg, params = toy
    # find what token the model emits first for some prompt, use it as EOS
    prompt = [5, 9, 11]
    logits, _ = model.forward_full(cfg, params,
                                   jnp.asarray(prompt, jnp.int32)[None])
    eos = int(np.argmax(np.asarray(logits[0, -1])))
    eng = ServeEngine(cfg, params, max_slots=2, max_len=32,
                      discrete_sizes=(16, 8), avg_decode_len=4)
    r = Request(rid=0, prompt=prompt, max_new_tokens=6, eos_id=eos)
    eng.submit(r)
    done = eng.run()
    assert len(done) == 1
    assert done[0].output[-1] == eos          # stripped to the EOS token
    assert len(done[0].output) <= 2           # EOS first or second token
    # decode_tokens counts the extra post-EOS token (paper's <1% overhead)
    assert eng.stats.decode_tokens >= len(done[0].output)


def test_discrete_batching_only_emits_configured_sizes(toy):
    cfg, params = toy
    sizes = (16, 8)
    eng = ServeEngine(cfg, params, max_slots=4, max_len=64,
                      discrete_sizes=sizes, avg_decode_len=4)
    rng = np.random.default_rng(1)
    for i in range(5):
        eng.submit(Request(rid=i, prompt=list(rng.integers(0, 64, size=11)),
                           max_new_tokens=4))
    eng.run()
    assert set(eng.stats.dense_batch_hist) <= set(sizes)


# ---------------------------------------------------------------------------
# KV manager properties
# ---------------------------------------------------------------------------
@given(tokens=st.lists(st.integers(1, 300), min_size=1, max_size=20))
@settings(max_examples=50, deadline=None)
def test_kv_allocation_never_exceeds_pool(tokens):
    kv = PagedKVManager(total_pages=64, page_size=16, bytes_per_token=128,
                        avg_decode_len=32)
    live = []
    for i, t in enumerate(tokens):
        if kv.allocate(i, t):
            live.append(i)
        assert kv.pages_used <= 64
        assert kv.pages_used + kv.pages_free == 64
    for i in live:
        kv.free(i)
    assert kv.pages_used == 0 and kv.pages_free == 64


@given(st.data())
@settings(max_examples=30, deadline=None)
def test_peak_estimator_is_admission_safe(data):
    """If the estimator admits, actually growing every request to its
    predicted end never exceeds the pool (no-eviction invariant, §4.4)."""
    kv = PagedKVManager(total_pages=48, page_size=8, bytes_per_token=64,
                        avg_decode_len=16)
    reqs = []
    for i in range(data.draw(st.integers(1, 8))):
        p = data.draw(st.integers(1, 60))
        m = data.draw(st.integers(1, 40))
        r = Request(rid=i, prompt=list(range(p)), max_new_tokens=m)
        if kv.can_admit(r, reqs) and kv.allocate(i, p):
            reqs.append(r)
    # simulate worst-case growth to predicted lengths
    grown = [r.predicted_final_len(kv.avg_decode_len) for r in reqs]
    finish = sorted(range(len(reqs)), key=lambda j: grown[j] - reqs[j].prompt_len)
    alive = set(range(len(reqs)))
    for t in sorted(set(grown[j] - reqs[j].prompt_len for j in finish)) or [0]:
        demand = sum(kv.pages_for(min(reqs[j].prompt_len + t, grown[j]))
                     for j in alive)
        assert demand <= kv.stats.device_pages_total
        for j in list(alive):
            if grown[j] - reqs[j].prompt_len <= t:
                alive.discard(j)


def test_offload_upload_roundtrip():
    kv = PagedKVManager(total_pages=32, page_size=8, bytes_per_token=64,
                        avg_decode_len=8, host_capacity_bytes=1 << 20)
    kv.allocate(1, 40)
    data = np.arange(40 * 16, dtype=np.float32).reshape(40, 16)
    kv.offload(1, data)
    assert kv.pages_used == 0
    assert kv.stats.offload_bytes == data.nbytes
    back = kv.upload(1, np.float32, (40, 16))
    np.testing.assert_array_equal(back, data)
    assert kv.stats.upload_bytes == data.nbytes
    assert kv.pages_used == kv.pages_for(40)


def test_host_pool_lru_eviction():
    kv = PagedKVManager(total_pages=64, page_size=8, bytes_per_token=64,
                        avg_decode_len=8, host_capacity_bytes=1000)
    for rid in range(5):
        kv.allocate(rid, 8)
        kv.offload(rid, np.zeros(100, np.float32))   # 400 B each
    assert kv.stats.host_bytes <= 1000
    assert kv.upload(0, np.float32, (100,)) is None  # LRU-evicted
    assert kv.upload(4, np.float32, (100,)) is not None


def test_scheduler_admission_respects_capacity():
    kv = PagedKVManager(total_pages=8, page_size=8, bytes_per_token=64,
                        avg_decode_len=64)
    sched = GlobalBatchScheduler(kv, discrete_sizes=(16, 8), max_active=16)
    for i in range(10):
        sched.submit(Request(rid=i, prompt=list(range(16)),
                             max_new_tokens=48))
    plan = sched.plan()
    assert plan is not None
    assert sched.n_active < 10           # capacity-bounded admission
    assert kv.pages_used <= kv.stats.device_pages_total
