"""End-to-end system behaviour tests (replaces the scaffold placeholder):
the full NanoFlow loop — cost model -> autosearch plan -> engine run —
plus model-level semantics the paper depends on."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import SHAPES, applicable_shapes, get_config
from repro.core import costmodel as cm
from repro.core.autosearch import autosearch, throughput_estimate
from repro.models import model
from repro.serving.engine import ServeEngine
from repro.serving.request import Request


def test_shape_cells_are_the_assignment():
    """10 archs × shapes: long_500k only for ssm/hybrid (DESIGN.md §4)."""
    archs = ["jamba-1.5-large-398b", "xlstm-1.3b", "qwen3-4b", "minitron-4b",
             "qwen3-8b", "starcoder2-7b", "llava-next-34b", "musicgen-medium",
             "arctic-480b", "deepseek-v2-236b"]
    cells = [(a, s.name) for a in archs
             for s in applicable_shapes(get_config(a))]
    assert len(cells) == 32  # 8 archs x 3 + 2 archs x 4
    long_ctx = [a for a, s in cells if s == "long_500k"]
    assert sorted(long_ctx) == ["jamba-1.5-large-398b", "xlstm-1.3b"]


def test_param_counts_sane():
    """Config-derived parameter counts match the published model sizes."""
    expect = {
        "jamba-1.5-large-398b": (330e9, 430e9),
        # assignment config is tagged "unverified"; block-diag qkv + untied
        # head at 48L/2048d lands at 2.0B
        "xlstm-1.3b": (1.0e9, 2.2e9),
        "qwen3-4b": (3.2e9, 5.0e9),
        "minitron-4b": (3.5e9, 5.2e9),
        "qwen3-8b": (7.0e9, 9.3e9),
        "starcoder2-7b": (6.3e9, 8.0e9),
        "llava-next-34b": (30e9, 38e9),
        # decoder only (the T5 text encoder is out of scope / stubbed)
        "musicgen-medium": (1.2e9, 2.4e9),
        "arctic-480b": (420e9, 520e9),
        "deepseek-v2-236b": (210e9, 260e9),
        "llama2-70b": (67e9, 70e9),
    }
    for name, (lo, hi) in expect.items():
        n = model.num_params(get_config(name))
        assert lo <= n <= hi, f"{name}: {n/1e9:.1f}B not in [{lo/1e9},{hi/1e9}]"


def test_moe_active_params_below_total():
    for name in ("arctic-480b", "deepseek-v2-236b", "jamba-1.5-large-398b"):
        cfg = get_config(name)
        assert model.active_params(cfg) < 0.5 * model.num_params(cfg)


def test_autosearch_improves_all_ported_models():
    """Paper Fig. 15 analogue: overlap plan beats sequential for every arch
    the technique applies to (network or memory ops to hide)."""
    from repro.core.autosearch import sequential_schedule
    w = cm.Workload(1024, 512)
    for name in ("llama2-70b", "qwen3-8b", "arctic-480b",
                 "deepseek-v2-236b", "llava-next-34b"):
        cfg = get_config(name)
        nano = autosearch(cfg, w, cm.TPU_V5E, 256)
        seq = sequential_schedule(cfg, w, cm.TPU_V5E, 256)
        assert nano.iter_time < seq.iter_time, name


def test_full_serving_path_with_offload_and_accounting():
    cfg = get_config("tiny-toy")
    params = model.init(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_slots=3, max_len=48,
                      discrete_sizes=(16, 8), avg_decode_len=4)
    rng = np.random.default_rng(7)
    reqs = [Request(rid=i, prompt=list(rng.integers(0, 64, size=9)),
                    max_new_tokens=4) for i in range(7)]
    for r in reqs:
        eng.submit(r)
    done = eng.run()
    assert len(done) == 7
    # all KV offloaded for multi-round reuse
    assert eng.kv.stats.aggregated_copies == 7
    assert eng.kv.pages_used == 0
    # continuous batching keeps slots busy: far fewer iters than serial
    assert eng.stats.iterations < 7 * (4 + 3)


def test_decode_cache_donation_single_buffer():
    """The jitted decode step donates the cache (no double-buffering)."""
    cfg = get_config("tiny-toy")
    params = model.init(cfg, jax.random.PRNGKey(0))
    cache = model.init_cache(cfg, 1, 2, 16)
    clen = jnp.zeros((2,), jnp.int32)
    toks = jnp.zeros((2, 1), jnp.int32)
    fn = jax.jit(lambda p, c, t, l: model.forward_decode(cfg, p, t, c, l),
                 donate_argnums=(1,))
    logits, new_cache = fn(params, cache, toks, clen)
    assert logits.shape == (2, cfg.vocab_size)
    with pytest.raises(RuntimeError):
        _ = np.asarray(jax.tree.leaves(cache)[0])   # donated => invalidated


def test_vlm_and_audio_input_specs():
    llava = get_config("llava-next-34b")
    sp = model.input_specs(llava, SHAPES["prefill_32k"])
    assert sp["patches"].shape == (32, 1024, llava.d_model)
    assert sp["tokens"].shape == (32, 32768 - 1024)
    mg = get_config("musicgen-medium")
    sp = model.input_specs(mg, SHAPES["train_4k"])
    assert sp["tokens"].shape == (256, 4096, 4)
    sp = model.input_specs(mg, SHAPES["decode_32k"])
    assert sp["tokens"].shape == (128, 1, 4)
    assert sp["cache_len"].shape == (128,)


def test_throughput_estimate_below_optimal():
    cfg = get_config("llama2-70b")
    w = cm.Workload(512, 1024)
    ms = cm.model_stats(cfg)
    sched = autosearch(cfg, w, cm.A100_80G, 8, bdense=2048)
    tp = throughput_estimate(cfg, sched, w, cm.A100_80G, 8, bdense=2048)
    opt = cm.optimal_throughput(cm.A100_80G, ms, 8) / 8
    assert 0.3 * opt < tp <= opt * 1.001
