"""Block-table KV with cross-request prefix caching + CoW (DESIGN.md §12).

Covers the tentpole invariants:
  * property-style block-table invariants under a random op soup — a block
    with refcount > 0 is never on the free list or in the evictor, hash
    entries only point at immutable *full* blocks whose content never
    changes after registration, shared blocks are always hash-registered;
  * a shared prefix ending mid-block takes exactly one CoW copy and shares
    the preceding full blocks (the bucket-edge case);
  * f32 token-exactness of the prefix-caching engine vs the no-sharing
    engine across GQA and MLA configs at async depth 0 and 1, with the
    packed step's 1-dispatch/1-deferred-sync invariant and the
    (|T buckets| + 1) × |kv buckets| compile-cache bound unchanged;
  * LRU eviction of cached ref-0 blocks under allocation pressure;
  * the EngineConfig satellite: validation in ``__post_init__``, the shared
    ``add_args``/``from_args`` CLI surface, env pinning via ``from_env``,
    legacy-kwarg deprecation (``page_size`` -> ``kv_block_size``);
  * the stats satellite: ``EngineStats``/``KVStats`` ``snapshot()`` schema.
"""
import argparse
import dataclasses
import warnings

import jax
import numpy as np
import pytest

from repro.configs import get_config, scale_down
from repro.models import model
from repro.serving.config import EngineConfig
from repro.serving.engine import ServeEngine
from repro.serving.kvcache import BlockAllocator, PagedKVManager
from repro.serving.request import Request

SIZES = (16, 8)


def _mgr(pages=32, bs=4, prefix=True):
    return PagedKVManager(total_pages=pages, page_size=bs, bytes_per_token=1,
                          avg_decode_len=4.0, prefix_caching=prefix)


# ---------------------------------------------------------------------------
# allocator-level invariants
# ---------------------------------------------------------------------------
def test_block_allocator_protocol():
    assert isinstance(_mgr(), BlockAllocator)


def _check_invariants(kv: PagedKVManager, frozen: dict) -> None:
    """The BlockAllocator protocol invariants, checked against internals."""
    table_refs: dict[int, int] = {}
    for t in kv.tables.values():
        for b in t:
            table_refs[b] = table_refs.get(b, 0) + 1
    pin_refs: dict[int, int] = {}
    for s, _ in kv._pending_copies:
        pin_refs[s] = pin_refs.get(s, 0) + 1
    free = set(kv.free_pages)
    # refcounts exactly mirror table membership + copy-source pins, and a
    # referenced block is never free or evictable
    for b, n in kv._ref.items():
        assert n == table_refs.get(b, 0) + pin_refs.get(b, 0), b
        assert n > 0
        assert b not in free
        assert b not in kv.evictor
    for b in set(table_refs) | set(pin_refs):
        assert b in kv._ref
    # a block in two tables (shared) must be hash-registered (immutable)
    for b, n in table_refs.items():
        if n > 1:
            assert b in kv._key, b
    # hash entries: bijective with _key, full blocks only, never free,
    # content frozen forever once registered
    for key, b in kv._hash.items():
        assert kv._key.get(b) == key
        assert len(kv._tokens[b]) == kv.page_size
        assert b not in free
        if key in frozen:
            assert frozen[key] == kv._tokens[b], "registered block mutated"
        else:
            frozen[key] = kv._tokens[b]
    # a registered block is either referenced or cached in the evictor
    for b in kv._key:
        assert b in kv._ref or b in kv.evictor
    # free list disjoint from the evictor
    for b in free:
        assert b not in kv.evictor


def test_block_table_invariants_random_ops():
    """Property-style: a random soup of allocate / (ensure+extend) / free /
    drain over a tiny token alphabet (to force prefix collisions and
    sharing) keeps every block-table invariant at every step."""
    rng = np.random.default_rng(0)
    kv = _mgr(pages=24, bs=4)
    frozen: dict = {}
    live: list[tuple[int, list[int]]] = []
    next_rid = 0
    for _ in range(300):
        op = int(rng.integers(0, 4))
        if op == 0 or not live:
            plen = int(rng.integers(1, 14))
            prompt = [int(t) for t in rng.integers(0, 3, size=plen)]
            if kv.allocate(next_rid, plen, token_ids=prompt):
                live.append((next_rid, prompt))
                next_rid += 1
        elif op == 1:
            i = int(rng.integers(len(live)))
            rid, toks = live[i]
            toks = toks + [int(rng.integers(0, 3))]
            if kv.ensure(rid, len(toks)):
                assert kv.extend(rid, len(toks), token_ids=toks)
                live[i] = (rid, toks)
        elif op == 2:
            i = int(rng.integers(len(live)))
            rid, _ = live.pop(i)
            kv.free(rid)
        else:
            kv.take_pending_copies()
        _check_invariants(kv, frozen)
    assert kv.stats.prefix_hit_tokens > 0, "soup never shared a prefix"
    assert kv.stats.extend_failures == 0


def test_shared_prefix_ends_mid_block():
    """Bucket-edge case: divergence *inside* a cached block shares the full
    blocks before it and takes exactly one CoW copy of the divergent one."""
    kv = _mgr(pages=32, bs=4)
    p0 = [1, 2, 3, 4, 5, 6, 7, 8, 9]
    assert kv.allocate(0, len(p0), token_ids=p0)
    assert kv.cached_tokens(0) == 0
    assert kv.extend(0, len(p0), token_ids=p0)    # commits blocks 0 and 1
    p1 = [1, 2, 3, 4, 5, 6, 99, 98, 97]           # diverges at token 6
    assert kv.allocate(1, len(p1), token_ids=p1)
    # block 0 (tokens 0-3) shared whole; tokens 4-5 of block 1 via CoW
    assert kv.cached_tokens(1) == 6
    assert kv.stats.prefix_hit_tokens == 6
    assert kv.table(1)[0] == kv.table(0)[0]
    assert kv.table(1)[1] != kv.table(0)[1]
    assert kv.take_pending_copies() == [(kv.table(0)[1], kv.table(1)[1])]
    shared = kv.table(0)[0]
    assert kv._ref[shared] == 2 and shared in kv._key


def test_full_block_reuse_no_cow():
    """A prompt that extends a committed prompt block-exactly shares every
    full block with no copy."""
    kv = _mgr(pages=32, bs=4)
    p0 = [1, 2, 3, 4, 5, 6, 7, 8]
    assert kv.allocate(0, 8, token_ids=p0)
    assert kv.extend(0, 8, token_ids=p0)
    p1 = p0 + [9, 10, 11]
    assert kv.allocate(1, len(p1), token_ids=p1)
    assert kv.cached_tokens(1) == 8
    assert kv.table(1)[:2] == kv.table(0)[:2]
    assert kv.take_pending_copies() == []
    assert kv.stats.cow_copies == 0


def test_lru_eviction_reclaims_cached_blocks():
    kv = _mgr(pages=4, bs=4)
    p = [1, 2, 3, 4, 5, 6, 7, 8]
    assert kv.allocate(0, 8, token_ids=p)
    assert kv.extend(0, 8, token_ids=p)
    kv.free(0)
    # registered blocks stay cached (evictor), not on the free list
    assert kv.pages_free == 4 and len(kv.free_pages) == 2
    # an unrelated allocation under pressure reclaims a cached block and
    # drops its hash entry for good
    assert kv.allocate(1, 12, token_ids=[9] * 12)
    assert kv.stats.evicted_blocks == 1
    _check_invariants(kv, {})


def test_no_prefix_mode_degenerates_to_private_pages():
    kv = _mgr(pages=8, bs=4, prefix=False)
    assert kv.allocate(0, 8, token_ids=[1] * 8)
    assert kv.extend(0, 8, token_ids=[1] * 8)
    assert not kv._hash and not len(kv.evictor)
    assert kv.cached_tokens(0) == 0
    kv.free(0)
    assert sorted(kv.free_pages) == list(range(8))
    assert kv.pages_used == 0


# ---------------------------------------------------------------------------
# engine-level: f32 token-exactness vs the no-sharing engine
# ---------------------------------------------------------------------------
ENGINE_FAMILIES = ["tiny-toy", "deepseek-v2-236b"]   # GQA and (absorbed) MLA


@pytest.fixture(scope="module", params=ENGINE_FAMILIES)
def family(request):
    cfg = get_config(request.param) if request.param == "tiny-toy" \
        else scale_down(get_config(request.param))
    if cfg.moe is not None:
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, capacity_factor=16.0))
    cfg = dataclasses.replace(cfg, dtype="float32")
    params = model.init(cfg, jax.random.PRNGKey(0))
    return cfg, params


@pytest.fixture(scope="module")
def toy():
    cfg = dataclasses.replace(get_config("tiny-toy"), dtype="float32")
    return cfg, model.init(cfg, jax.random.PRNGKey(0))


def _serve(cfg, params, prefix, depth, waves):
    eng = ServeEngine(cfg, params, EngineConfig(
        max_slots=4, max_len=64, kv_block_size=8, discrete_sizes=SIZES,
        async_depth=depth, prefix_caching=prefix, avg_decode_len=4.0))
    outs = {}
    for wave in waves:
        for rid, prompt in wave:
            # 6 new tokens: the committed stream (prompt + output[:-1]) is
            # then 16 tokens, so the *second* block fills and registers —
            # that's what arms the partial-tail CoW path for wave 2
            eng.submit(Request(rid=rid, prompt=list(prompt),
                               max_new_tokens=6))
        for r in eng.run():
            outs[r.rid] = tuple(r.output)
    return eng, outs


@pytest.mark.parametrize("depth", [0, 1])
def test_prefix_engine_token_exact_vs_no_sharing(family, depth):
    """Two waves — the second shares a 10-token prefix with a completed
    request and diverges mid-block (block size 8) — must sample exactly the
    same f32 tokens with and without prefix caching, while actually sharing
    (hits and CoW copies observed) and keeping the packed step's dispatch /
    sync / compile-cache invariants."""
    cfg, params = family
    base = list(range(11, 21))                       # 10 shared tokens
    wave1 = [(0, base + [30])]
    wave2 = [(i, base + [30 + i]) for i in range(1, 4)]
    _, out0 = _serve(cfg, params, False, depth, [wave1, wave2])
    e1, out1 = _serve(cfg, params, True, depth, [wave1, wave2])
    assert out0 == out1, (cfg.name, depth)
    s = e1.kv.stats
    assert s.prefix_hit_tokens == 30                 # 3 requests x 10 tokens
    assert s.cow_copies == 3                         # one mid-block CoW each
    assert e1.stats.dispatches_per_iter == 1.0
    assert e1.stats.syncs_per_iter == 1.0
    bound = (len(SIZES) + 1) * len(e1.kv_buckets)
    assert e1._packed_step._cache_size() <= bound


# ---------------------------------------------------------------------------
# EngineConfig satellite
# ---------------------------------------------------------------------------
def test_engine_config_validation():
    with pytest.raises(AssertionError):
        EngineConfig(step_mode="packed", prefill_mode="recompute")
    with pytest.raises(AssertionError):
        EngineConfig(tp=2, step_mode="legacy")
    with pytest.raises(AssertionError):
        EngineConfig(prefix_caching=True, step_mode="legacy")
    with pytest.raises(AssertionError):
        EngineConfig(prefix_caching=True, max_len=60, kv_block_size=16)
    # defaulting rules stay un-baked: replace() re-resolves
    c = EngineConfig()
    assert c.resolved_step_mode == "packed" and c.resolved_async_depth == 1
    c2 = dataclasses.replace(c, prefill_mode="recompute", step_mode="legacy")
    assert c2.resolved_step_mode == "legacy" and c2.resolved_async_depth == 0


def test_engine_config_from_args_and_overrides():
    ap = argparse.ArgumentParser()
    EngineConfig.add_args(ap)
    ns = ap.parse_args(["--slots", "4", "--max-len", "64",
                        "--kv-block-size", "8", "--prefix-caching",
                        "--tp", "2", "--no-kv-bucketing"])
    cfg = EngineConfig.from_args(ns)
    assert cfg.max_slots == 4 and cfg.max_len == 64
    assert cfg.kv_block_size == 8 and cfg.prefix_caching and cfg.tp == 2
    assert cfg.resolved_kv_buckets() == (64,)
    # overrides win over flags (benchmark mode matrices rely on this)
    assert EngineConfig.from_args(ns, prefix_caching=False,
                                  tp=1).prefix_caching is False


def test_engine_config_env_pinned_once(monkeypatch):
    monkeypatch.setenv("REPRO_ATTN_FAST", "1")
    monkeypatch.delenv("REPRO_ATTN_STREAM", raising=False)
    cfg = EngineConfig.from_env()
    assert cfg.attn_fast is True and cfg.attn_stream is False
    # explicit values win over env
    assert EngineConfig.from_env(attn_fast=False).attn_fast is False
    # from_env pins: a later env flip cannot change the config
    monkeypatch.setenv("REPRO_ATTN_FAST", "0")
    assert cfg.resolved_attn_fast() is True


def test_legacy_kwargs_deprecated_and_mapped(toy):
    cfg, params = toy
    with pytest.warns(DeprecationWarning):
        eng = ServeEngine(cfg, params, max_slots=2, max_len=32, page_size=8)
    assert eng.kv.page_size == 8 and eng.config.kv_block_size == 8
    assert eng.max_slots == 2
    with pytest.raises(TypeError, match="bogus"):
        ServeEngine(cfg, params, bogus=1)
    # config-first call sites stay warning-free, overrides allowed
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        eng = ServeEngine(cfg, params,
                          EngineConfig(max_slots=2, max_len=32), max_len=64)
    assert eng.max_len == 64 and eng.config.max_slots == 2


# ---------------------------------------------------------------------------
# stats satellite: common snapshot() schema
# ---------------------------------------------------------------------------
def test_stats_snapshot_schema(toy):
    cfg, params = toy
    eng = ServeEngine(cfg, params, EngineConfig(
        max_slots=2, max_len=32, kv_block_size=8, discrete_sizes=(8,),
        prefix_caching=True, avg_decode_len=2.0))
    eng.submit(Request(rid=0, prompt=[1, 2, 3], max_new_tokens=2))
    eng.run()
    snap = eng.stats.snapshot()
    for k in ("iterations", "model_dispatches", "host_syncs", "total_tokens",
              "throughput", "dispatches_per_iter", "syncs_per_iter",
              "dense_batch_hist", "kv_bucket_hist", "wall_time"):
        assert k in snap, k
    # hist entries are copies, not views into live engine state
    snap["dense_batch_hist"][999] = 1
    assert 999 not in eng.stats.dense_batch_hist
    kv = eng.kv.stats.snapshot()
    for k in ("device_pages_total", "offload_bytes", "prefix_hit_tokens",
              "cow_copies", "evicted_blocks", "extend_failures"):
        assert k in kv, k
