# NOTE: never set --xla_force_host_platform_device_count here — smoke tests
# and benches must see 1 device; multi-device tests spawn subprocesses.
# (tests/test_tp_engine.py instead SKIPS below 2 devices and runs in CI's
# tp-host-devices job, where the flag is set in the job environment.)
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
