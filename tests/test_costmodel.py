"""Cost-model tests: reproduction of the paper's published numbers +
hypothesis property tests of the §3 equations."""

import pytest
from _hyp import given, settings, st  # optional-hypothesis shim

from repro.configs import get_config
from repro.core import costmodel as cm
from repro.core.autosearch import autosearch, sequential_schedule


@pytest.fixture(scope="module")
def llama70b():
    return cm.model_stats(get_config("llama2-70b"))


class TestPaperNumbers:
    """Exact checks against the paper's published values."""

    def test_param_count(self, llama70b):
        assert 67e9 < llama70b.p_model < 70e9

    def test_optimal_throughput_eq9(self, llama70b):
        # paper §3.4: 8×A100 → ≈17828 tok/s (they use exactly 70e9 params)
        opt = cm.optimal_throughput(cm.A100_80G, llama70b, 8)
        assert abs(opt - 17828) / 17828 < 0.05

    def test_table2_gemm_rows(self, llama70b):
        rows = {r["op"]: r for r in cm.table2(
            get_config("llama2-70b"), cm.Workload(512, 1024), cm.A100_80G, 8,
            bdense=2048)}
        # paper Table 2 GFLOP column (exact formulas)
        assert abs(rows["GEMM-KQV"]["gflops"] - 27487.8) < 1.0
        assert abs(rows["GEMM-O"]["gflops"] - 21990.2) < 1.0
        assert abs(rows["GEMM-UG"]["gflops"] - 153931.6) < 1.0
        assert abs(rows["GEMM-D"]["gflops"] - 76965.8) < 1.0

    def test_table2_comm_row(self, llama70b):
        rows = cm.table2(get_config("llama2-70b"), cm.Workload(512, 1024),
                         cm.A100_80G, 8, bdense=2048)
        net_gb = sum(r["net_gb"] for r in rows)
        t_net = sum(r["t_net_ms"] for r in rows)
        assert abs(net_gb - 75.2) < 1.0          # paper: 75.2 GB
        assert abs(t_net - 31.33) < 1.0          # paper: 31.33 ms

    def test_compute_bound_classification(self, llama70b):
        # paper Fig. 2: LLaMA-2-70B @ 8×A100 is compute-bound on all traces
        for w in (cm.WORKLOADS["splitwise"], cm.WORKLOADS["lmsys"],
                  cm.WORKLOADS["sharegpt"]):
            assert cm.classify(cm.A100_80G, llama70b, w, 8) == "compute-bound"

    def test_nanoflow_beats_sequential(self, llama70b):
        cfg = get_config("llama2-70b")
        w = cm.Workload(512, 1024)
        nano = autosearch(cfg, w, cm.A100_80G, 8, bdense=2048)
        seq = sequential_schedule(cfg, w, cm.A100_80G, 8, bdense=2048)
        speedup = seq.iter_time / nano.iter_time
        # paper ablation (Fig. 13): ≥1.17× over non-overlap; model ≈1.2–1.9×
        assert 1.1 < speedup < 2.5


hw_strat = st.sampled_from(list(cm.HARDWARE.values()))
w_strat = st.builds(cm.Workload,
                    p=st.floats(16, 8192), d=st.floats(1, 4096))


class TestProperties:
    @given(w=w_strat, n=st.integers(1, 64))
    @settings(max_examples=50, deadline=None)
    def test_eq9_independent_of_workload(self, w, n):
        """Optimal throughput depends only on compute and params (§3.4)."""
        ms = cm.model_stats(get_config("llama2-70b"))
        base = cm.optimal_throughput(cm.A100_80G, ms, n)
        assert base == cm.optimal_throughput(cm.A100_80G, ms, n)
        assert base == pytest.approx(
            n * cm.A100_80G.compute / (2 * ms.p_active))

    @given(p=st.floats(16, 4096), d1=st.floats(1, 2000), delta=st.floats(1, 2000))
    @settings(max_examples=50, deadline=None)
    def test_tr_monotone_in_decode_length(self, p, d1, delta):
        """Longer decode (fixed prefill) pushes memory-bound (§3.3)."""
        ms = cm.model_stats(get_config("llama2-70b"))
        t1 = cm.t_r(cm.A100_80G, ms, cm.Workload(p, d1), 8)
        t2 = cm.t_r(cm.A100_80G, ms, cm.Workload(p, d1 + delta), 8)
        assert t2 >= t1 * 0.999

    @given(w=w_strat)
    @settings(max_examples=30, deadline=None)
    def test_times_positive_and_finite(self, w):
        ms = cm.model_stats(get_config("qwen3-8b"))
        for fn in (cm.t_mem, ):
            assert fn(cm.TPU_V5E) > 0
        assert 0 < cm.t_compute(cm.TPU_V5E, ms, w, 256) < 1e4
        assert 0 <= cm.t_net(cm.TPU_V5E, ms, w, 256) < 1e4

    @given(b=st.integers(32, 4096))
    @settings(max_examples=20, deadline=None)
    def test_table2_scales_linearly_in_batch(self, b):
        cfg = get_config("llama2-70b")
        w = cm.Workload(512, 1024)
        r1 = cm.table2(cfg, w, cm.A100_80G, 8, bdense=b)
        r2 = cm.table2(cfg, w, cm.A100_80G, 8, bdense=2 * b)
        g1 = next(r["gflops"] for r in r1 if r["op"] == "GEMM-UG")
        g2 = next(r["gflops"] for r in r2 if r["op"] == "GEMM-UG")
        assert g2 == pytest.approx(2 * g1, rel=1e-6)

    @given(w=w_strat, n=st.integers(2, 64))
    @settings(max_examples=30, deadline=None)
    def test_schedule_never_slower_than_critical_lower_bound(self, w, n):
        """Overlapped schedule >= max single-resource time (can't beat the
        bottleneck resource) and <= sequential sum."""
        cfg = get_config("qwen3-8b")
        nano = autosearch(cfg, w, cm.TPU_V5E, n, bdense=2048)
        seq = sequential_schedule(cfg, w, cm.TPU_V5E, n, bdense=2048)
        assert nano.iter_time <= seq.iter_time * 1.001
        per_kind = {}
        for node in nano.pipeline.nodes.values():
            per_kind[node.kind] = per_kind.get(node.kind, 0.0) + node.work
        assert nano.iter_time >= max(per_kind.values()) * 0.999


class TestKVDtype:
    """Dtype-aware KV byte terms (DESIGN.md §15)."""

    def test_int8_doubles_kv_capacity(self):
        cfg = get_config("llama2-70b")            # head_dim 128, GQA
        ms_bf = cm.model_stats(cfg)
        ms_i8 = cm.model_stats(cfg, "int8")
        assert ms_i8.kv_per_token == ms_bf.kv_per_token
        # 1 B/elem + f32 scale per (row, kv-head): 1 + 4/128 vs 2 bytes
        assert ms_i8.kv_bytes_per_elem < 0.52 * ms_bf.kv_bytes_per_elem
        e_bf = cm.e_kv(cm.A100_80G, ms_bf, 8)
        e_i8 = cm.e_kv(cm.A100_80G, ms_i8, 8)
        assert e_i8 >= 1.9 * e_bf                 # ~2x resident elements
        # bigger resident batch at the same byte budget
        w = cm.Workload(512, 1024)
        assert cm.b_req(cm.A100_80G, ms_i8, w, 8) >= \
            1.9 * cm.b_req(cm.A100_80G, ms_bf, w, 8)

    def test_decode_attention_bytes_track_storage_rate(self):
        cfg = get_config("llama2-70b")
        w = cm.Workload(512, 1024)
        row = lambda rows, name: next(r for r in rows if r["op"] == name)
        # pin bdense: without it the int8 run's bigger b_req inflates every
        # dense term too, which is real but not what this test isolates
        t_bf = cm.table2(cfg, w, cm.A100_80G, 8, bdense=2048)
        t_i8 = cm.table2(cfg, w, cm.A100_80G, 8, bdense=2048,
                         kv_dtype="int8")
        bf = row(t_bf, "DecodeAttention")["mem_gb"]
        i8 = row(t_i8, "DecodeAttention")["mem_gb"]
        # ~2x the elements at ~half the bytes each: byte term ~unchanged
        assert bf * 0.9 <= i8 <= bf * 1.1
        # dense GEMM terms don't see the cache dtype
        assert row(t_bf, "GEMM-O")["mem_gb"] == \
            pytest.approx(row(t_i8, "GEMM-O")["mem_gb"])
