"""Optional-hypothesis shim.

``hypothesis`` is a declared dev dependency (pyproject ``[dev]``; CI installs
it), but test *collection* must never hard-fail without it — property-based
tests skip cleanly instead.  Import from here rather than from ``hypothesis``
directly:

    from _hyp import given, settings, st

When hypothesis is missing, ``given`` replaces the test with a zero-argument
function that calls ``pytest.skip`` (a plain ``pytest.importorskip`` at
module scope would skip the module's non-property tests too, which this shim
keeps runnable).
"""
try:
    from hypothesis import given, settings  # noqa: F401
    from hypothesis import strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:  # pragma: no cover - exercised only without dep
    import pytest

    HAVE_HYPOTHESIS = False

    class _Strategies:
        """Stand-in for ``hypothesis.strategies``: any strategy constructor
        returns an inert placeholder (never executed — the test skips)."""

        def __getattr__(self, name):
            return lambda *a, **k: None

    st = _Strategies()

    def settings(*a, **k):
        return lambda fn: fn

    def given(*a, **k):
        def deco(fn):
            # *args absorbs ``self`` for test methods; no named parameters,
            # so pytest resolves no fixtures before the skip fires
            def _skipped(*_args):
                pytest.skip("hypothesis not installed (pyproject [dev] dep)")
            _skipped.__name__ = fn.__name__
            _skipped.__doc__ = fn.__doc__
            return _skipped
        return deco
