"""Nano-batching semantics: any split plan preserves op outputs exactly
(the paper's correctness requirement for intra-device parallelism)."""
import jax
import jax.numpy as jnp
import numpy as np
from _hyp import given, settings, st  # optional-hypothesis shim

from repro.core.nanobatch import (NanoBatchPlan, interleaved_apply, merge,
                                  nano_batch_sizes_for, split)
from repro.core.pipeline import build_nanoflow_pipeline, sequential_pipeline
from repro.core import autosearch as asrch


@given(total=st.integers(1, 512), n=st.integers(1, 8))
@settings(max_examples=100, deadline=None)
def test_even_plan_partitions(total, n):
    plan = NanoBatchPlan.even(total, n)
    assert sum(plan.sizes) == total
    assert all(s > 0 for s in plan.sizes)
    assert len(plan.sizes) <= n


@given(total=st.integers(8, 4096), n=st.integers(1, 8),
       mult=st.sampled_from([8, 16, 64]))
@settings(max_examples=100, deadline=None)
def test_discrete_nano_sizes(total, n, mult):
    plan = nano_batch_sizes_for(total, n, multiple_of=mult)
    assert sum(plan.sizes) == total
    # all but the ragged tail are hardware-friendly multiples
    for s in plan.sizes[:-1]:
        assert s % mult == 0


@given(rows=st.integers(1, 64), n=st.integers(1, 6))
@settings(max_examples=50, deadline=None)
def test_split_merge_roundtrip(rows, n):
    x = jnp.arange(rows * 3, dtype=jnp.float32).reshape(rows, 3)
    plan = NanoBatchPlan.even(rows, n)
    assert np.array_equal(np.asarray(merge(split(x, plan))), np.asarray(x))


@given(rows=st.integers(2, 64), n=st.integers(1, 4))
@settings(max_examples=25, deadline=None)
def test_interleaved_apply_semantics_preserving(rows, n):
    """Figure-6 interleave == unsplit compute∘network composition."""
    w1 = jnp.asarray(np.random.default_rng(0).normal(size=(8, 8)), jnp.float32)
    x = jnp.asarray(np.random.default_rng(1).normal(size=(rows, 8)),
                    jnp.float32)
    com = lambda c: jnp.tanh(c @ w1)
    net = lambda c: c * 2.0 + 1.0      # stand-in for a collective
    plan = NanoBatchPlan.even(rows, n)
    out = interleaved_apply(com, net, x, plan)
    want = net(com(x))
    # row-split GEMMs may take a different accumulation path (GEMV): allow ulps
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-5,
                               atol=1e-6)


def test_pipeline_critical_path_and_units():
    prof = {"KQV": ("compute", 1.0), "GEMV": ("memory", 2.0),
            "PF": ("compute", 0.2), "O": ("compute", 0.8),
            "UGD": ("compute", 3.0), "AG": ("network", 0.5),
            "AR": ("network", 1.0)}
    pipe = build_nanoflow_pipeline(prof)
    t, path = pipe.critical_path()
    assert t > 0 and path[0].startswith("KQV")
    seq = sequential_pipeline(prof)
    t_seq, _ = seq.critical_path()
    assert t_seq >= sum(v for _, v in prof.values()) * 0.99


def test_autosearch_unit_and_bandwidth_budgets_respected():
    from repro.configs import get_config
    from repro.core import costmodel as cm
    sched = asrch.autosearch(get_config("qwen3-8b"), cm.Workload(512, 1024),
                             cm.TPU_V5E, 256, bdense=2048)
    nodes = list(sched.pipeline.nodes.values())
    events = sorted({n.start for n in nodes} | {n.end for n in nodes})
    for t0 in events:
        # (a) total execution-unit budget
        units = sum(n.units for n in nodes if n.start <= t0 < n.end)
        assert units <= 1.0 + 1e-6, (t0, units)
        # (b) per-kind bandwidth
        for kind in ("compute", "memory", "network"):
            rate = sum(asrch.efficiency(n.kind, n.units) for n in nodes
                       if n.kind == kind and n.start <= t0 < n.end)
            assert rate <= 1.0 + 1e-6, (t0, kind, rate)
