"""Multi-replica router: load balance, straggler skew, failure re-dispatch."""
import pytest

from repro.serving.request import Request
from repro.serving.router import ReplicaHandle, Router


def _reqs(n, plen=32):
    return [Request(rid=i, prompt=list(range(plen)), max_new_tokens=8)
            for i in range(n)]


def test_balanced_dispatch():
    router = Router([ReplicaHandle(i) for i in range(4)])
    counts = [0] * 4
    for r in _reqs(40):
        counts[router.submit(r)] += 1
    assert max(counts) - min(counts) <= 2     # near-uniform under equal load


def test_straggler_gets_less():
    router = Router([ReplicaHandle(i) for i in range(4)], straggler_alpha=1.0)
    router.observe_step_times([1.0, 1.0, 1.0, 3.0])    # replica 3 slow
    counts = [0] * 4
    for r in _reqs(60):
        counts[router.submit(r)] += 1
    assert counts[3] == min(counts)
    assert counts[3] < sum(counts) / 4


def test_failure_redispatch():
    router = Router([ReplicaHandle(i) for i in range(3)])
    for r in _reqs(12):
        router.submit(r)
    before = sum(len(rep.assigned) for rep in router.replicas)
    moved = router.mark_failed(1)
    assert router.n_alive == 2
    assert all(not router.replicas[1].assigned for _ in [0])
    after = sum(len(rep.assigned) for rep in router.replicas if rep.alive)
    assert after == before                    # nothing lost
    assert router.redispatched == len(moved) > 0
    # further submissions avoid the dead replica
    for r in _reqs(6):
        assert router.submit(r) != 1


def test_no_live_replicas_raises():
    router = Router([ReplicaHandle(0)])
    router.mark_failed(0)
    with pytest.raises(RuntimeError):
        router.submit(Request(rid=99, prompt=[1, 2], max_new_tokens=2))


def test_dead_replica_never_selected_backlog_reenters_once():
    """Satellite: alive=False is terminal for selection, and the dead
    replica's backlog re-enters the dispatch path exactly once — a second
    retirement finds nothing to move."""
    router = Router([ReplicaHandle(i) for i in range(3)])
    for r in _reqs(12):
        router.submit(r)
    moved = router.mark_failed(1)
    assert len(moved) == len({r.rid for r in moved}) > 0
    assert router.replicas[1].stats().alive is False
    assert router.mark_failed(1) == []            # exactly once
    assert router.redispatched == len(moved)
    for r in _reqs(50):
        assert router.submit(r) != 1


def test_orphans_park_in_pending_when_no_live_replica():
    """A failure with no survivors parks the backlog instead of dropping
    it; a joining replica drains the parked queue."""
    router = Router([ReplicaHandle(0)])
    reqs = _reqs(5)
    for r in reqs:
        router.submit(r)
    moved = router.mark_failed(0)
    assert len(router.pending) == len(moved) == 5  # parked, not lost
    router.add_replica(ReplicaHandle(1))
    assert not router.pending
    assert len(router.replicas[1].assigned) == 5


def test_session_affinity_sticks_until_failure():
    router = Router([ReplicaHandle(i) for i in range(3)])
    first = router.submit(Request(rid=0, prompt=[1] * 8, max_new_tokens=2,
                                  session=42))
    # pile unrelated load elsewhere -> affinity must still win
    for r in _reqs(9):
        router.submit(r)
    again = router.submit(Request(rid=100, prompt=[1] * 8, max_new_tokens=2,
                                  session=42))
    assert again == first
    router.mark_failed(first)
    rebound = router.submit(Request(rid=101, prompt=[1] * 8,
                                    max_new_tokens=2, session=42))
    assert rebound != first


def test_engine_backed_stats_count_inflight_tokens():
    """Satellite: ReplicaStats for an engine-backed handle must include
    launched-but-uncommitted tokens — at async depth 1 a replica whose
    every sample is in flight is busy, not idle."""
    import dataclasses as _dc

    import jax as _jax

    from repro.configs import get_config as _get
    from repro.models import model as _model
    from repro.serving.config import EngineConfig as _EC
    from repro.serving.engine import ServeEngine as _SE

    cfg = _dc.replace(_get("tiny-toy"), dtype="float32")
    params = _model.init(cfg, _jax.random.PRNGKey(0))
    eng = _SE(cfg, params, _EC(max_slots=2, max_len=32, kv_block_size=8,
                               discrete_sizes=(8,), async_depth=1,
                               avg_decode_len=4.0))
    handle = ReplicaHandle(0, eng)
    handle.submit(Request(rid=0, prompt=[1, 2, 3, 4, 5, 6, 7, 8],
                          max_new_tokens=4))
    # step until the prompt is fully launched and a decode token is in
    # flight (depth 1: launched, not yet committed)
    for _ in range(8):
        plan = eng.scheduler.plan()
        if plan is None:
            break
        eng.step(plan)
        st = handle.stats()
        if st.inflight_tokens > 0:
            break
    st = handle.stats()
    assert st.inflight_tokens > 0, "in-flight work invisible to the router"
    assert st.backlog_tokens >= st.inflight_tokens
    eng.drain()
    assert handle.stats().inflight_tokens == 0
