"""Multi-replica router: load balance, straggler skew, failure re-dispatch."""
import pytest

from repro.serving.request import Request
from repro.serving.router import ReplicaHandle, Router


def _reqs(n, plen=32):
    return [Request(rid=i, prompt=list(range(plen)), max_new_tokens=8)
            for i in range(n)]


def test_balanced_dispatch():
    router = Router([ReplicaHandle(i) for i in range(4)])
    counts = [0] * 4
    for r in _reqs(40):
        counts[router.submit(r)] += 1
    assert max(counts) - min(counts) <= 2     # near-uniform under equal load


def test_straggler_gets_less():
    router = Router([ReplicaHandle(i) for i in range(4)], straggler_alpha=1.0)
    router.observe_step_times([1.0, 1.0, 1.0, 3.0])    # replica 3 slow
    counts = [0] * 4
    for r in _reqs(60):
        counts[router.submit(r)] += 1
    assert counts[3] == min(counts)
    assert counts[3] < sum(counts) / 4


def test_failure_redispatch():
    router = Router([ReplicaHandle(i) for i in range(3)])
    for r in _reqs(12):
        router.submit(r)
    before = sum(len(rep.assigned) for rep in router.replicas)
    moved = router.mark_failed(1)
    assert router.n_alive == 2
    assert all(not router.replicas[1].assigned for _ in [0])
    after = sum(len(rep.assigned) for rep in router.replicas if rep.alive)
    assert after == before                    # nothing lost
    assert router.redispatched == len(moved) > 0
    # further submissions avoid the dead replica
    for r in _reqs(6):
        assert router.submit(r) != 1


def test_no_live_replicas_raises():
    router = Router([ReplicaHandle(0)])
    router.mark_failed(0)
    with pytest.raises(RuntimeError):
        router.submit(Request(rid=99, prompt=[1, 2], max_new_tokens=2))
