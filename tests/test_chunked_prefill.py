"""Incremental chunked prefill (DESIGN.md §7).

Covers the tentpole invariants:
  * chunked == unchunked prefill across every mixer family (GQA attention,
    MLA, Mamba SSM, mLSTM/sLSTM, audio frontend) for chunk sizes below and
    above the conv kernel;
  * the ``forward_full(initial_states=...)`` carry path matches too;
  * linear work: a p-token prompt prefilled in k chunks executes exactly p
    model token-positions (the recompute path strictly more);
  * the engine's jitted bucketed path: greedy-exact vs naive decoding on the
    attention toy, mode-equivalent (incremental vs recompute) on recurrent /
    MoE archs, through slot reuse;
  * the scheduler only emits bucketed chunk lengths.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, scale_down
from repro.configs.base import ATTN
from repro.models import model
from repro.serving.engine import ServeEngine
from repro.serving.kvcache import PagedKVManager
from repro.serving.request import Request
from repro.serving.scheduler import GlobalBatchScheduler

# one arch per mixer family (smoke-scaled): GQA, MLA+MoE, Mamba-hybrid+MoE,
# mLSTM/sLSTM, audio frontend
FAMILIES = ["tiny-toy", "deepseek-v2-236b", "jamba-1.5-large-398b",
            "xlstm-1.3b", "musicgen-medium"]


def _cfg(name):
    cfg = get_config(name) if name == "tiny-toy" else scale_down(
        get_config(name))
    if cfg.moe is not None:
        # dropless so prefill/decode paths route identically (capacity drops
        # legitimately differ between batched shapes)
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, capacity_factor=16.0))
    return cfg


def _tokens(cfg, key, b, s):
    if cfg.frontend == "audio":
        return jax.random.randint(key, (b, s, cfg.num_codebooks), 0,
                                  cfg.vocab_size)
    return jax.random.randint(key, (b, s), 0, cfg.vocab_size)


@pytest.fixture(scope="module", params=FAMILIES)
def family(request):
    cfg = _cfg(request.param)
    params = model.init(cfg, jax.random.PRNGKey(0))
    return cfg, params


# chunk 1 exercises chunks shorter than the conv kernel (d_conv - 1 == 3
# history rows); 5 a ragged split; 12 the unchunked degenerate case
@pytest.mark.parametrize("chunk", [1, 5, 12])
def test_forward_chunk_matches_full(family, chunk):
    cfg, params = family
    b, s = 2, 12
    toks = _tokens(cfg, jax.random.PRNGKey(2), b, s)
    full, _ = model.forward_full(cfg, params, toks)

    cache = model.init_cache(cfg, 1, b, s + 2)
    clen = jnp.zeros((b,), jnp.int32)
    outs, off = [], 0
    while off < s:
        length = min(chunk, s - off)
        lg, cache = model.forward_chunk(cfg, params, toks[:, off:off + length],
                                        cache, clen)
        outs.append(lg)
        off += length
        clen = jnp.full((b,), off, jnp.int32)
    got = jnp.concatenate(outs, axis=1)
    err = float(jnp.abs(got.astype(jnp.float32)
                        - full.astype(jnp.float32)).max())
    scale = float(jnp.abs(full.astype(jnp.float32)).max()) + 1e-6
    assert err <= max(0.02 * scale, 1e-4), (cfg.name, chunk, err, scale)


def test_forward_chunk_then_decode_matches_prefill(family):
    """Decode from a chunk-built cache == decode from the one-shot prefill
    cache (the engine's handoff invariant)."""
    cfg, params = family
    b, s = 2, 10
    toks = _tokens(cfg, jax.random.PRNGKey(3), b, s)

    cache = model.init_cache(cfg, 1, b, s)
    clen = jnp.zeros((b,), jnp.int32)
    off = 0
    while off < s - 1:
        length = min(4, s - 1 - off)
        _, cache = model.forward_chunk(cfg, params, toks[:, off:off + length],
                                       cache, clen)
        off += length
        clen = jnp.full((b,), off, jnp.int32)
    dec_c, _ = model.forward_decode(cfg, params, toks[:, s - 1: s], cache,
                                    clen)

    _, cache_p, clen_p = model.prefill(cfg, params, toks[:, : s - 1],
                                       max_len=s)
    dec_p, _ = model.forward_decode(cfg, params, toks[:, s - 1: s], cache_p,
                                    clen_p)
    err = float(jnp.abs(dec_c.astype(jnp.float32)
                        - dec_p.astype(jnp.float32)).max())
    scale = float(jnp.abs(dec_p.astype(jnp.float32)).max()) + 1e-6
    assert err <= max(0.02 * scale, 1e-4), (cfg.name, err, scale)


def test_forward_full_initial_states_carry(family):
    """The reference (non-bucketed) carry path: chain forward_full chunks
    via initial_states/q_offset, accumulating attention prefixes."""
    cfg, params = family
    b, s, ch = 2, 12, 5
    toks = _tokens(cfg, jax.random.PRNGKey(4), b, s)
    full, _ = model.forward_full(cfg, params, toks)

    outs, states, off = [], None, 0
    while off < s:
        length = min(ch, s - off)
        lg, _aux, new_states = model.forward_full(
            cfg, params, toks[:, off:off + length], q_offset=off,
            initial_states=states, return_states=True)
        outs.append(lg)
        if states is None:
            states = new_states
        else:
            merged = []
            for gi, (pattern, reps) in enumerate(cfg.layer_groups()):
                g = {}
                for i, spec in enumerate(pattern):
                    old = states[gi][f"sub{i}"]
                    new = new_states[gi][f"sub{i}"]
                    if spec.mixer == ATTN:   # prefix KV accumulates
                        g[f"sub{i}"] = {"kv": tuple(
                            jnp.concatenate([o, n], axis=2)
                            for o, n in zip(old["kv"], new["kv"]))}
                    else:                    # recurrent state replaces
                        g[f"sub{i}"] = new
                merged.append(g)
            states = merged
        off += length
    got = jnp.concatenate(outs, axis=1)
    err = float(jnp.abs(got.astype(jnp.float32)
                        - full.astype(jnp.float32)).max())
    scale = float(jnp.abs(full.astype(jnp.float32)).max()) + 1e-6
    assert err <= max(0.02 * scale, 1e-4), (cfg.name, err, scale)


# ---------------------------------------------------------------------------
# engine: linear work + correctness through the jitted bucketed path
# ---------------------------------------------------------------------------
def test_engine_prefill_work_is_linear():
    """Acceptance criterion: a 512-token prompt prefilled in 64-token chunks
    executes exactly 512 model token-positions — the same count as one
    unchunked prefill."""
    cfg = get_config("tiny-toy")
    params = model.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompt = list(rng.integers(0, cfg.vocab_size, size=512))
    eng = ServeEngine(cfg, params, max_slots=2, max_len=520,
                      discrete_sizes=(64,), avg_decode_len=2)
    eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=2))
    done = eng.run()
    assert len(done) == 1
    st = eng.stats
    assert st.prefill_tokens == 512
    assert st.prefill_model_tokens == 512          # == one unchunked prefill
    assert st.prefill_expansion == 1.0
    # and it really was chunked: 512/64 prefill iterations at least
    assert st.iterations >= 8


def test_recompute_mode_is_superlinear():
    """The legacy recompute path documents the O(p²/chunk) behaviour the
    incremental path removes."""
    cfg = get_config("tiny-toy")
    params = model.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompt = list(rng.integers(0, cfg.vocab_size, size=64))
    eng = ServeEngine(cfg, params, max_slots=2, max_len=96,
                      discrete_sizes=(16,), avg_decode_len=2,
                      prefill_mode="recompute")
    eng.submit(Request(rid=0, prompt=prompt, max_new_tokens=2))
    eng.run()
    st = eng.stats
    assert st.prefill_tokens == 64
    # 16+32+48+64 = 160 model token-positions for a 64-token prompt
    assert st.prefill_model_tokens == 160
    assert st.prefill_expansion > 1.0


def test_engine_incremental_matches_naive_greedy():
    """End-to-end: jitted bucketed chunked prefill + decode == token-by-token
    full recomputation (attention toy; exact argmax equality)."""
    cfg = get_config("tiny-toy")
    params = model.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(0, cfg.vocab_size,
                                 size=int(rng.integers(3, 20))))
               for _ in range(5)]
    eng = ServeEngine(cfg, params, max_slots=2, max_len=64,
                      discrete_sizes=(8,), avg_decode_len=4)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=list(p), max_new_tokens=4))
    done = eng.run()
    assert len(done) == len(prompts)
    assert eng.stats.prefill_expansion == 1.0
    for r in done:
        toks = list(prompts[r.rid])
        want = []
        for _ in range(r.max_new_tokens):
            logits, _ = model.forward_full(
                cfg, params, jnp.asarray(toks, jnp.int32)[None])
            t = int(np.argmax(np.asarray(logits[0, -1])))
            want.append(t)
            toks.append(t)
        assert r.output == want, (r.rid, r.output, want)


@pytest.mark.parametrize("arch", ["deepseek-v2-236b", "jamba-1.5-large-398b",
                                  "xlstm-1.3b"])
def test_engine_modes_agree_with_slot_reuse(arch):
    """Incremental == recompute engine outputs on MLA/SSM/xLSTM archs, with
    more requests than slots so slots get reused (state reset path).

    Both modes pin ``step_mode="legacy"`` so this stays the §7 prefill A/B
    it always was (recompute implies the legacy step; running incremental
    through the packed step would compare different bf16 accumulation
    orders instead — packed-vs-legacy equivalence is covered in f32 by
    tests/test_packed_step.py)."""
    cfg = _cfg(arch)
    params = model.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    prompts = [list(rng.integers(0, cfg.vocab_size,
                                 size=int(rng.integers(3, 12))))
               for _ in range(5)]
    outs = {}
    for mode in ("incremental", "recompute"):
        eng = ServeEngine(cfg, params, max_slots=2, max_len=48,
                          discrete_sizes=(16, 8), avg_decode_len=4,
                          prefill_mode=mode, step_mode="legacy")
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=list(p), max_new_tokens=3))
        done = eng.run()
        assert len(done) == len(prompts)
        outs[mode] = {r.rid: r.output for r in done}
    assert outs["incremental"] == outs["recompute"]


@pytest.mark.parametrize("variant", ["flash_attention_ref",
                                     "flash_attention_fast",
                                     "flash_attention_stream"])
def test_ref_attention_per_row_q_offset(variant):
    """The ref kernels accept per-row (B,) q_offsets (different slots sit at
    different prefix depths) — equal to row-by-row scalar offsets."""
    from repro.kernels import ref
    fn = getattr(ref, variant)
    rng = np.random.default_rng(0)
    b, sq, skv, h, kv, d = 3, 4, 16, 4, 2, 8
    q = jnp.asarray(rng.normal(size=(b, sq, h, d)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(b, skv, kv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(b, skv, kv, d)), jnp.float32)
    offs = jnp.asarray([0, 3, 7], jnp.int32)
    batched = fn(q, k, v, causal=True, q_offset=offs)
    rows = [fn(q[i:i + 1], k[i:i + 1], v[i:i + 1], causal=True,
               q_offset=int(offs[i])) for i in range(b)]
    np.testing.assert_allclose(np.asarray(batched),
                               np.asarray(jnp.concatenate(rows)), atol=1e-6)


# the second size set has its smallest discrete size above the default
# prefill_chunk_min — the scheduler must still keep every non-terminal chunk
# bucketed (chunk_min is floored at the smallest size)
@pytest.mark.parametrize("sizes", [(64, 32, 16, 8), (64, 32, 16)])
def test_scheduler_quantizes_chunk_lengths(sizes):
    """Chunk lengths come from the discrete set (plus exact sub-minimum
    terminal remainders), bounding the jit compile cache."""
    kv = PagedKVManager(total_pages=1024, page_size=16, bytes_per_token=64,
                        avg_decode_len=8)
    sched = GlobalBatchScheduler(kv, discrete_sizes=sizes, max_active=8)
    rng = np.random.default_rng(2)
    for i in range(6):
        sched.submit(Request(rid=i,
                             prompt=list(range(int(rng.integers(3, 150)))),
                             max_new_tokens=1))
    seen = set()
    for _ in range(100):
        plan = sched.plan()
        if plan is None:
            break
        assert plan.dense_tokens <= plan.dense_batch
        sampled = {}
        for c in plan.prefill:
            seen.add(c.length)
            # bucketed, or a terminal remainder below the smallest size
            assert c.length in sizes or (
                c.length < min(sizes)
                and c.offset + c.length == c.req.prompt_len), c.length
            if c.offset + c.length == c.req.prompt_len:
                sampled[c.req.rid] = 0
        for r in plan.decode:
            sampled[r.rid] = 0
        sched.commit(plan, sampled, 0.0)
    assert seen, "no prefill chunks emitted"
