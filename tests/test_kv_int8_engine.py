"""int8 KV-cache serving, engine end-to-end (DESIGN.md §15).

The quantized engine must be a drop-in: same packed step (1 dispatch +
1 sync per iteration, same compile-cache bound), same scheduler, same
block-table/prefix/spec-decode machinery — only the attention cache leaves
change (int8 values + f32 per-(row, kv-head) scales).  Covered here:

  * greedy token-match vs the native-dtype engine on a short-horizon mixed
    workload (f32 configs; int8 rounding can flip near-ties on random-init
    toy weights, so the workload seed is pinned to one with clear margins),
    GQA and absorbed-MLA families, async depth 0 and 1;
  * teacher-forced logit drift vs the native cache stays under the
    per-family bound;
  * a fixed ``kv_budget_bytes`` admits ~2x the pages (>= 1.9x at
    head_dim 128 — the acceptance criterion);
  * composition with prefix caching and speculative decoding;
  * the ``kv_quant_bytes_saved`` counter and config validation.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, scale_down
from repro.models import model
from repro.serving.config import EngineConfig
from repro.serving.engine import ServeEngine, kv_bytes_per_token
from repro.serving.request import Request

SIZES = (16, 8)
FAMILIES = ["tiny-toy", "deepseek-v2-236b"]      # GQA / absorbed MLA (+MoE)
# max teacher-forced logit drift vs the native cache (f32 toy weights;
# symmetric int8 rounds each K/V row to ~0.4% of its max)
DRIFT_BOUND = {"tiny-toy": 0.08, "deepseek-v2-236b": 0.08}


def _cfg(name):
    cfg = get_config(name) if name == "tiny-toy" else scale_down(
        get_config(name))
    if cfg.moe is not None:
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, capacity_factor=16.0))
    return dataclasses.replace(cfg, dtype="float32")


@pytest.fixture(scope="module", params=FAMILIES)
def family(request):
    cfg = _cfg(request.param)
    params = model.init(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _run(cfg, params, kv_dtype, depth, **kw):
    eng = ServeEngine(cfg, params, EngineConfig(
        max_slots=2, max_len=48, kv_block_size=8, discrete_sizes=SIZES,
        avg_decode_len=4.0, async_depth=depth, kv_dtype=kv_dtype, **kw))
    rng = np.random.default_rng(1)               # pinned: clear-margin seed
    for i, n in enumerate([3, 11, 5, 9, 4]):
        eng.submit(Request(
            rid=i, prompt=list(map(int, rng.integers(0, cfg.vocab_size,
                                                     size=n))),
            max_new_tokens=4))
    done = eng.run()
    assert len(done) == 5
    return eng, {r.rid: tuple(r.output) for r in done}


@pytest.mark.parametrize("depth", [0, 1])
def test_int8_greedy_token_match(family, depth):
    cfg, params = family
    e_bf, out_bf = _run(cfg, params, "bf16", depth)
    e_i8, out_i8 = _run(cfg, params, "int8", depth)
    assert out_bf == out_i8, (cfg.name, depth)
    # still the single-dispatch packed step with a bounded compile cache
    assert e_i8.stats.dispatches_per_iter == 1.0
    assert e_i8.stats.syncs_per_iter == 1.0
    bound = (len(SIZES) + 1) * len(e_i8.kv_buckets)
    assert e_i8._packed_step._cache_size() <= bound
    # counters: quantized run banked real bytes, native run none
    assert e_i8.stats.kv_quant_bytes_saved > 0
    assert e_bf.stats.kv_quant_bytes_saved == 0
    assert e_i8.stats.snapshot()["kv_quant_bytes_saved"] > 0


def test_int8_logit_drift_bound(family):
    """Teacher-forced packed forward, native vs int8 cache: same tokens,
    same positions — the only difference is cache quantization."""
    cfg, params = family
    prompt = np.arange(1, 17, dtype=np.int32) % cfg.vocab_size
    t = len(prompt)
    tok = jnp.asarray(prompt)[None]
    pos = jnp.arange(t, dtype=jnp.int32)
    slot = jnp.zeros(t, jnp.int32)
    act = jnp.ones(t, jnp.int32)
    outs = {}
    for kd in (None, "int8"):
        cache = model.init_cache(cfg, 1, 2, 48, kd)
        logits, _ = model.forward_packed(cfg, params, tok, cache,
                                         slot, pos, pos, act, kv_bucket=48)
        outs[kd] = np.asarray(logits, np.float32)[0]
    drift = np.abs(outs[None] - outs["int8"]).max()
    assert drift < DRIFT_BOUND[cfg.name.replace("-smoke", "")], \
        (cfg.name, drift)


def test_int8_doubles_admitted_pages_at_fixed_budget():
    """Acceptance criterion: at the same ``kv_budget_bytes`` the int8
    engine admits >= 1.9x the pages (head_dim 128: the f32 scale adds
    4/128 B per element to the 1 B int8 value)."""
    cfg = dataclasses.replace(get_config("tiny-toy"), head_dim=128)
    assert cfg.dtype == "bfloat16"
    params = model.init(cfg, jax.random.PRNGKey(0))
    budget = kv_bytes_per_token(cfg) * 8 * 16    # 16 native pages of 8 rows
    engines = {}
    for kd in ("bf16", "int8"):
        engines[kd] = ServeEngine(cfg, params, EngineConfig(
            max_slots=4, max_len=64, kv_block_size=8, discrete_sizes=SIZES,
            avg_decode_len=4.0, kv_budget_bytes=budget, kv_dtype=kd))
    n_bf = engines["bf16"].kv.stats.device_pages_total
    n_i8 = engines["int8"].kv.stats.device_pages_total
    assert n_i8 >= 1.9 * n_bf, (n_bf, n_i8)
    # the rate the pool charges per token is the quantized one
    assert engines["int8"].kv.bytes_per_token == kv_bytes_per_token(
        cfg, "int8")
    assert engines["int8"].kv.bytes_per_token < \
        0.52 * engines["bf16"].kv.bytes_per_token


@pytest.mark.parametrize("depth", [0, 1])
def test_int8_composes_with_prefix_caching(depth):
    """Prefix caching shares int8 blocks byte-identically (hashes stay over
    token ids; CoW copies move (values, scales) pairs), so shared-prefix
    serving is token-exact vs the unshared int8 engine."""
    cfg = _cfg("tiny-toy")
    params = model.init(cfg, jax.random.PRNGKey(0))
    base = list(range(11, 21))

    def serve(prefix):
        eng = ServeEngine(cfg, params, EngineConfig(
            max_slots=4, max_len=64, kv_block_size=8, discrete_sizes=SIZES,
            avg_decode_len=4.0, async_depth=depth, prefix_caching=prefix,
            kv_dtype="int8"))
        outs = {}
        for wave in ([(0, base + [30])],
                     [(i, base + [30 + i]) for i in range(1, 4)]):
            for rid, prompt in wave:
                eng.submit(Request(rid=rid, prompt=list(prompt),
                                   max_new_tokens=6))
            for r in eng.run():
                outs[r.rid] = tuple(r.output)
        return eng, outs

    _, out_np = serve(False)
    eng, out_pc = serve(True)
    assert out_np == out_pc
    assert eng.kv.stats.prefix_hit_tokens == 30  # 3 requests x 10 tokens
    assert eng.kv.stats.cow_copies == 3
    assert eng.stats.dispatches_per_iter == 1.0


def test_int8_composes_with_spec_decode():
    """Speculative decoding's accept/rollback chain operates on positions,
    not bytes — rejected int8 rows (values + scales) just stay unattended —
    so spec_k > 0 keeps greedy exactness vs the plain int8 engine."""
    cfg = _cfg("tiny-toy")
    params = model.init(cfg, jax.random.PRNGKey(0))

    def serve(spec_k):
        eng = ServeEngine(cfg, params, EngineConfig(
            max_slots=2, max_len=64, kv_block_size=8, discrete_sizes=(24, 8),
            avg_decode_len=6.0, spec_k=spec_k, kv_dtype="int8"))
        rng = np.random.default_rng(1)
        for i in range(3):
            eng.submit(Request(
                rid=i, prompt=list(map(int, rng.integers(
                    0, cfg.vocab_size, size=int(rng.integers(3, 10))))),
                max_new_tokens=6))
        done = eng.run()
        return eng, {r.rid: tuple(r.output) for r in done}

    _, out0 = serve(0)
    eng, out2 = serve(2)
    assert out0 == out2
    assert eng.stats.spec_verify_segments > 0
    assert eng.stats.dispatches_per_iter == 1.0


def test_int8_requires_packed_step():
    with pytest.raises(AssertionError):
        EngineConfig(kv_dtype="int8", step_mode="legacy")
    with pytest.raises(AssertionError):
        EngineConfig(kv_dtype="fp8")             # unknown dtype tag
    assert EngineConfig(kv_dtype="int8").kv_dtype == "int8"
