"""Streaming (flash-style XLA) attention: allclose vs ref + property sweep."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hyp import given, settings, st  # optional-hypothesis shim

from repro.kernels.ref import flash_attention_ref, flash_attention_stream

RNG = np.random.default_rng(13)


@pytest.mark.parametrize("b,sq,skv,h,kv,d,causal,qoff,blk", [
    (1, 64, 64, 4, 2, 16, True, 0, 16),
    (2, 37, 53, 6, 2, 32, True, 16, 8),
    (1, 128, 128, 8, 8, 64, False, 0, 32),
    (1, 16, 96, 2, 1, 8, True, 80, 64),   # long cache, short q
])
def test_stream_matches_ref(b, sq, skv, h, kv, d, causal, qoff, blk):
    q = jnp.asarray(RNG.normal(size=(b, sq, h, d)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(b, skv, kv, d)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(b, skv, kv, d)), jnp.float32)
    o1 = flash_attention_stream(q, k, v, causal=causal, q_offset=qoff,
                                block=blk)
    o2 = flash_attention_ref(q, k, v, causal=causal, q_offset=qoff)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=1e-5,
                               atol=2e-5)


@given(sq=st.integers(1, 48), skv=st.integers(1, 80),
       blk=st.sampled_from([4, 16, 64]), seed=st.integers(0, 50))
@settings(max_examples=25, deadline=None)
def test_stream_property(sq, skv, blk, seed):
    rng = np.random.default_rng(seed)
    q = jnp.asarray(rng.normal(size=(1, sq, 2, 8)), jnp.float32)
    k = jnp.asarray(rng.normal(size=(1, skv, 2, 8)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(1, skv, 2, 8)), jnp.float32)
    # non-causal so q/k lengths are unconstrained
    o1 = flash_attention_stream(q, k, v, causal=False, block=blk)
    o2 = flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=1e-4,
                               atol=1e-4)


def test_stream_grad_matches_ref():
    q = jnp.asarray(RNG.normal(size=(1, 32, 2, 8)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(1, 32, 2, 8)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(1, 32, 2, 8)), jnp.float32)
    g1 = jax.grad(lambda q_: jnp.sum(
        flash_attention_stream(q_, k, v, block=8) ** 2))(q)
    g2 = jax.grad(lambda q_: jnp.sum(
        flash_attention_ref(q_, k, v) ** 2))(q)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), rtol=1e-4,
                               atol=1e-4)
