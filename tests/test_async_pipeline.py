"""Asynchronous iteration pipeline (DESIGN.md §10).

Covers the tentpole invariants:
  * ``async_depth=0`` is behavior-identical to the eager engine (same
    outputs, same dispatch/sync counts, nothing dropped);
  * ``async_depth>=1`` produces the same f32 outputs after EOS-strip as the
    eager engine across every mixer family (the device-resident
    ``last_token`` feedback + speculative planning change *when* results
    cross to the host, never *what* is computed);
  * lag-k EOS reconciliation: with harvesting disabled (worst-case lag) a
    depth-k engine launches up to k extra speculative tokens past EOS,
    commits drop the late ones (``scheduler.dropped_tokens``), and the
    finalized output still strips to EOS;
  * speculation never launches past ``max_new_tokens`` (launch-side cap);
  * the ``last_token`` buffer adds no trace axis — the packed-step compile
    cache stays ≤ (|T buckets| + 1) × |kv buckets|;
  * ``drain()`` retires everything (no sampled tokens left on device);
  * the scheduler's defensive bucket branches (``bucket_tokens`` overflow,
    ``bucket_kv`` saturation) and the size-only KV offload accounting
    satellites.

Engine A/Bs run in f32 (bf16 accumulation-order diffs flip MoE routing).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, scale_down
from repro.models import model
from repro.serving.engine import ServeEngine
from repro.serving.kvcache import PagedKVManager
from repro.serving.request import Request
from repro.serving.scheduler import GlobalBatchScheduler

FAMILIES = ["tiny-toy", "deepseek-v2-236b", "jamba-1.5-large-398b",
            "xlstm-1.3b"]


def _cfg(name):
    cfg = get_config(name) if name == "tiny-toy" else scale_down(
        get_config(name))
    if cfg.moe is not None:
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, capacity_factor=16.0))
    return dataclasses.replace(cfg, dtype="float32")


@pytest.fixture(scope="module", params=FAMILIES)
def family(request):
    cfg = _cfg(request.param)
    params = model.init(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _probe_eos(cfg, params, prompt):
    """A token the model actually emits (greedy continuation of ``prompt``)
    — submitting ``prompt`` with this as ``eos_id`` guarantees an EOS hit."""
    logits, _ = model.forward_full(cfg, params,
                                   jnp.asarray(prompt, jnp.int32)[None])
    return int(np.argmax(np.asarray(logits[0, -1])))


def _run(cfg, params, prompts, eos_id, **kwargs):
    eng = ServeEngine(cfg, params, max_slots=2, max_len=48,
                      discrete_sizes=(16, 8), avg_decode_len=4, **kwargs)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=i, prompt=list(p), max_new_tokens=4,
                           eos_id=eos_id))
    done = eng.run()
    assert len(done) == len(prompts)
    assert not eng._ring                      # drained on exit
    return eng, {r.rid: r.output for r in done}


def test_async_matches_eager_with_eos_strip(family):
    """Acceptance criterion: depth-1 pipelined outputs == eager outputs
    after EOS-strip, across mixer families, through slot reuse and an EOS
    hit mid-stream."""
    cfg, params = family
    rng = np.random.default_rng(0)
    prompts = [list(rng.integers(0, cfg.vocab_size,
                                 size=int(rng.integers(3, 12))))
               for _ in range(5)]
    eos = _probe_eos(cfg, params, prompts[0])
    eager, out0 = _run(cfg, params, prompts, eos, async_depth=0)
    asyn, out1 = _run(cfg, params, prompts, eos, async_depth=1)
    assert out0 == out1, cfg.name
    # rid 0 really exercised the EOS path (probe = its first greedy token)
    assert out0[0][-1] == eos
    # same per-iteration dispatch/sync discipline on both engines
    assert asyn.stats.model_dispatches == asyn.stats.iterations
    assert asyn.stats.host_syncs == asyn.stats.iterations


def test_depth0_is_bit_identical_lockstep():
    """async_depth=0 must behave exactly like the pre-§10 engine: one
    blocking retirement per iteration, launch state never leads committed
    state, nothing speculative, nothing dropped."""
    cfg = _cfg("tiny-toy")
    params = model.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(1)
    prompts = [list(rng.integers(0, cfg.vocab_size, size=9))
               for _ in range(4)]
    eng, _ = _run(cfg, params, prompts, eos_id=None, async_depth=0)
    assert eng.async_depth == 0
    assert eng.stats.model_dispatches == eng.stats.iterations
    assert eng.stats.host_syncs == eng.stats.iterations
    assert eng.scheduler.dropped_tokens == 0
    for r in eng.scheduler.active:
        assert r.inflight == 0                # fully reconciled


def test_lag_k_eos_overshoot_dropped_and_truncated():
    """Worst-case lag (harvesting off): a depth-k engine keeps planning
    through the EOS-bearing in-flight window, launching up to k extra
    speculative tokens; the §5.3 one extra is kept-then-stripped, the late
    ones are dropped at commit, and slots/KV pages are retired on the late
    EOS."""
    cfg = _cfg("tiny-toy")
    params = model.init(cfg, jax.random.PRNGKey(0))
    prompt = [5, 9, 11]
    eos = _probe_eos(cfg, params, prompt)

    outs = {}
    for depth in (0, 2, 3):
        eng = ServeEngine(cfg, params, max_slots=2, max_len=32,
                          discrete_sizes=(16, 8), avg_decode_len=4,
                          async_depth=depth, async_harvest=False)
        eng.submit(Request(rid=0, prompt=list(prompt), max_new_tokens=6,
                           eos_id=eos))
        done = eng.run()
        assert len(done) == 1
        outs[depth] = done[0].output
        assert done[0].output[-1] == eos      # stripped to the EOS token
        if depth == 0:
            assert eng.scheduler.dropped_tokens == 0
        else:
            # deterministic worst-case: EOS is the first sampled token, the
            # pipeline launches depth speculative decodes before its commit
            # lands; one is the §5.3 extra, depth-1 arrive late and drop
            assert eng.stats.decode_tokens == depth
            assert eng.scheduler.dropped_tokens == depth - 1
        # KV pages and the slot retired despite the late EOS
        assert eng.kv.pages_used == 0
        assert len(eng.slot_free) == 2
    assert outs[0] == outs[2] == outs[3]


def test_speculation_respects_max_new_tokens():
    """The launch-side cap (len(output) + inflight) keeps a deep pipeline
    from ever launching past max_new_tokens — no dropped tokens on a
    cap-finished request."""
    cfg = _cfg("tiny-toy")
    params = model.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(2)
    for depth in (2, 4):
        eng = ServeEngine(cfg, params, max_slots=2, max_len=32,
                          discrete_sizes=(16, 8), avg_decode_len=4,
                          async_depth=depth, async_harvest=False)
        eng.submit(Request(rid=0,
                           prompt=list(rng.integers(0, cfg.vocab_size,
                                                    size=7)),
                           max_new_tokens=3))
        done = eng.run()
        assert len(done[0].output) == 3
        assert eng.stats.decode_tokens == 2   # final prefill samples tok 1
        assert eng.scheduler.dropped_tokens == 0


def test_async_compile_cache_bound_unchanged():
    """The device-resident last_token buffer is a traced operand, not a
    trace axis: depth-1 and depth-0 engines compile the same program set,
    bounded by (|T buckets| + 1) × |kv buckets|."""
    cfg = get_config("tiny-toy")
    params = model.init(cfg, jax.random.PRNGKey(0))
    sizes = (32, 16, 8)

    def load(depth):
        eng = ServeEngine(cfg, params, max_slots=4, max_len=64,
                          discrete_sizes=sizes, avg_decode_len=4,
                          async_depth=depth)
        rng = np.random.default_rng(3)
        for i in range(8):
            eng.submit(Request(
                rid=i,
                prompt=list(rng.integers(0, cfg.vocab_size,
                                         size=int(rng.integers(3, 40)))),
                max_new_tokens=3))
        eng.run()
        return eng

    eager, asyn = load(0), load(1)
    bound = (len(sizes) + 1) * len(eager.kv_buckets)
    assert eager._packed_step._cache_size() <= bound
    assert asyn._packed_step._cache_size() == eager._packed_step._cache_size()


def test_async_eager_equivalence_smoke():
    """CI benchmark-smoke gate: tiny f32 config, async_depth 0 and 1
    produce identical outputs after EOS-strip."""
    cfg = _cfg("tiny-toy")
    params = model.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(4)
    prompts = [list(rng.integers(0, cfg.vocab_size, size=8))
               for _ in range(3)]
    eos = _probe_eos(cfg, params, prompts[0])
    _, out0 = _run(cfg, params, prompts, eos, async_depth=0)
    _, out1 = _run(cfg, params, prompts, eos, async_depth=1)
    assert out0 == out1


def test_lockstep_plan_commit_driver_makes_progress():
    """Direct plan()/commit() drivers (no engine, no mark_launched) must
    keep the pre-§10 contract: commit advances launch state so the next
    plan's chunks move forward instead of re-emitting offset 0 forever."""
    kv = PagedKVManager(total_pages=1024, page_size=16, bytes_per_token=64,
                        avg_decode_len=8)
    sched = GlobalBatchScheduler(kv, discrete_sizes=(64, 32, 16, 8),
                                 max_active=8)
    sched.submit(Request(rid=0, prompt=list(range(100)), max_new_tokens=2))
    iters = 0
    while (plan := sched.plan()) is not None:
        iters += 1
        assert iters < 50, "plan()/commit() livelocked"
        sampled = {}
        for c in plan.prefill:
            assert c.offset + c.length <= 100     # never past the prompt
            if c.offset + c.length == c.req.prompt_len:
                sampled[c.req.rid] = 0
        for r in plan.decode:
            sampled[r.rid] = 0
        sched.commit(plan, sampled, 0.0)
    assert sched.n_active == 0                    # ran to completion


# ---------------------------------------------------------------------------
# scheduler defensive-branch satellites
# ---------------------------------------------------------------------------
def _sched(**kw):
    kv = PagedKVManager(total_pages=1024, page_size=16, bytes_per_token=64,
                        avg_decode_len=8)
    return GlobalBatchScheduler(kv, **kw)


def test_bucket_tokens_overflow_rounds_to_next_multiple():
    """Tokens beyond the largest discrete size take the next multiple of it
    (defensive: no real plan should get there, but the launch shape must
    still cover the stream)."""
    sched = _sched(discrete_sizes=(16, 8), max_active=64)
    assert sched.bucket_tokens(16) == 16
    assert sched.bucket_tokens(17) == 32      # ceil(17/16) * 16
    assert sched.bucket_tokens(40) == 48
    assert sched.bucket_tokens(64) == 64


def test_bucket_tokens_max_active_floor():
    """max_active below the smallest discrete size joins the grid as a
    floor bucket (decode-only iterations never exceed it)."""
    sched = _sched(discrete_sizes=(16, 8), max_active=4)
    assert sched.bucket_tokens(3) == 4
    assert sched.bucket_tokens(4) == 4
    assert sched.bucket_tokens(5) == 8


def test_bucket_kv_saturates_at_grid_top():
    sched = _sched(discrete_sizes=(16, 8), max_active=8,
                   kv_buckets=(64, 128, 256))
    assert sched.bucket_kv(1) == 64
    assert sched.bucket_kv(64) == 64
    assert sched.bucket_kv(65) == 128
    assert sched.bucket_kv(256) == 256
    assert sched.bucket_kv(1000) == 256       # saturation: top of the grid
    with pytest.raises(AssertionError):
        _sched(discrete_sizes=(16, 8), max_active=8).bucket_kv(1)


# ---------------------------------------------------------------------------
# size-only KV offload accounting satellite
# ---------------------------------------------------------------------------
def test_offload_size_only_accounts_without_blob():
    kv = PagedKVManager(total_pages=32, page_size=8, bytes_per_token=64,
                        avg_decode_len=8)
    kv.allocate(1, 24)
    kv.offload(1, nbytes=24 * 64)
    assert kv.pages_used == 0                 # pages retired
    assert kv.stats.offload_bytes == 24 * 64
    assert kv.stats.host_bytes == 24 * 64
    assert kv.stats.aggregated_copies == 1
    # no data to restore: a miss that neither allocates nor drops the entry
    assert kv.upload(1, np.float32, (24 * 16,)) is None
    assert kv.pages_used == 0
    assert 1 in kv.host_pool


def test_reoffload_does_not_drift_host_bytes():
    """Re-offloading a rid whose entry is still pooled (the steady state
    for size-only entries — upload() never pops them) replaces the entry:
    host_bytes must not accumulate per round, or it drifts past capacity
    and the LRU loop evicts the whole pool forever."""
    kv = PagedKVManager(total_pages=32, page_size=8, bytes_per_token=64,
                        avg_decode_len=8, host_capacity_bytes=10_000)
    for _round in range(5):
        kv.allocate(1, 8)
        kv.offload(1, nbytes=400)
        assert kv.upload(1, np.float32, (100,)) is None   # size-only miss
    assert kv.stats.host_bytes == 400                     # one live entry
    assert kv.stats.offload_bytes == 5 * 400              # traffic counted
    assert kv.stats.discarded_requests == 0
    # real-blob replacement accounts the same way
    kv.allocate(1, 8)
    kv.offload(1, np.zeros(100, np.float32))
    assert kv.stats.host_bytes == 400


def test_offload_size_only_participates_in_lru():
    kv = PagedKVManager(total_pages=64, page_size=8, bytes_per_token=64,
                        avg_decode_len=8, host_capacity_bytes=1000)
    for rid in range(5):
        kv.allocate(rid, 8)
        kv.offload(rid, nbytes=400)
    assert kv.stats.host_bytes <= 1000
    assert kv.stats.discarded_requests == 5 - len(kv.host_pool)
    # mixed real/size-only entries evict coherently
    kv.allocate(10, 8)
    kv.offload(10, np.zeros(100, np.float32))          # real 400 B blob
    assert kv.stats.host_bytes <= 1000


def test_offload_requires_exactly_one_payload():
    kv = PagedKVManager(total_pages=8, page_size=8, bytes_per_token=64,
                        avg_decode_len=8)
    kv.allocate(1, 8)
    with pytest.raises(AssertionError):
        kv.offload(1)
    with pytest.raises(AssertionError):
        kv.offload(1, np.zeros(4, np.float32), nbytes=16)
