"""KV accounting bugfixes (ISSUE 5 satellites).

1. ``kv_bytes_per_token`` derives per-token KV bytes from the *actual*
   cache leaves: MLA configs cache only the latent ``c_kv + k_rope`` (the
   old GQA formula over-charged deepseek-style admission ~an order of
   magnitude) and attention-free SSM/xLSTM models cache nothing per token
   (the old ``max(n_attn, 1)`` floor charged O(1) recurrent state per-token
   paging).  ``ServeEngine(kv_budget_bytes=...)`` turns a device byte
   budget into pages through the corrected rate.

2. ``PagedKVManager.peak_pages`` counts launch-side state: with a
   pipelined engine (DESIGN.md §10) in-flight sampled tokens occupy cache
   rows before commit makes them visible, and a committed-only sweep lets
   admission overshoot the pool so ``extend`` fails at commit time.
"""
import dataclasses

import jax
import numpy as np

from repro.configs import get_config, scale_down
from repro.configs.base import ATTN
from repro.models import model
from repro.serving.engine import ServeEngine, kv_bytes_per_token
from repro.serving.kvcache import PagedKVManager
from repro.serving.request import Request, State


def _old_formula(cfg) -> int:
    """The pre-fix engine formula (engine.py:223-226 at PR 4)."""
    hd = cfg.resolved_head_dim
    n_attn = max(sum(1 for s in cfg.layer_specs() if s.mixer == ATTN), 1)
    return 2 * cfg.n_kv_heads * hd * 2 * n_attn


# ---------------------------------------------------------------------------
# bytes-per-token derivation
# ---------------------------------------------------------------------------
def test_gqa_bytes_match_cache_leaves():
    cfg = get_config("tiny-toy")          # bf16 GQA: formula was correct
    n_attn = sum(1 for s in cfg.layer_specs() if s.mixer == ATTN)
    want = 2 * cfg.n_kv_heads * cfg.resolved_head_dim * 2 * n_attn
    assert kv_bytes_per_token(cfg) == want == _old_formula(cfg)


def test_mla_bytes_are_latent_not_per_head():
    """The absorbed MLA path caches (kv_lora_rank + qk_rope_dim) per layer;
    the full deepseek-v2 config was over-charged ~28x (eval_shape only —
    no allocation)."""
    cfg = get_config("deepseek-v2-236b")
    m = cfg.mla
    n_attn = sum(1 for s in cfg.layer_specs() if s.mixer == ATTN)
    itemsize = np.dtype(np.float32).itemsize if cfg.dtype == "float32" else 2
    want = (m.kv_lora_rank + m.qk_rope_dim) * itemsize * n_attn
    got = kv_bytes_per_token(cfg)
    assert got == want, (got, want)
    assert _old_formula(cfg) / got > 10     # "~an order of magnitude"
    # the smoke config shows the same shape of error
    smoke = scale_down(cfg)
    assert _old_formula(smoke) / kv_bytes_per_token(smoke) > 4


def test_attention_free_models_charge_zero_per_token():
    cfg = get_config("xlstm-1.3b")
    assert kv_bytes_per_token(cfg) == 0
    assert _old_formula(cfg) > 0            # the old floor charged them


def test_kv_budget_admission_capacity_mla():
    """Same byte budget -> the corrected rate buys several times more pages
    (admission capacity) for the tiny MLA config (28x on the full one)."""
    cfg = scale_down(get_config("deepseek-v2-236b"))
    params = model.init(cfg, jax.random.PRNGKey(0))
    budget = 1 << 20
    eng = ServeEngine(cfg, params, max_slots=2, max_len=32, page_size=8,
                      kv_budget_bytes=budget, avg_decode_len=4)
    bpt = kv_bytes_per_token(cfg)
    assert eng.kv.bytes_per_token == bpt
    assert eng.kv.stats.device_pages_total == budget // (bpt * 8)
    old_pages = budget // (_old_formula(cfg) * 8)
    assert eng.kv.stats.device_pages_total > 4 * old_pages


def test_kv_budget_attention_free_falls_back_to_slot_capacity():
    """A byte budget can't bound an attention-free model (0 B/token): the
    engine falls back to the slot-capacity page pool and still serves."""
    cfg = dataclasses.replace(scale_down(get_config("xlstm-1.3b")),
                              dtype="float32")
    params = model.init(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_slots=2, max_len=32, page_size=8,
                      kv_budget_bytes=1024,      # tiny budget, irrelevant
                      discrete_sizes=(16, 8), avg_decode_len=4)
    assert eng.kv.bytes_per_token == 0
    assert eng.kv.stats.device_pages_total == 2 * 32 // 8
    eng.submit(Request(rid=0, prompt=[1, 2, 3, 4, 5], max_new_tokens=2))
    done = eng.run()
    assert len(done) == 1 and len(done[0].output) == 2


# ---------------------------------------------------------------------------
# launch-side peak-memory sweep
# ---------------------------------------------------------------------------
def _decoding_request(prompt_len, output_len, inflight, max_new=16):
    r = Request(rid=0, prompt=list(range(prompt_len)), max_new_tokens=max_new)
    r.state = State.DECODE
    r.prefill_done = r.prefill_launched = prompt_len
    r.output = list(range(output_len))
    r.inflight = inflight
    return r


def test_peak_pages_counts_inflight_tokens():
    """A request decoding past its predicted length with k tokens in flight
    occupies k rows the committed-only sweep missed: admission of a
    candidate must see them (harvesting off, depth >= 2 is exactly the
    state that produces inflight > 1)."""
    kv = PagedKVManager(total_pages=14, page_size=1, bytes_per_token=2,
                        avg_decode_len=1)
    r = _decoding_request(prompt_len=4, output_len=4, inflight=3)
    kv.allocate(r.rid, r.total_tokens)
    cand = Request(rid=1, prompt=list(range(4)), max_new_tokens=1)
    # launch view: r occupies 11 rows (8 committed + 3 in flight); cand
    # peaks at 5 -> 16 > 14: must NOT admit.  The committed-only sweep saw
    # 8 + 5 = 13 <= 14 and admitted -> extend failed at commit.
    assert kv.peak_pages([r], cand) > kv.stats.device_pages_total
    assert not kv.can_admit(cand, [r])
    r.inflight = 0
    assert kv.can_admit(cand, [r])          # committed-only view fits


class _CommittedOnlyKV(PagedKVManager):
    """The pre-fix estimator: launch-side state invisible to the sweep."""

    def peak_pages(self, active, candidate=None):
        stripped = []
        for r in list(active) + ([candidate] if candidate is not None else []):
            s = Request(rid=r.rid, prompt=list(r.prompt),
                        max_new_tokens=r.max_new_tokens)
            s.output = list(r.output)
            s.prefill_done = r.prefill_done
            stripped.append(s)
        return super().peak_pages(stripped)


def test_admission_never_overshoots_under_async_pipeline():
    """Engine regression, deterministic construction (harvesting off,
    depth 2): drive plan/step by hand until request A sits 2 sampled tokens
    past its *committed* state and past its predicted length
    (``avg_decode_len=1`` understates), then offer candidate B.  The
    committed-blind estimator admits B — pool 13 vs committed view
    8 + 4 — and A's in-flight commits later find their pages taken
    (``extend_failures > 0``).  The launch-side sweep sees A's 10
    launched rows + B's 4 > 13, defers B until A finishes, and every
    ``extend`` finds its page.  (Pool 13, not 12: commit reserves one
    row ahead per decode, so A alone peaks at prompt+max_new+1.)"""
    cfg = get_config("tiny-toy")
    params = model.init(cfg, jax.random.PRNGKey(0))

    def run(fixed: bool):
        eng = ServeEngine(cfg, params, max_slots=2, max_len=32, page_size=1,
                          total_pages=13, discrete_sizes=(8,),
                          avg_decode_len=1, async_depth=2,
                          async_harvest=False)
        if not fixed:
            eng.kv = _CommittedOnlyKV(
                total_pages=13, page_size=1,
                bytes_per_token=eng.kv.bytes_per_token, avg_decode_len=1)
            eng.scheduler.kv = eng.kv
        done = []
        eng.submit(Request(rid=0, prompt=[1, 2, 3, 4], max_new_tokens=8))
        # 6 iterations: prefill + 5 decode launches; with depth 2 and
        # harvesting off, commits lag by exactly 2 -> A has 4 committed
        # outputs (8 rows) and 2 in flight (rows 8, 9 already written)
        for _ in range(6):
            done += eng.step(eng.scheduler.plan())
        a = eng.scheduler.active[0]
        assert (a.total_tokens, a.inflight) == (8, 2)
        eng.submit(Request(rid=1, prompt=[5, 6, 7, 8], max_new_tokens=1))
        plan = eng.scheduler.plan()             # the admission decision
        assert plan is not None
        admitted_b = eng.scheduler.n_active == 2
        done += eng.step(plan)
        done += eng.run()
        assert len(done) == 2
        assert eng.kv.pages_used <= eng.kv.stats.device_pages_total
        return admitted_b, eng.kv.stats.extend_failures

    admitted, failures = run(fixed=False)
    assert admitted and failures > 0, \
        "scenario no longer reproduces the committed-blind overshoot"
    admitted, failures = run(fixed=True)
    assert not admitted and failures == 0


def test_extend_failure_counter():
    kv = PagedKVManager(total_pages=2, page_size=4, bytes_per_token=8,
                        avg_decode_len=4)
    assert kv.allocate(0, 8)                # both pages
    assert not kv.extend(0, 9)
    assert kv.stats.extend_failures == 1
