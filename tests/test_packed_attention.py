"""Packed-attention kernel + KV-length bucketing (DESIGN.md §9).

Covers the tentpole invariants:
  * Pallas (interpret=True) packed attention == the XLA ref across GQA and
    absorbed-MLA shapes (incl. ``d_v != d_qk``), f32 and bf16;
  * ``ops.packed_attention`` dispatches ``impl`` for real — the MLA
    ``d_v != d_qk`` case runs the Pallas kernel, no silent ref downgrade;
  * kv-bucket slicing is exact at and around bucket boundaries, in the ref,
    the kernel, and the scheduler's quantizer;
  * engine end-to-end: kv-bucketed packed step == dense max_len sweep ==
    legacy step (f32 per the known bf16-nondeterminism constraint), with a
    request crossing a bucket edge mid-decode;
  * the packed compile cache is bounded by |T buckets| × |kv buckets| and
    the kv-bucket histogram records what launched;
  * the §Perf-HC3 env toggles are now explicit engine arguments (env is
    only the construction-time fallback — no trace-time env reads).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, scale_down
from repro.kernels import ops, ref
from repro.kernels import packed_attention as pa
from repro.models import model
from repro.serving.engine import ServeEngine
from repro.serving.kvcache import PagedKVManager
from repro.serving.request import Request
from repro.serving.scheduler import GlobalBatchScheduler, default_kv_buckets

RNG = np.random.default_rng(7)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=1e-5, atol=2e-5)


def _case(t, n, s, h, kv, d_qk, d_v, dtype):
    q = jnp.asarray(RNG.normal(size=(t, h, d_qk)), dtype)
    k = jnp.asarray(RNG.normal(size=(n, s, kv, d_qk)), dtype)
    v = jnp.asarray(RNG.normal(size=(n, s, kv, d_v)), dtype)
    slot = jnp.asarray(RNG.integers(0, n, size=t), jnp.int32)
    lens = jnp.asarray(RNG.integers(1, s + 1, size=t), jnp.int32)
    return q, k, v, slot, lens


# ---------------------------------------------------------------------------
# kernel parity: pallas-interpret vs XLA ref
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("t,n,s,h,kv,d_qk,d_v", [
    (10, 3, 64, 4, 2, 32, 32),       # GQA
    (7, 2, 48, 8, 8, 16, 16),        # MHA
    (5, 4, 40, 4, 1, 16, 16),        # MQA, ragged S
])
def test_packed_attention_parity_gqa(t, n, s, h, kv, d_qk, d_v, dtype):
    q, k, v, slot, lens = _case(t, n, s, h, kv, d_qk, d_v, dtype)
    out = pa.packed_attention(q, k, v, slot, lens, block_k=16, interpret=True)
    want = ref.packed_attention_ref(q, k, v, slot, lens)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_packed_attention_parity_mla_dv_neq_dqk(dtype):
    """Absorbed MLA attends with d_qk = rank + rope but d_v = rank — the
    kernel must handle the mismatch (it used to silently fall back)."""
    t, n, s, h, d_qk, d_v = 6, 3, 48, 4, 24, 16
    q, k, v, slot, lens = _case(t, n, s, h, 1, d_qk, d_v, dtype)
    scale = d_qk ** -0.5
    out = pa.packed_attention(q, k, v, slot, lens, logit_scale=scale,
                              block_k=16, interpret=True)
    want = ref.packed_attention_ref(q, k, v, slot, lens, logit_scale=scale)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


def test_ops_dispatch_is_real(monkeypatch):
    """``ops.packed_attention(impl=...)`` routes to the Pallas kernel —
    including the ``d_v != d_qk`` case — instead of discarding ``impl``."""
    calls = []
    real = pa.packed_attention

    def spy(*args, **kwargs):
        calls.append(kwargs.get("interpret"))
        return real(*args, **kwargs)

    monkeypatch.setattr(pa, "packed_attention", spy)
    q, k, v, slot, lens = _case(5, 2, 32, 4, 1, 24, 16, jnp.float32)
    scale = 24 ** -0.5
    got = ops.packed_attention(q, k, v, slot, lens, logit_scale=scale,
                               impl="interpret")
    assert calls == [True]
    want = ops.packed_attention(q, k, v, slot, lens, logit_scale=scale,
                                impl="ref")
    assert calls == [True]                      # ref path never touches pa
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# int8 KV: in-register dequant parity (DESIGN.md §15)
# ---------------------------------------------------------------------------
def _quantize(k, v):
    from repro.serving import kvquant
    kq, ks = kvquant.quantize_kv(k)
    vq, vs = kvquant.quantize_kv(v)
    return kq, ks, vq, vs


@pytest.mark.parametrize("t,n,s,h,kv,d_qk,d_v", [
    (10, 3, 64, 4, 2, 32, 32),       # GQA
    (6, 3, 48, 4, 1, 24, 16),        # absorbed MLA: d_v != d_qk
])
def test_packed_attention_int8_parity(t, n, s, h, kv, d_qk, d_v):
    """Pallas kernel with int8 k/v + f32 scale tiles == ref dequant path
    (tight tol: both dequantize the same stored values), and both stay
    within the quantization-noise band of the unquantized oracle."""
    q, k, v, slot, lens = _case(t, n, s, h, kv, d_qk, d_v, jnp.float32)
    kq, ks, vq, vs = _quantize(k, v)
    scale = d_qk ** -0.5
    out = pa.packed_attention(q, kq, vq, slot, lens, k_scale=ks, v_scale=vs,
                              logit_scale=scale, block_k=16, interpret=True)
    want = ref.packed_attention_ref(q, kq, vq, slot, lens, k_scale=ks,
                                    v_scale=vs, logit_scale=scale)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=2e-5)
    exact = ref.packed_attention_ref(q, k, v, slot, lens, logit_scale=scale)
    err = float(jnp.abs(out - exact).max())
    assert err < 0.05 * float(jnp.abs(exact).max()) + 1e-6, err


def test_packed_attention_int8_kv_bucket():
    """Scale tiles ride the same kv_bucket slice as the values."""
    t, n, s, h, kv, d = 8, 3, 64, 4, 2, 16
    q, k, v, slot, _ = _case(t, n, s, h, kv, d, d, jnp.float32)
    lens = jnp.asarray(RNG.integers(1, 33, size=t), jnp.int32)
    kq, ks, vq, vs = _quantize(k, v)
    full = ref.packed_attention_ref(q, kq, vq, slot, lens,
                                    k_scale=ks, v_scale=vs)
    for impl_kw in (dict(), dict(kv_bucket=32)):
        got = pa.packed_attention(q, kq, vq, slot, lens, k_scale=ks,
                                  v_scale=vs, block_k=16, interpret=True,
                                  **impl_kw)
        np.testing.assert_allclose(np.asarray(got), np.asarray(full),
                                   rtol=1e-5, atol=2e-5)


def test_packed_attention_int8_block_tables():
    """Block-table mode: the scale tiles dereference the same physical
    block ids as the int8 value tiles (non-identity permutation)."""
    t, n, s, h, kv, d, bs = 6, 3, 32, 4, 2, 16, 8
    nb = s // bs
    q, k, v, slot, lens = _case(t, n, s, h, kv, d, d, jnp.float32)
    kq, ks, vq, vs = _quantize(k, v)
    # scatter logical blocks into a permuted physical row space
    perm = RNG.permutation(n * nb)
    tables = jnp.asarray(perm.reshape(n, nb), jnp.int32)
    flat = lambda x: x.reshape(n * nb, bs, *x.shape[2:])
    phys = lambda x: jnp.zeros_like(flat(x)).at[perm].set(flat(x)) \
        .reshape(x.shape)
    kq_p, vq_p, ks_p, vs_p = phys(kq), phys(vq), phys(ks), phys(vs)
    want = ref.packed_attention_ref(q, kq, vq, slot, lens,
                                    k_scale=ks, v_scale=vs)
    got_ref = ref.packed_attention_ref(q, kq_p, vq_p, slot, lens,
                                       block_tables=tables,
                                       k_scale=ks_p, v_scale=vs_p)
    got_pal = pa.packed_attention(q, kq_p, vq_p, slot, lens,
                                  block_tables=tables, k_scale=ks_p,
                                  v_scale=vs_p, interpret=True)
    np.testing.assert_allclose(np.asarray(got_ref), np.asarray(want),
                               rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(got_pal), np.asarray(want),
                               rtol=1e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# pad-free ragged last block (DESIGN.md §15): s % block_k != 0 masks the
# final tile in-kernel instead of jnp.pad-ing a copy of the whole cache
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("s,block_k", [(40, 16), (33, 16), (24, 16), (7, 8)])
def test_packed_attention_ragged_last_block(s, block_k):
    t, n, h, kv, d = 8, 3, 4, 2, 16
    q, k, v, slot, lens = _case(t, n, s, h, kv, d, d, jnp.float32)
    out = pa.packed_attention(q, k, v, slot, lens, block_k=block_k,
                              interpret=True)
    want = ref.packed_attention_ref(q, k, v, slot, lens)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=2e-5)


def test_packed_attention_no_cache_pad(monkeypatch):
    """The hot path never materializes a padded copy of the K/V caches.
    (Pallas *interpret mode* pads partial blocks internally — that's the
    simulator, not the lowered program — so only pads issued from our
    kernel module count.)"""
    import traceback
    calls = []
    real = jnp.pad

    def spy(*args, **kwargs):
        if any(pa.__file__ == f.filename
               for f in traceback.extract_stack()):
            calls.append(args[0].shape)
        return real(*args, **kwargs)

    monkeypatch.setattr(jnp, "pad", spy)
    # fresh shape -> jit re-traces with the spy active; any pad of the
    # cache would fire at trace time
    q, k, v, slot, lens = _case(9, 3, 41, 4, 2, 16, 16, jnp.float32)
    pa.packed_attention(q, k, v, slot, lens, block_k=16, interpret=True)
    assert calls == []


# ---------------------------------------------------------------------------
# kv-bucket correctness at bucket boundaries
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("bucket,max_lens", [
    (32, 32),        # every length exactly at the bucket edge
    (32, 31),        # strictly inside
    (64, 33),        # one past the previous bucket edge -> needs the next
])
def test_kv_bucket_slicing_exact(bucket, max_lens):
    t, n, s, h, kv, d = 8, 3, 64, 4, 2, 16
    q, k, v, slot, _ = _case(t, n, s, h, kv, d, d, jnp.float32)
    lens = jnp.asarray(RNG.integers(1, max_lens + 1, size=t)
                       .clip(max=max_lens), jnp.int32)
    lens = lens.at[0].set(max_lens)             # hit the boundary for sure
    full = ref.packed_attention_ref(q, k, v, slot, lens)
    sliced = ref.packed_attention_ref(q, k, v, slot, lens, kv_bucket=bucket)
    np.testing.assert_allclose(np.asarray(sliced), np.asarray(full),
                               rtol=1e-6, atol=1e-6)
    kern = pa.packed_attention(q, k, v, slot, lens, kv_bucket=bucket,
                               block_k=16, interpret=True)
    np.testing.assert_allclose(np.asarray(kern), np.asarray(full),
                               rtol=1e-5, atol=2e-5)


def test_scheduler_bucket_kv_boundaries():
    kvm = PagedKVManager(total_pages=64, page_size=8, bytes_per_token=64,
                         avg_decode_len=8)
    sched = GlobalBatchScheduler(kvm, discrete_sizes=(16, 8), max_active=8,
                                 kv_buckets=(32, 64, 128))
    assert sched.bucket_kv(1) == 32
    assert sched.bucket_kv(32) == 32             # exactly at the edge
    assert sched.bucket_kv(33) == 64             # one past the edge
    assert sched.bucket_kv(64) == 64
    assert sched.bucket_kv(65) == 128
    assert sched.bucket_kv(10_000) == 128        # saturates at max_len
    # no grid -> pack() reports kv_bucket=None (engine sweeps max_len)
    plain = GlobalBatchScheduler(kvm, discrete_sizes=(16, 8), max_active=8)
    plain.submit(Request(rid=0, prompt=list(range(11)), max_new_tokens=1))
    packed = plain.pack(plain.plan())
    assert packed.kv_bucket is None
    # first plan chunks the first 8 prompt tokens -> KV extent 8
    assert packed.kv_needed == 8


def test_default_kv_buckets_grid():
    assert default_kv_buckets(512) == (64, 128, 256, 512)
    assert default_kv_buckets(520) == (64, 128, 256, 512, 520)
    assert default_kv_buckets(128) == (64, 128)
    assert default_kv_buckets(48) == (48,)


# ---------------------------------------------------------------------------
# engine end-to-end: bucketed == dense == legacy (f32: bf16 accumulation-
# order diffs + MoE routing would flip argmax between execution paths)
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch", ["tiny-toy", "deepseek-v2-236b"])
def test_engine_kv_bucketing_matches_dense_and_legacy(arch):
    cfg = get_config(arch) if arch == "tiny-toy" else scale_down(
        get_config(arch))
    if cfg.moe is not None:
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, capacity_factor=16.0))
    cfg = dataclasses.replace(cfg, dtype="float32")
    params = model.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(3)
    # prompt 30 + 4 decode tokens crosses the 32-bucket edge mid-decode
    # (context 31..34); prompt 12 stays inside the smallest bucket
    prompts = [list(rng.integers(0, cfg.vocab_size, size=n))
               for n in (30, 12, 7)]
    outs = {}
    for name, kw in [("bucketed", dict(kv_buckets=(32, 64))),
                     ("dense", dict(kv_bucketing=False)),
                     ("legacy", dict(step_mode="legacy"))]:
        eng = ServeEngine(cfg, params, max_slots=2, max_len=64,
                          discrete_sizes=(16, 8), avg_decode_len=4, **kw)
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=list(p), max_new_tokens=4))
        done = eng.run()
        assert len(done) == len(prompts)
        outs[name] = {r.rid: r.output for r in done}
        if name == "bucketed":
            # both edge-straddling buckets really launched
            assert set(eng.stats.kv_bucket_hist) == {32, 64}
    assert outs["bucketed"] == outs["dense"]
    assert outs["bucketed"] == outs["legacy"]


def test_packed_compile_cache_bounded_by_t_times_kv_buckets():
    """Acceptance criterion: the packed program is keyed by (T bucket,
    kv bucket) only, so the compile cache is ≤ |T buckets| × |kv buckets| —
    and attention work tracked the buckets, not max_len."""
    cfg = get_config("tiny-toy")
    params = model.init(cfg, jax.random.PRNGKey(0))
    sizes = (32, 16, 8)
    kv_grid = (32, 64, 128)
    eng = ServeEngine(cfg, params, max_slots=4, max_len=128,
                      discrete_sizes=sizes, avg_decode_len=4,
                      kv_buckets=kv_grid)
    rng = np.random.default_rng(5)
    for i in range(10):
        eng.submit(Request(
            rid=i,
            prompt=list(rng.integers(0, cfg.vocab_size,
                                     size=int(rng.integers(3, 60)))),
            max_new_tokens=3))
    eng.run()
    assert eng.kv_buckets == kv_grid
    # len(sizes) + the max_active floor bucket, × the kv grid
    assert eng._packed_step._cache_size() <= (len(sizes) + 1) * len(kv_grid)
    assert set(eng.stats.kv_bucket_hist) <= set(kv_grid)
    # short contexts actually used the small buckets: the launched
    # attention sweep is strictly less than a max_len sweep every iteration
    launched = sum(eng.stats.kv_bucket_hist.values())
    assert launched == eng.stats.iterations
    assert min(eng.stats.kv_bucket_hist) < eng.max_len
    assert eng.stats.packed_attn_kv_rows < \
        eng.scheduler.launched_tokens * eng.max_len


# ---------------------------------------------------------------------------
# §Perf HC3 toggle promotion: explicit args, env only as fallback default
# ---------------------------------------------------------------------------
def test_attn_toggles_resolved_at_engine_construction(monkeypatch):
    cfg = get_config("tiny-toy")
    params = model.init(cfg, jax.random.PRNGKey(0))
    monkeypatch.delenv("REPRO_ATTN_FAST", raising=False)
    monkeypatch.delenv("REPRO_ATTN_STREAM", raising=False)
    assert ServeEngine(cfg, params).attn_fast is False
    # explicit argument wins over env...
    monkeypatch.setenv("REPRO_ATTN_FAST", "1")
    eng = ServeEngine(cfg, params, attn_fast=False, attn_stream=True)
    assert eng.attn_fast is False and eng.attn_stream is True
    # ...env is the fallback, captured once at construction
    eng2 = ServeEngine(cfg, params)
    assert eng2.attn_fast is True
    monkeypatch.setenv("REPRO_ATTN_FAST", "0")
    assert eng2.attn_fast is True                # no trace-time env re-read


def test_attn_config_context_pins_and_restores():
    assert ops.attn_fast_default() in (False, True)
    before = (ops.attn_fast_default(), ops.attn_stream_default())
    with ops.attn_config(fast=True, stream=True):
        assert ops.attn_fast_default() is True
        assert ops.attn_stream_default() is True
    assert (ops.attn_fast_default(), ops.attn_stream_default()) == before


def test_ops_fast_kwarg_selects_variant(monkeypatch):
    """The explicit ``fast`` kwarg picks the ref variant regardless of env."""
    monkeypatch.setenv("REPRO_ATTN_FAST", "1")
    called = []
    monkeypatch.setattr(ref, "packed_attention_ref",
                        lambda *a, **k: called.append("ref"))
    monkeypatch.setattr(ref, "packed_attention_fast",
                        lambda *a, **k: called.append("fast"))
    q, k, v, slot, lens = _case(2, 2, 8, 2, 1, 8, 8, jnp.float32)
    ops.packed_attention(q, k, v, slot, lens, impl="ref", fast=False)
    ops.packed_attention(q, k, v, slot, lens, impl="ref", fast=True)
    ops.packed_attention(q, k, v, slot, lens, impl="ref")   # env fallback
    assert called == ["ref", "fast", "fast"]
