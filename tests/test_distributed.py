"""Distribution-layer tests.

Multi-device semantics (shard_map collectives, GSPMD lowering) run in
subprocesses so the XLA fake-device flag never leaks into this process
(smoke tests must keep seeing 1 device)."""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.distributed import checkpoint as ckpt
from repro.distributed.elastic import (ClusterState, ElasticManager,
                                       StragglerMitigator, per_replica_batch)
from repro.models import model

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


# version-adaptive shard_map (check_rep/check_vma across jax releases):
# the ONE implementation lives in repro.distributed.sharding — the engine's
# TP packed step (DESIGN.md §11) uses it too, and subprocess snippets run
# with PYTHONPATH=src.  Prepended to every subprocess snippet.
SMAP_COMPAT = """
    from repro.distributed.sharding import shard_map_compat as smap
"""


def run_subprocess(code: str, devices: int = 8) -> str:
    env = dict(os.environ,
               XLA_FLAGS=f"--xla_force_host_platform_device_count={devices}",
               PYTHONPATH=SRC)
    out = subprocess.run(
        [sys.executable, "-c",
         textwrap.dedent(SMAP_COMPAT) + textwrap.dedent(code)],
        capture_output=True, text=True, env=env, timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_collective_matmuls_multi_device():
    out = run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.distributed.collective_matmul import (
            allgather_matmul, matmul_reduce_scatter, matmul_allreduce)
        mesh = jax.make_mesh((8,), ("model",))
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.normal(size=(16, 64)), jnp.float32)
        w = jnp.asarray(rng.normal(size=(64, 32)), jnp.float32)
        f = jax.jit(smap(lambda a, b: allgather_matmul(a, b, "model"),
            mesh, (P(None, "model"), P(None, "model")), P(None, "model")))
        assert float(jnp.abs(f(x, w) - x @ w).max()) < 1e-4
        g = jax.jit(smap(lambda a, b: matmul_reduce_scatter(a, b, "model"),
            mesh, (P(None, "model"), P("model", None)), P(None, "model")))
        assert float(jnp.abs(g(x, w) - x @ w).max()) < 1e-4
        h = jax.jit(smap(lambda a, b: matmul_allreduce(a, b, "model"),
            mesh, (P(None, "model"), P("model", None)), P(None, None),
            check=False))
        assert float(jnp.abs(h(x, w) - x @ w).max()) < 1e-4
        print("OK")
    """)
    assert "OK" in out


def test_tp_engine_token_equivalence_subprocess():
    """DESIGN.md §11 smoke under tier-1's single-device run: the shard_map
    TP packed step must be f32 token-exact against tp=1 (the full
    per-family suite lives in tests/test_tp_engine.py and runs in CI's
    tp-host-devices job)."""
    out = run_subprocess("""
        import dataclasses
        import jax, numpy as np
        from repro.configs import get_config
        from repro.models import model
        from repro.serving.engine import ServeEngine
        from repro.serving.request import Request

        cfg = dataclasses.replace(get_config("tiny-toy"), dtype="float32")
        params = model.init(cfg, jax.random.PRNGKey(0))
        rng = np.random.default_rng(0)
        prompts = [list(map(int, rng.integers(0, cfg.vocab_size,
                                              size=int(n))))
                   for n in rng.integers(3, 12, size=4)]
        outs = {}
        for tp in (1, 2):
            eng = ServeEngine(cfg, params, max_slots=2, max_len=32,
                              discrete_sizes=(16, 8), avg_decode_len=4,
                              tp=tp)
            for i, p in enumerate(prompts):
                eng.submit(Request(rid=i, prompt=list(p), max_new_tokens=3))
            done = eng.run()
            outs[tp] = {r.rid: tuple(r.output) for r in done}
            assert eng.stats.model_dispatches == eng.stats.iterations
            assert eng.stats.host_syncs == eng.stats.iterations
        assert outs[1] == outs[2], (outs[1], outs[2])
        print("OK")
    """, devices=2)
    assert "OK" in out


def test_compressed_psum_error_feedback():
    out = run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from jax.sharding import PartitionSpec as P
        from repro.distributed.compression import (
            compressed_psum, compress_state_init, plain_psum)
        mesh = jax.make_mesh((4,), ("pod",))
        rng = np.random.default_rng(0)
        g = jnp.asarray(rng.normal(size=(4, 64)), jnp.float32)  # per-pod rows

        def exchange(gs, rs):
            return compressed_psum({"w": gs}, {"w": rs}, "pod")

        f = jax.jit(smap(exchange, mesh,
            (P("pod"), P("pod")), (P("pod"), P("pod")), check=False))
        # accumulated compressed means track the true mean (error feedback)
        true_mean = np.asarray(g).mean(axis=0)
        res = jnp.zeros_like(g)
        acc_c, acc_t = 0.0, 0.0
        for step in range(8):
            out_, new_res = f(g, res)
            res = new_res["w"]
            acc_c += np.asarray(out_["w"])[0]
            acc_t += true_mean
        err = np.abs(acc_c - acc_t).max() / (np.abs(acc_t).max() + 1e-9)
        assert err < 0.05, err       # error feedback keeps drift bounded
        print("OK", err)
    """)
    assert "OK" in out


def test_checkpoint_roundtrip(tmp_path):
    cfg = get_config("tiny-toy")
    params = model.init(cfg, jax.random.PRNGKey(0))
    tree = {"params": params, "step": jnp.asarray(7)}
    ckpt.save(tree, str(tmp_path), 7)
    back, step = ckpt.restore(str(tmp_path), tree)
    assert step == 7
    flat_a = jax.tree.leaves(tree)
    flat_b = jax.tree.leaves(back)
    for a, b in zip(flat_a, flat_b):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_retention_and_latest(tmp_path):
    tree = {"x": jnp.ones((4,))}
    for s in (10, 20, 30, 40):
        ckpt.save(tree, str(tmp_path), s)
    ckpt.retain(str(tmp_path), keep=2)
    assert ckpt.latest_step(str(tmp_path)) == 40
    with pytest.raises(FileNotFoundError):
        ckpt.restore(str(tmp_path), tree, step=10)


def test_trainer_crash_restart_bitexact(tmp_path):
    """Injected failure + restart == uninterrupted run (deliverable:
    fault-tolerant checkpoint/restart)."""
    from repro.training.data import DataConfig, synthetic_stream
    from repro.training.optimizer import AdamWConfig
    from repro.training.trainer import DriverConfig, TrainConfig, Trainer

    cfg = get_config("tiny-toy")
    tc = TrainConfig(opt=AdamWConfig(lr=1e-3, total_steps=12, warmup_steps=2))
    dconf = DataConfig(batch=2, seq_len=16, vocab_size=cfg.vocab_size, seed=3)

    # uninterrupted reference
    dc_ref = DriverConfig(steps=12, ckpt_dir=str(tmp_path / "ref"),
                          ckpt_every=4)
    ref = Trainer(cfg, tc, dc_ref, seed=1)
    ref.fit(synthetic_stream(dconf))

    # crash at step 7, then restart
    dc = DriverConfig(steps=12, ckpt_dir=str(tmp_path / "ft"), ckpt_every=4,
                      inject_failure_at=7)
    tr = Trainer(cfg, tc, dc, seed=1)
    with pytest.raises(RuntimeError, match="injected failure"):
        tr.fit(synthetic_stream(dconf))
    tr2 = Trainer(cfg, tc, dc, seed=1)           # restores from step 4
    assert tr2.start_step == 4
    stream = synthetic_stream(dconf)
    for _ in range(tr2.start_step):              # deterministic data order
        next(stream)
    tr2.dc.inject_failure_at = None
    tr2.fit(stream)

    a = jax.tree.leaves(ref.params)
    b = jax.tree.leaves(tr2.params)
    for x, y in zip(a, b):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))


def test_elastic_rescale_policy():
    em = ElasticManager(ClusterState(data=16, model=16, pods=2), min_data=4)
    d = em.on_failure("data")
    assert d.action == "rescale" and d.new_state.data == 15
    d = em.on_failure("model")
    assert d.action == "rescale" and d.new_state.pods == 1
    d = em.on_failure("model")
    assert d.action == "halt"
    assert per_replica_batch(256, ClusterState(data=15, model=16)) == 18


def test_elastic_checkpoint_restore_to_new_topology(tmp_path):
    """Save params, restore under different sharding — elastic path."""
    cfg = get_config("tiny-toy")
    params = model.init(cfg, jax.random.PRNGKey(0))
    ckpt.save({"params": params}, str(tmp_path), 1)
    # restore with explicit (single-device) shardings
    dev = jax.devices()[0]
    shardings = jax.tree.map(
        lambda _: jax.sharding.SingleDeviceSharding(dev), {"params": params})
    back, _ = ckpt.restore(str(tmp_path), {"params": params},
                           shardings=shardings)
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(back["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_straggler_mitigator_shifts_load():
    sm = StragglerMitigator(4, alpha=1.0, max_skew=0.25)
    sm.observe([1.0, 1.0, 1.0, 2.0])     # host 3 is 2× slower
    shares = sm.shares()
    assert shares[3] == min(shares)
    split = sm.split_batch(256, multiple_of=8)
    assert sum(split) == 256
    assert split[3] <= min(split[:3])
    assert all(s % 8 == 0 or i == int(np.argmax(shares))
               for i, s in enumerate(split))
