"""Packed speculative decoding (DESIGN.md §13).

Covers the tentpole invariants:
  * greedy spec-decode is f32 **token-exact** vs the plain packed engine
    across GQA and MLA configs, at async depth 0 and 1 and several
    ``spec_k`` — the verify/rollback path may only change *when* tokens
    are produced, never *which*;
  * 1 model dispatch + 1 (deferred) host sync per iteration regardless of
    ``spec_k`` — acceptance, rejection sampling and the cache_len rollback
    all happen inside the single packed program;
  * the compile cache keeps the (|T buckets| + 1) × |kv buckets| bound:
    ``spec_k`` only swaps the decode-only floor bucket for
    ``max_active × (spec_k + 1)``;
  * acceptance accounting (``spec_proposed_tokens`` /
    ``spec_accepted_tokens`` / ``spec_verify_segments``): a drafter that
    replays the known continuation gets near-perfect acceptance and
    finishes in correspondingly fewer iterations;
  * speculation requires attention-only models (recurrent state cannot
    roll back) and composes with cross-request prefix caching (§12);
  * the sampling satellite: ``temperature`` / ``top_k`` serving is
    deterministic and async-depth invariant (per-(slot, pos) PRNG keys),
    and the config surface validates its invariants.
"""
import dataclasses

import jax
import numpy as np
import pytest

from repro.configs import get_config, scale_down
from repro.models import model
from repro.serving.config import EngineConfig
from repro.serving.draft import Drafter, NgramDrafter, make_drafter
from repro.serving.engine import ServeEngine
from repro.serving.request import Request

SIZES = (16, 8)
# GQA (tiny-toy) and MLA (+MoE) — the two attention cache layouts the
# verify segment's scatter/rollback must cover
FAMILIES = ["tiny-toy", "deepseek-v2-236b"]


def _cfg(name):
    cfg = get_config(name) if name == "tiny-toy" else scale_down(
        get_config(name))
    if cfg.moe is not None:
        # dropless so spec and plain runs route identically
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, capacity_factor=16.0))
    return dataclasses.replace(cfg, dtype="float32")


@pytest.fixture(scope="module", params=FAMILIES)
def family(request):
    cfg = _cfg(request.param)
    params = model.init(cfg, jax.random.PRNGKey(0))
    return cfg, params


def _prompts(cfg):
    """Repetitive motifs (the n-gram drafter's home turf) plus one random
    prompt (acceptance may be ~0 there — correctness must not care)."""
    rng = np.random.default_rng(3)
    motif = [5, 9, 3, 7]
    return [motif * 5, ([2, 4] * 8)[:13],
            list(map(int, rng.integers(0, cfg.vocab_size, size=7)))]


def _run(cfg, params, spec_k, depth, *, max_new=12, drafter=None, slots=2,
         **kw):
    eng = ServeEngine(cfg, params, EngineConfig(
        max_slots=slots, max_len=96, discrete_sizes=SIZES,
        avg_decode_len=4.0, spec_k=spec_k, async_depth=depth,
        async_harvest=False, **kw))
    if drafter is not None:
        eng.drafter = eng.scheduler.drafter = drafter
    for i, p in enumerate(_prompts(cfg)):
        eng.submit(Request(rid=i, prompt=list(p), max_new_tokens=max_new))
    done = eng.run()
    assert len(done) == 3
    assert eng.in_flight == 0
    return eng, {r.rid: tuple(r.output) for r in done}


# ---------------------------------------------------------------------------
# greedy token-exactness + single-dispatch invariants
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("depth", [0, 1])
@pytest.mark.parametrize("spec_k", [2, 4])
def test_greedy_spec_decode_token_exact(family, spec_k, depth):
    cfg, params = family
    _, base = _run(cfg, params, 0, depth)
    eng, out = _run(cfg, params, spec_k, depth)
    assert out == base, (cfg.name, spec_k, depth)
    # still ONE dispatch and ONE (deferred) sync per iteration: the whole
    # verify/accept/rollback path lives inside the packed program
    assert eng.stats.dispatches_per_iter == 1.0
    assert eng.stats.syncs_per_iter == 1.0
    st = eng.stats
    assert st.spec_verify_segments > 0
    assert st.spec_proposed_tokens == st.spec_verify_segments * spec_k
    assert 0 <= st.spec_accepted_tokens <= st.spec_proposed_tokens
    assert st.spec_accepted_per_verify >= 1.0


def test_spec_compile_cache_bound(family):
    """spec_k swaps the decode-only floor bucket (max_active × (spec_k+1))
    into the T grid — still (|T buckets| + 1) × |kv buckets| programs."""
    cfg, params = family
    eng, _ = _run(cfg, params, 3, 1)
    bound = (len(SIZES) + 1) * len(eng.kv_buckets)
    assert eng._packed_step._cache_size() <= bound, \
        (eng._packed_step._cache_size(), bound)


# ---------------------------------------------------------------------------
# acceptance accounting with a known-good drafter
# ---------------------------------------------------------------------------
class _ReplayDrafter:
    """Proposes the continuation of a known target sequence — near-perfect
    acceptance at async depth 0 (the drafter sees fully-committed history),
    so the engine must finish in ~max_new / (spec_k + 1) verify segments."""

    def __init__(self, targets: dict[int, list[int]]):
        self.targets = targets

    def propose(self, req, k):
        tgt = self.targets.get(req.rid, [])
        return tgt[len(req.output):len(req.output) + k]


def test_replay_drafter_acceptance_and_iteration_count(family):
    cfg, params = family
    k, max_new = 3, 12
    e0, base = _run(cfg, params, 0, 0, max_new=max_new)
    replay = _ReplayDrafter({rid: list(out) for rid, out in base.items()})
    assert isinstance(replay, Drafter)   # runtime-checkable protocol
    eng, out = _run(cfg, params, k, 0, max_new=max_new, drafter=replay)
    assert out == base
    st = eng.stats
    # every draft that fit under max_new_tokens was accepted: the only
    # rejections are final-segment tails truncated by the cap
    assert st.spec_acceptance_rate > 0.6, st.spec_acceptance_rate
    assert st.spec_accepted_per_verify > 2.0, st.spec_accepted_per_verify
    # the whole point: far fewer verify segments than plain decode steps
    plain_decode_iters = e0.stats.decode_tokens  # 1 committed token each
    assert st.spec_verify_segments < plain_decode_iters / 2


# ---------------------------------------------------------------------------
# composition + guardrails
# ---------------------------------------------------------------------------
def test_spec_requires_attention_only():
    cfg = dataclasses.replace(scale_down(get_config("xlstm-1.3b")),
                              dtype="float32")
    params = model.init(cfg, jax.random.PRNGKey(0))
    with pytest.raises(AssertionError, match="attention-only"):
        ServeEngine(cfg, params, EngineConfig(max_slots=2, max_len=64,
                                              spec_k=2))


def test_spec_composes_with_prefix_caching():
    """Verify-segment write targets route through the block table on
    device (the host leaves them OOB), so §13 stays token-exact under the
    §12 block-table KV with shared prefixes."""
    cfg = _cfg("tiny-toy")
    params = model.init(cfg, jax.random.PRNGKey(0))
    kw = dict(prefix_caching=True, kv_block_size=8)
    _, base = _run(cfg, params, 0, 1, **kw)
    eng, out = _run(cfg, params, 2, 1, **kw)
    assert out == base
    assert eng.stats.dispatches_per_iter == 1.0
    assert eng.stats.spec_verify_segments > 0


# ---------------------------------------------------------------------------
# stochastic sampling satellite (temperature / top_k)
# ---------------------------------------------------------------------------
def test_stochastic_sampling_deterministic_and_depth_invariant():
    """PRNG keys fold (request id, position) only — never the launch
    index, physical slot, or sampled values — so a temperature/top_k run
    is exactly reproducible and identical at any async depth, even when
    slot-reuse timing differs between depths."""
    cfg = _cfg("tiny-toy")
    params = model.init(cfg, jax.random.PRNGKey(0))
    kw = dict(temperature=0.8, top_k=8)
    _, a = _run(cfg, params, 0, 0, **kw)
    _, b = _run(cfg, params, 0, 0, **kw)
    _, c = _run(cfg, params, 0, 1, **kw)
    assert a == b        # deterministic replay
    assert a == c        # lag-invariant draws (slot reuse shifts, rid wins)
    _, greedy = _run(cfg, params, 0, 0)
    assert a != greedy   # the sampler is actually in the graph


def test_stochastic_spec_decode_token_exact():
    """Sample-and-compare rejection with (rid, pos)-keyed draws: a
    re-verify of a rejected position repeats the same sample, so
    point-mass-drafter speculation commits exactly the plain stochastic
    trajectory (common random numbers) — token-exact beyond greedy."""
    cfg = _cfg("tiny-toy")
    params = model.init(cfg, jax.random.PRNGKey(0))
    kw = dict(temperature=0.8)
    _, base = _run(cfg, params, 0, 1, **kw)
    eng, out = _run(cfg, params, 2, 1, **kw)
    assert out == base
    st = eng.stats
    assert st.spec_proposed_tokens == st.spec_verify_segments * 2
    assert st.spec_accepted_tokens <= st.spec_proposed_tokens


# ---------------------------------------------------------------------------
# config + drafter registry surface
# ---------------------------------------------------------------------------
def test_config_validation():
    with pytest.raises(AssertionError):
        EngineConfig(spec_k=-1)
    with pytest.raises(AssertionError, match="packed"):
        EngineConfig(step_mode="legacy", spec_k=2)
    with pytest.raises(AssertionError):
        EngineConfig(max_len=8, spec_k=8)
    with pytest.raises(AssertionError):
        EngineConfig(drafter="nope")
    with pytest.raises(AssertionError, match="top_k"):
        EngineConfig(top_k=5)                 # needs temperature > 0
    with pytest.raises(AssertionError):
        EngineConfig(temperature=-0.1)
    assert EngineConfig(spec_k=2).resolved_drafter == "ngram"
    assert EngineConfig().resolved_drafter is None
    assert EngineConfig(drafter="ngram").resolved_drafter is None  # spec off


def test_ngram_drafter_lookup():
    d = make_drafter("ngram")
    assert isinstance(d, NgramDrafter)
    with pytest.raises(ValueError):
        make_drafter("nope")
    # trailing 2-gram (3, 7) recurs -> proposes its continuation
    r = Request(rid=0, prompt=[5, 9, 3, 7, 1, 2, 3, 7], max_new_tokens=4)
    assert d.propose(r, 3) == [1, 2, 3]
    # drafts follow committed *output* too (self-history lookup)
    r2 = Request(rid=1, prompt=[4, 4], max_new_tokens=4)
    r2.output = [8, 6, 4, 4]
    assert d.propose(r2, 2) == [8, 6]
    # no recurrence -> no proposal (scheduler pads; padding gets rejected)
    r3 = Request(rid=2, prompt=[1, 2, 3, 4], max_new_tokens=4)
    assert d.propose(r3, 3) == []
