"""Token-packed dense-batch step (DESIGN.md §8).

Covers the tentpole invariants:
  * ``forward_packed`` over a mixed stream (decode token + two prefill
    chunks + padding, all in one call) == per-request ``forward_decode`` /
    ``forward_chunk`` references, across every mixer family;
  * engine packed step == legacy per-chunk step end-to-end (f32 so op-order
    rounding can't flip MoE routing), through slot reuse;
  * exactly one jitted model dispatch and one device→host transfer per
    engine iteration (the legacy step strictly more);
  * the compile cache is bounded by the scheduler's discrete dense sizes
    and the launched shapes come from that set;
  * prefill expansion stays 1.0 and padding is accounted;
  * nano-batch interleave ordering of packed segments;
  * the KV-manager satellite fixes (upload no longer loses blobs on device
    re-allocation failure; LRU evictions count discarded requests).
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, scale_down
from repro.core.nanobatch import NanoBatchPlan, packed_segment_order
from repro.models import model
from repro.serving.engine import ServeEngine
from repro.serving.kvcache import PagedKVManager
from repro.serving.request import Request

FAMILIES = ["tiny-toy", "deepseek-v2-236b", "jamba-1.5-large-398b",
            "xlstm-1.3b", "musicgen-medium"]


def _cfg(name, dtype=None):
    cfg = get_config(name) if name == "tiny-toy" else scale_down(
        get_config(name))
    if cfg.moe is not None:
        # dropless so per-request and packed batch shapes route identically
        cfg = dataclasses.replace(cfg, moe=dataclasses.replace(
            cfg.moe, capacity_factor=16.0))
    if dtype is not None:
        cfg = dataclasses.replace(cfg, dtype=dtype)
    return cfg


def _tokens(cfg, key, b, s):
    if cfg.frontend == "audio":
        return jax.random.randint(key, (b, s, cfg.num_codebooks), 0,
                                  cfg.vocab_size)
    return jax.random.randint(key, (b, s), 0, cfg.vocab_size)


def _gather_slot(cache, i):
    return jax.tree.map(lambda a: a[:, i:i + 1], cache)


# f32 end-to-end: the packed step must be *semantically* exact against the
# per-request paths — in f32 the recurrent families agree to the last ulp,
# so any real masking/offset bug shows as a gross error instead of hiding
# under a bf16 accumulation-order tolerance (bf16 coverage comes from the
# tiny-toy naive-greedy engine tests, which run the packed step by default)
@pytest.fixture(scope="module", params=FAMILIES)
def family(request):
    cfg = _cfg(request.param, dtype="float32")
    params = model.init(cfg, jax.random.PRNGKey(0))
    return cfg, params


def test_forward_packed_matches_per_request_reference(family):
    """One packed call carrying a decode token (slot 0), a deep prefill
    chunk (slot 1), a short chunk (slot 2), and padding == the per-request
    decode/chunk reference paths."""
    cfg, params = family
    max_len, pre = 16, 4
    cache = model.init_cache(cfg, 1, 3, max_len)

    # common 4-token prefix in every slot (per-row chunk path)
    prefix = _tokens(cfg, jax.random.PRNGKey(1), 3, pre)
    _, cache = model.forward_chunk(cfg, params, prefix, cache,
                                   jnp.zeros((3,), jnp.int32))
    clen = jnp.full((3,), pre, jnp.int32)

    # the packed stream: slot0 decode @4, slot1 chunk [4,9), slot2 chunk
    # [4,6), 2 padding tokens -> T = 10
    dec = _tokens(cfg, jax.random.PRNGKey(2), 1, 1)
    ch1 = _tokens(cfg, jax.random.PRNGKey(3), 1, 5)
    ch2 = _tokens(cfg, jax.random.PRNGKey(4), 1, 2)
    pad = jnp.zeros_like(_tokens(cfg, jax.random.PRNGKey(5), 1, 2))
    stream = jnp.concatenate([dec, ch1, ch2, pad], axis=1)
    slot = jnp.asarray([0] + [1] * 5 + [2] * 2 + [0] * 2, jnp.int32)
    pos = jnp.asarray([4, 4, 5, 6, 7, 8, 4, 5, 0, 0], jnp.int32)
    active = jnp.asarray([True] * 8 + [False] * 2)
    wpos = jnp.where(active, pos, max_len)

    logits, new_cache = model.forward_packed(cfg, params, stream, cache,
                                             slot, pos, wpos, active)

    # per-request references on gathered one-slot caches
    ref_dec, ref_dec_cache = model.forward_decode(
        cfg, params, dec, _gather_slot(cache, 0), clen[:1])
    ref1, ref1_cache = model.forward_chunk(
        cfg, params, ch1, _gather_slot(cache, 1), clen[1:2])
    ref2, ref2_cache = model.forward_chunk(
        cfg, params, ch2, _gather_slot(cache, 2), clen[2:3])

    ref = jnp.concatenate([ref_dec[:, None], ref1, ref2], axis=1)
    got = logits[:, :8]
    err = float(jnp.abs(got.astype(jnp.float32)
                        - ref.astype(jnp.float32)).max())
    scale = float(jnp.abs(ref.astype(jnp.float32)).max()) + 1e-6
    # f32: exact up to einsum-order rounding (the recurrent families are
    # bit-identical; attention differs in reduction order, and the MoE
    # router amplifies those ulps into slightly different expert weights) —
    # a real masking/offset bug would be O(scale)
    assert err <= max(1e-3 * scale, 1e-4), (cfg.name, err, scale)

    # committed state: each slot's recurrent carry matches its reference;
    # padding committed nothing (slot 0's state untouched by the pad tokens)
    for gi, (pattern, reps) in enumerate(cfg.layer_groups()):
        for i, spec in enumerate(pattern):
            got_sub = new_cache[gi][f"sub{i}"]
            for si, ref_cache in ((0, ref_dec_cache), (1, ref1_cache),
                                  (2, ref2_cache)):
                ref_sub = ref_cache[gi][f"sub{i}"]
                for name, leaf in got_sub.items():
                    g = np.asarray(leaf[:, si], np.float32)
                    r = np.asarray(ref_sub[name][:, 0], np.float32)
                    tol = max(1e-3 * (np.abs(r).max() + 1e-6), 1e-4)
                    assert np.abs(g - r).max() <= tol, \
                        (cfg.name, gi, i, name, si)


def test_engine_packed_matches_legacy(family):
    """End-to-end A/B: the packed single-dispatch step produces the same
    tokens as the legacy decode-then-per-chunk step, with slot reuse."""
    cfg, params = family
    rng = np.random.default_rng(1)
    prompts = [list(rng.integers(0, cfg.vocab_size,
                                 size=int(rng.integers(3, 12))))
               for _ in range(5)]
    outs = {}
    for mode in ("packed", "legacy"):
        eng = ServeEngine(cfg, params, max_slots=2, max_len=48,
                          discrete_sizes=(16, 8), avg_decode_len=4,
                          step_mode=mode)
        for i, p in enumerate(prompts):
            eng.submit(Request(rid=i, prompt=list(p), max_new_tokens=3))
        done = eng.run()
        assert len(done) == len(prompts)
        outs[mode] = {r.rid: r.output for r in done}
    assert outs["packed"] == outs["legacy"]


def test_packed_one_dispatch_one_sync_per_iteration():
    """Acceptance criterion: a packed iteration issues exactly one jitted
    model dispatch and one device→host transfer; the legacy step issues
    1 + K dispatches (decode + per-chunk) with a blocking sync per chunk."""
    cfg = get_config("tiny-toy")
    params = model.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    def load(eng):
        for i in range(6):
            eng.submit(Request(
                rid=i, prompt=list(rng.integers(0, cfg.vocab_size, size=20)),
                max_new_tokens=4))
        eng.run()

    eng = ServeEngine(cfg, params, max_slots=4, max_len=64,
                      discrete_sizes=(32, 16, 8), avg_decode_len=4,
                      step_mode="packed")
    load(eng)
    assert eng.stats.iterations > 0
    assert eng.stats.model_dispatches == eng.stats.iterations
    assert eng.stats.host_syncs == eng.stats.iterations

    rng = np.random.default_rng(0)
    leg = ServeEngine(cfg, params, max_slots=4, max_len=64,
                      discrete_sizes=(32, 16, 8), avg_decode_len=4,
                      step_mode="legacy")
    load(leg)
    assert leg.stats.model_dispatches > leg.stats.iterations
    assert leg.stats.host_syncs > leg.stats.iterations


def test_packed_compile_cache_bounded_and_shapes_discrete():
    """The packed program is keyed only by the bucketed launch length T, so
    the XLA compile cache is bounded by the discrete dense sizes — and every
    launched shape comes from that set."""
    cfg = get_config("tiny-toy")
    params = model.init(cfg, jax.random.PRNGKey(0))
    sizes = (32, 16, 8)
    eng = ServeEngine(cfg, params, max_slots=4, max_len=64,
                      discrete_sizes=sizes, avg_decode_len=4,
                      step_mode="packed")
    rng = np.random.default_rng(2)
    for i in range(8):
        eng.submit(Request(
            rid=i,
            prompt=list(rng.integers(0, cfg.vocab_size,
                                     size=int(rng.integers(3, 40)))),
            max_new_tokens=3))
    eng.run()
    # len(sizes) buckets + the max_active floor bucket (decode-only launches)
    assert eng._packed_step._cache_size() <= len(sizes) + 1
    assert set(eng.stats.dense_batch_hist) <= set(sizes)
    assert eng.stats.prefill_expansion == 1.0
    # padding accounted on both sides of the scheduler/engine boundary
    assert eng.stats.packed_pad_tokens == eng.scheduler.padding_tokens
    assert eng.scheduler.launched_tokens >= eng.stats.total_tokens


def test_step_mode_validation():
    cfg = get_config("tiny-toy")
    params = model.init(cfg, jax.random.PRNGKey(0))
    with pytest.raises(AssertionError):
        ServeEngine(cfg, params, step_mode="packed",
                    prefill_mode="recompute")
    eng = ServeEngine(cfg, params, prefill_mode="recompute")
    assert eng.step_mode == "legacy"          # auto-fallback
    assert ServeEngine(cfg, params).step_mode == "packed"


# ---------------------------------------------------------------------------
# nano-batch interleave ordering
# ---------------------------------------------------------------------------
def test_packed_segment_order_interleave():
    kinds = ["prefill", "decode", "prefill", "decode", "prefill"]
    lengths = [8, 1, 32, 1, 16]
    order = packed_segment_order(kinds, lengths)
    assert [kinds[i] for i in order[:2]] == ["decode", "decode"]
    assert [lengths[i] for i in order[2:]] == [32, 16, 8]


def test_nano_plan_assigns_segments():
    plan = NanoBatchPlan((8, 8))
    assert plan.assign_segments([1, 1, 6, 8]) == (0, 0, 0, 1)


def test_scheduler_pack_accounts_padding():
    from repro.serving.scheduler import GlobalBatchScheduler
    kv = PagedKVManager(total_pages=1024, page_size=16, bytes_per_token=64,
                        avg_decode_len=8)
    sched = GlobalBatchScheduler(kv, discrete_sizes=(16, 8), max_active=8)
    sched.submit(Request(rid=0, prompt=list(range(11)), max_new_tokens=1))
    plan = sched.plan()
    packed = sched.pack(plan)
    assert packed.launch_tokens in (16, 8)
    assert packed.tokens == plan.dense_tokens
    assert packed.padding == packed.launch_tokens - packed.tokens
    assert sched.padding_tokens == packed.padding
    assert sum(packed.nano.sizes) == packed.launch_tokens
    assert len(packed.segment_nano) == len(packed.segments)


# ---------------------------------------------------------------------------
# KV-manager satellites
# ---------------------------------------------------------------------------
def test_upload_failure_keeps_host_blob():
    """Device re-allocation failure must not lose the host KV blob (it used
    to be popped first and silently discarded)."""
    kv = PagedKVManager(total_pages=4, page_size=8, bytes_per_token=64,
                        avg_decode_len=8)
    kv.allocate(1, 32)                        # all 4 pages
    data = np.arange(32, dtype=np.float32)
    kv.offload(1, data)                       # frees pages, blob on host
    kv.allocate(2, 32)                        # device full again
    assert kv.upload(1, np.float32, (32,)) is None
    assert 1 in kv.host_pool                  # blob retained, retryable
    assert kv.stats.discarded_requests == 0
    kv.free(2)
    back = kv.upload(1, np.float32, (32,))
    np.testing.assert_array_equal(back, data)


def test_lru_eviction_counts_discarded_requests():
    kv = PagedKVManager(total_pages=64, page_size=8, bytes_per_token=64,
                        avg_decode_len=8, host_capacity_bytes=1000)
    for rid in range(5):
        kv.allocate(rid, 8)
        kv.offload(rid, np.zeros(100, np.float32))    # 400 B each
    assert kv.stats.discarded_requests > 0
    assert kv.stats.discarded_requests == 5 - len(kv.host_pool)
