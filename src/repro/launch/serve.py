"""Serving driver: offline batch or poisson-arrival online simulation.

  python -m repro.launch.serve --arch tiny-toy --requests 16
  python -m repro.launch.serve --arch tiny-toy --online --rate 4 --duration 10
"""
from __future__ import annotations

import argparse
import os
import time

import jax
import numpy as np

from repro.configs import get_config, scale_down
from repro.models import model as model_lib
from repro.serving.config import EngineConfig, PoolConfig
from repro.serving.engine import ServeEngine
from repro.serving.pool import ReplicaPool
from repro.serving.request import Request


def ensure_host_devices(n: int) -> None:
    """Give this process ``n`` host-platform devices for ``--tp n`` runs on
    CPU.  Importing jax doesn't initialize the backend, so appending the
    flag first thing in main() — before any jax *operation* — is enough; if
    the backend somehow initialized earlier with too few devices,
    ``make_tp_mesh`` raises the actionable error."""
    if n <= 1:
        return
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + f" --xla_force_host_platform_device_count={n}").strip()


def make_requests(n: int, vocab: int, seed: int = 0, p_mean: int = 24,
                  d_mean: int = 16) -> list[Request]:
    rng = np.random.default_rng(seed)
    out = []
    for i in range(n):
        plen = max(2, int(rng.exponential(p_mean)))
        dlen = max(2, int(rng.exponential(d_mean)))
        out.append(Request(
            rid=i, prompt=list(rng.integers(0, vocab, size=min(plen, 96))),
            max_new_tokens=min(dlen, 64)))
    return out


def serve_pool(args, cfg, params, pcfg, reqs) -> None:
    """Multi-replica path (DESIGN.md §14): N engines behind the router,
    driven by the pool event loop, with optional chaos injection."""
    ecfg = EngineConfig.from_args(args, seed=args.seed)

    def mk_engine():
        return ServeEngine(cfg, params, ecfg)

    pool = ReplicaPool([mk_engine() for _ in range(pcfg.replicas)], pcfg,
                       engine_factory=mk_engine)
    rng = np.random.default_rng(args.seed)
    if args.online:
        offsets = list(np.cumsum(
            rng.exponential(1.0 / args.rate, size=len(reqs))))
    else:
        offsets = [0.0] * len(reqs)
    t0 = time.perf_counter()
    results = pool.run_online(reqs, offsets, duration=args.duration
                              if args.online else None)
    wall = time.perf_counter() - t0

    snap = pool.snapshot()
    n_tok = sum(h.engine.stats.total_tokens
                for h in pool.router.replicas if h.engine is not None)
    print(f"pool[{pcfg.replicas} replicas]: finished {len(results)}"
          f"/{len(reqs)} requests, {snap['shed_requests']} shed, "
          f"{n_tok} tokens in {wall*1e3:.0f} ms "
          f"({n_tok / max(wall, 1e-9):.1f} tok/s)")
    print(f"fault tolerance: {snap['faults_injected']} faults injected, "
          f"{snap['redispatched_requests']} requests re-dispatched "
          f"({snap['redispatched_tokens']} committed tokens replayed), "
          f"{snap['retries']} retries, {snap['timeouts']} timeouts, "
          f"{snap['slo_violations']} SLO violations")
    for rep in snap["replicas"]:
        state = "alive" if rep["alive"] else "dead"
        if rep["suspect"]:
            state += "/suspect"
        print(f"  r{rep['replica']} [{state}]: depth {rep['queue_depth']}, "
              f"queued {rep['queued_tokens']} tok, in-flight "
              f"{rep['inflight_tokens']} tok, KV {rep['kv_used_frac']:.0%}")
    done = list(results.values())
    lat = [r.finished_at - r.arrival for r in done
           if r.finished_at is not None]
    if lat and args.online:
        print(f"latency: p50 {np.percentile(lat, 50)*1e3:.1f} ms "
              f"p99 {np.percentile(lat, 99)*1e3:.1f} ms")
    for r in pool.shed[:5]:
        print(f"  shed rid={r.rid}: {r.reject_reason}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tiny-toy")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    # engine knobs are defined ONCE on EngineConfig and shared with
    # benchmarks/offline_throughput.py
    EngineConfig.add_args(ap)
    # pool knobs (DESIGN.md §14) — defined once, shared with the online
    # latency benchmark
    PoolConfig.add_args(ap)
    ap.add_argument("--online", action="store_true")
    ap.add_argument("--rate", type=float, default=4.0, help="req/s (poisson)")
    ap.add_argument("--duration", type=float, default=10.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    ensure_host_devices(args.tp)     # before the first jax operation

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = scale_down(cfg)
    params = model_lib.init(cfg, jax.random.PRNGKey(args.seed))
    pcfg = PoolConfig.from_args(args)
    reqs = make_requests(args.requests, cfg.vocab_size, args.seed)

    if pcfg.replicas > 1 or pcfg.fault_plan:
        serve_pool(args, cfg, params, pcfg, reqs)
        return
    eng = ServeEngine(cfg, params, EngineConfig.from_args(args, seed=args.seed))

    if not args.online:
        for r in reqs:
            eng.submit(r)
        done = eng.run()
    else:
        rng = np.random.default_rng(args.seed)
        arrivals = np.cumsum(rng.exponential(1.0 / args.rate, size=len(reqs)))
        t0, done, i = time.perf_counter(), [], 0
        while time.perf_counter() - t0 < args.duration or eng.scheduler.n_active:
            now = time.perf_counter() - t0
            while i < len(reqs) and arrivals[i] <= now:
                # absolute stamp: finished_at (commit time) is absolute
                # perf_counter, so latency = finished_at - arrival works
                reqs[i].arrival = t0 + arrivals[i]
                eng.submit(reqs[i])
                i += 1
            plan = eng.scheduler.plan()
            if plan is None:
                # the oldest in-flight commit may unblock planning (§10) —
                # retire one, not the whole pipeline, and re-plan right away
                # if it made progress
                if eng.in_flight:
                    done += eng.drain(max_retire=1)
                    continue
                if i >= len(reqs) and not eng.scheduler.n_active:
                    break
                time.sleep(0.005)
                continue
            done += eng.step(plan)
        done += eng.drain()
        # run() accumulates wall_time internally; the external plan/step
        # loop must account it itself or throughput/wall prints read 0
        eng.stats.wall_time += time.perf_counter() - t0

    # every figure below comes off the common snapshot() schema shared with
    # the benchmark JSON and the tests (EngineStats / KVStats satellites)
    st = eng.stats.snapshot()
    kv = eng.kv.stats.snapshot()
    print(f"finished {len(done)}/{len(reqs)} requests in "
          f"{st['iterations']} iters")
    print(f"tokens: prefill {st['prefill_tokens']} decode "
          f"{st['decode_tokens']} total {st['total_tokens']}")
    print(f"throughput {st['throughput']:.1f} tok/s (CPU ref-path proxy)")
    print(f"step={eng.step_mode}: {st['dispatches_per_iter']:.2f} "
          f"dispatches/iter, {st['syncs_per_iter']:.2f} host syncs/iter, "
          f"{st['packed_pad_tokens']} pad tokens")
    print(f"async depth {eng.async_depth}: "
          f"{st['blocking_syncs']}/{st['host_syncs']} blocking syncs "
          f"({st['blocking_syncs_per_iter']:.2f}/iter), "
          f"blocked {st['blocked_sync_time']*1e3:.0f} ms, "
          f"host {st['host_time']*1e3:.0f} ms, "
          f"dispatch {st['dispatch_time']*1e3:.0f} ms "
          f"(wall {st['wall_time']*1e3:.0f} ms), "
          f"{eng.scheduler.dropped_tokens} overshoot tokens dropped")
    if eng.tp > 1:
        print(f"tp={eng.tp}: "
              f"~{st['tp_collective_bytes_per_iter'] / 1e3:.1f} KB "
              f"modeled collective traffic/iter "
              f"({st['tp_collective_bytes'] / 1e6:.2f} MB total)")
    print("dense batch histogram: "
          f"{dict(sorted(st['dense_batch_hist'].items()))}")
    if st["kv_bucket_hist"]:
        swept = sum(b * n for b, n in st["kv_bucket_hist"].items())
        dense = args.max_len * sum(st["kv_bucket_hist"].values())
        print(f"kv bucket histogram: "
              f"{dict(sorted(st['kv_bucket_hist'].items()))}"
              f" (attention sweep {swept / max(dense, 1):.2f}x of max_len)")
    if eng.spec_k > 0:
        rate = st["spec_acceptance_rate"]
        if rate is None:
            print(f"spec decode k={eng.spec_k}: no verify segments ran")
        else:
            print(f"spec decode k={eng.spec_k} "
                  f"({eng.config.resolved_drafter}): "
                  f"{st['spec_verify_segments']} verify segments, "
                  f"{st['spec_accepted_tokens']}/"
                  f"{st['spec_proposed_tokens']} drafts accepted "
                  f"({rate:.0%} acceptance, "
                  f"{st['spec_accepted_per_verify']:.2f} committed "
                  f"tokens/verify)")
    if eng.kv_dtype == "int8":
        drift = st["kv_quant_drift"]
        print(f"kv dtype int8: {st['kv_quant_bytes_saved']/1e6:.2f} MB of "
              f"cache writes saved vs {eng.cfg.dtype} storage "
              f"({eng.kv.bytes_per_token} B/token vs "
              f"{eng._kv_bytes_native} B/token)"
              + (f", max logit drift {drift:.4f}" if drift is not None
                 else ""))
    if eng.prefix_caching:
        total_prompt = sum(r.prompt_len for r in done)
        print(f"prefix caching: {kv['prefix_hit_tokens']} prompt tokens "
              f"served from shared blocks "
              f"({kv['prefix_hit_tokens'] / max(total_prompt, 1):.0%} of "
              f"prompt), {kv['cow_copies']} CoW block copies, "
              f"{kv['evicted_blocks']} cached blocks evicted")
    print(f"kv offload: {kv['offload_bytes']/1e6:.2f} MB aggregated in "
          f"{kv['aggregated_copies']} copies")
    lat = [(r.finished_at or 0) - r.arrival for r in done if r.finished_at]
    if lat and args.online:
        norm = [l / max(len(r.output), 1) for l, r in zip(lat, done)]
        print(f"normalized latency: p50 {np.percentile(norm, 50)*1e3:.1f} ms/tok "
              f"p99 {np.percentile(norm, 99)*1e3:.1f} ms/tok")


if __name__ == "__main__":
    main()
