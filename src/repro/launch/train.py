"""Training driver (CPU-runnable with tiny configs; production mesh via
--mesh).  Demonstrates checkpoint/restart fault tolerance end-to-end:

  python -m repro.launch.train --arch tiny-toy --steps 30
  python -m repro.launch.train --arch tiny-toy --steps 30 --inject-failure 12
    (crashes at step 12; re-running the same command restores and finishes)
"""
from __future__ import annotations

import argparse


from repro.configs import get_config, scale_down
from repro.models import model as model_lib
from repro.training.data import DataConfig, make_stream
from repro.training.optimizer import AdamWConfig
from repro.training.trainer import DriverConfig, TrainConfig, Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tiny-toy")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced same-family config")
    ap.add_argument("--steps", type=int, default=30)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--remat", default="none")
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--inject-failure", type=int, default=None)
    ap.add_argument("--data", default=None, help="memmap token file")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = scale_down(cfg)
    tc = TrainConfig(remat=args.remat, grad_accum=args.grad_accum,
                     opt=AdamWConfig(lr=args.lr, total_steps=args.steps,
                                     warmup_steps=max(args.steps // 10, 1)))
    dc = DriverConfig(steps=args.steps, ckpt_dir=args.ckpt_dir,
                      ckpt_every=args.ckpt_every,
                      inject_failure_at=args.inject_failure)
    trainer = Trainer(cfg, tc, dc)
    stream = make_stream(DataConfig(batch=args.batch, seq_len=args.seq,
                                    vocab_size=cfg.vocab_size,
                                    path=args.data))
    # skip batches already consumed before a restart (deterministic order)
    for _ in range(trainer.start_step):
        next(stream)
    out = trainer.fit(stream)
    for row in out["history"]:
        print(f"step {row['step']:5d}  loss {row['loss']:.4f}  "
              f"gnorm {row['grad_norm']:.3f}  {row['sec']*1e3:.0f} ms")
    print(f"done at step {out['final_step']} "
          f"({model_lib.num_params(cfg)/1e6:.1f}M params)")


if __name__ == "__main__":
    main()
