"""Production mesh construction.

A FUNCTION, not a module-level constant — importing this module never
touches jax device state (required so smoke tests see 1 device while the
dry-run sees 512 fake hosts)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; multi_pod adds a 2-pod DCN axis (512)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def make_tp_mesh(tp: int):
    """1-D tensor-parallel mesh over the first ``tp`` local devices (the
    serving engine's ``ServeEngine(tp=N)`` mesh, DESIGN.md §11).  On this
    CPU container the devices come from
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N``."""
    import numpy as np
    devs = jax.devices()
    if len(devs) < tp:
        raise RuntimeError(
            f"tp={tp} needs {tp} devices but only {len(devs)} are visible; "
            "set XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{tp} (host-platform devices) or run on a {tp}-chip slice")
    return jax.sharding.Mesh(np.array(devs[:tp]), ("model",))


def tp_size(mesh) -> int:
    return mesh.shape["model"]


def dp_size(mesh) -> int:
    n = mesh.shape["data"]
    if "pod" in mesh.axis_names:
        n *= mesh.shape["pod"]
    return n
