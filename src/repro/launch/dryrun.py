import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input shape) on
the production mesh, prove it fits (memory_analysis), and extract the
roofline terms (cost_analysis + HLO collective bytes).

The two lines above MUST stay first: jax locks the device count on first
init.  Do not import this module from test/bench processes — run it as
``python -m repro.launch.dryrun``.

Usage:
  python -m repro.launch.dryrun --arch qwen3-8b --shape decode_32k
  python -m repro.launch.dryrun --all [--multi-pod] [--out results/]
"""
import argparse
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs import SHAPES, applicable_shapes, get_config
from repro.launch.hlo_analysis import analyze_module
from repro.distributed.sharding import (RULES_LONG_CTX, RULES_TP_DP, use_mesh)
from repro.launch.mesh import make_production_mesh, tp_size
from repro.models import model as model_lib
from repro.training.trainer import TrainConfig, make_train_step, train_state_shapes

ASSIGNED = [
    "jamba-1.5-large-398b", "xlstm-1.3b", "qwen3-4b", "minitron-4b",
    "qwen3-8b", "starcoder2-7b", "llava-next-34b", "musicgen-medium",
    "arctic-480b", "deepseek-v2-236b",
]

# ---------------------------------------------------------------------------
# per-cell lowering
# ---------------------------------------------------------------------------
HBM_PER_CHIP = 16e9          # v5e


def needs_fsdp(cfg, tp: int) -> bool:
    """2D weight sharding (model×data) when TP alone can't fit the params
    in HBM with room for KV/activations (jamba-398B, arctic-480B,
    deepseek-236B at TP=16)."""
    from repro.models.model import num_params
    return num_params(cfg) * 2 / tp > 0.75 * HBM_PER_CHIP


def build_lowered(arch: str, shape_name: str, *, multi_pod: bool = False,
                  remat: str = "full", variant: str = "baseline",
                  fsdp: str = "auto", coschedule: int = 0):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = dict(RULES_LONG_CTX if shape_name == "long_500k" else RULES_TP_DP)
    rules.update(VARIANTS[variant])
    tp = tp_size(mesh)
    if fsdp == "on" or (fsdp == "auto" and needs_fsdp(cfg, tp)):
        rules["w_embed"] = "data"        # 2D weight sharding (FSDP x TP)

    with use_mesh(mesh, rules):
        pshapes = model_lib.shapes(cfg, tp, mesh, rules)
        specs = model_lib.input_specs(cfg, shape, mesh=mesh, rules=rules, tp=tp)
        if shape.step == "train":
            tc = TrainConfig(remat=remat)
            step = make_train_step(cfg, tc)
            _, opt_shapes = train_state_shapes(cfg, tp, mesh, rules)
            lowered = jax.jit(step, donate_argnums=(0, 1)).lower(
                pshapes, opt_shapes, specs)
        elif shape.step == "prefill":
            def prefill_step(params, batch):
                return model_lib.prefill(cfg, params, batch["tokens"],
                                         patches=batch.get("patches"), tp=tp)
            lowered = jax.jit(prefill_step).lower(pshapes, specs)
        elif coschedule == 0:
            def serve_step(params, tokens, cache, cache_len):
                return model_lib.forward_decode(cfg, params, tokens, cache,
                                                cache_len)
            lowered = jax.jit(serve_step, donate_argnums=(2,)).lower(
                pshapes, specs["tokens"], specs["cache"], specs["cache_len"])
        else:
            # §Perf HC3: the NanoFlow serving iteration — decode co-scheduled
            # with a chunked-prefill nano-batch (paper §4.2/§4.3).  The
            # prefill GEMMs give the iteration compute-bound work while the
            # decode KV sweep streams; XLA's scheduler can overlap them
            # because the two nano-batches share no dependencies.
            from jax.sharding import NamedSharding
            from repro.distributed.sharding import logical_to_pspec
            pre_b = mesh.shape["data"]        # divisible by the DP axis
            pre_s = max(coschedule // pre_b, 8)
            extra = (cfg.num_codebooks,) if cfg.frontend == "audio" else ()
            pre_tokens = jax.ShapeDtypeStruct(
                (pre_b, pre_s) + extra, jnp.int32,
                sharding=NamedSharding(mesh, logical_to_pspec(
                    ("batch", "act_seq") + ((None,) if extra else ()),
                    mesh, rules)))

            def serve_step_fused(params, tokens, cache, cache_len, p_tokens):
                dec_logits, new_cache = model_lib.forward_decode(
                    cfg, params, tokens, cache, cache_len)
                pre_logits, _aux, states = model_lib.forward_full(
                    cfg, params, p_tokens, return_states=True)
                return dec_logits, new_cache, pre_logits[:, -1], states

            lowered = jax.jit(serve_step_fused, donate_argnums=(2,)).lower(
                pshapes, specs["tokens"], specs["cache"], specs["cache_len"],
                pre_tokens)
    return lowered, mesh


# sharding-rule variants for §Perf hillclimbing
VARIANTS: dict[str, dict] = {
    "baseline": {},
    # shard long-context KV over data even for batch>1 (sequence parallelism)
    "seq_shard_kv": {"kv_seq": "data"},
    # replicate activations fully within a layer (no TP on activations)
    "no_tp_act": {"act_heads": None, "act_kv_heads": None, "act_ff": None},
    # pure data parallelism over ALL mesh axes — the right production mapping
    # for small models (xlstm-1.3b): no TP collectives at all (§Perf HC1)
    "pure_dp": {"batch": "all", "heads": None, "kv_heads": None, "ff": None,
                "vocab": None, "experts": None, "inner": None, "dv": None,
                "lora": None,
                "act_heads": None, "act_kv_heads": None, "act_ff": None,
                "act_vocab": None, "act_experts": None, "act_inner": None,
                "act_dv": None},
}


def run_cell(arch: str, shape_name: str, *, multi_pod: bool, remat: str,
             variant: str = "baseline", fsdp: str = "auto",
             coschedule: int = 0) -> dict:
    t0 = time.time()
    lowered, mesh = build_lowered(arch, shape_name, multi_pod=multi_pod,
                                  remat=remat, variant=variant, fsdp=fsdp,
                                  coschedule=coschedule)
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis() or {}
    coll = analyze_module(compiled.as_text())
    n_dev = mesh.size
    result = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "variant": variant, "remat": remat, "coschedule": coschedule,
        "fsdp": fsdp if fsdp != "auto" else
            ("on" if needs_fsdp(get_config(arch), tp_size(mesh)) else "off"),
        "n_devices": n_dev,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        # trip-count-expanded HLO walk (launch/hlo_analysis.py) — XLA's own
        # cost_analysis counts while bodies once, so its raw numbers are kept
        # only for reference.
        "flops_per_device": float(coll["dot_flops"]),
        "bytes_per_device": float(coll["io_bytes"]),
        "xla_flops_raw": cost.get("flops", 0.0),
        "xla_bytes_raw": cost.get("bytes accessed", 0.0),
        "collectives": coll,
        "memory": {
            "argument_gb": mem.argument_size_in_bytes / 1e9,
            "output_gb": mem.output_size_in_bytes / 1e9,
            "temp_gb": mem.temp_size_in_bytes / 1e9,
            "alias_gb": mem.alias_size_in_bytes / 1e9,
        } if mem else None,
        "ok": True,
    }
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--remat", default="full")
    ap.add_argument("--fsdp", default="auto", choices=("auto", "on", "off"))
    ap.add_argument("--coschedule", type=int, default=0,
                    help="prefill-chunk tokens co-scheduled with decode")
    ap.add_argument("--variant", default="baseline", choices=sorted(VARIANTS))
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    cells = []
    if args.all:
        for arch in ASSIGNED:
            for shape in applicable_shapes(get_config(arch)):
                cells.append((arch, shape.name))
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells.append((args.arch, args.shape))

    meshes = [args.multi_pod]
    if args.both_meshes:
        meshes = [False, True]

    failures = 0
    for arch, shape in cells:
        for mp in meshes:
            tag = f"{arch}__{shape}__{'2x16x16' if mp else '16x16'}" \
                  f"__{args.variant}" + (f"__{args.remat}" if args.remat != "full" else "")
            path = os.path.join(args.out, tag + ".json")
            if os.path.exists(path) and not args.force:
                print(f"[skip] {tag}", flush=True)
                continue
            print(f"[run ] {tag}", flush=True)
            try:
                res = run_cell(arch, shape, multi_pod=mp, remat=args.remat,
                               variant=args.variant, fsdp=args.fsdp,
                               coschedule=args.coschedule)
            except Exception as e:  # noqa: BLE001 — record the failure
                failures += 1
                res = {"arch": arch, "shape": shape,
                       "mesh": "2x16x16" if mp else "16x16",
                       "variant": args.variant, "ok": False,
                       "error": f"{type(e).__name__}: {e}",
                       "traceback": traceback.format_exc()[-2000:]}
            with open(path, "w") as f:
                json.dump(res, f, indent=1)
            if res["ok"]:
                mem = res["memory"]
                print(f"   ok: compile {res['compile_s']}s, "
                      f"flops/dev {res['flops_per_device']:.3e}, "
                      f"coll {res['collectives']['total_bytes']/1e9:.2f} GB/dev, "
                      f"args {mem['argument_gb']:.1f} GB", flush=True)
            else:
                print(f"   FAIL: {res['error']}", flush=True)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
