"""HLO-text analysis: FLOPs / HBM bytes / collective bytes with while-loop
trip-count expansion.

``compiled.cost_analysis()`` counts while bodies ONCE (verified empirically:
a 10-step scan of matmuls reports the FLOPs of one), and reports no
collective bytes at all — so we parse the SPMD-partitioned HLO ourselves:

  1. split the module into computations,
  2. per computation: dot FLOPs (2·out_elems·contract_size — validated exact
     against analytic counts), HBM io bytes (operand+output bytes of
     top-level instructions; fusion internals live in VMEM and are skipped),
     collective operand bytes, bf16→f32 upcast bytes,
  3. build the call graph (while bodies carry backend_config
     known_trip_count; call/conditional are ×1; fusion edges are
     FLOPs-only),
  4. DFS from the entry multiplying by enclosing trip counts.

All returned quantities are *per-device*.  Elementwise FLOPs (exp/tanh in
attention softmax and recurrent gates) are not counted — dots dominate; the
roofline methodology section documents this.
"""
from __future__ import annotations

import re
from typing import Optional

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1, "u64": 8, "u32": 4, "u16": 2,
    "u8": 1, "pred": 1, "c64": 8, "c128": 16, "s4": 1, "u4": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_TYPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DEF_RE = re.compile(r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.*)$")
# computation headers sit at column 0: `%name (params) -> type {` / `ENTRY ...`
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(")
_TRIP_RE = re.compile(r'known_trip_count[^}]*?"n"\s*:\s*"(\d+)"')


def _type_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _TYPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _first_dims(type_str: str) -> tuple[int, ...]:
    m = _TYPE_RE.search(type_str)
    if not m:
        return ()
    return tuple(int(d) for d in m.group(2).split(",") if d)


# ops whose operands/results are bookkeeping, not HBM traffic
_FREE_OPS = {"parameter", "get-tuple-element", "tuple", "constant", "bitcast",
             "after-all", "partition-id", "replica-id", "iota", "opt-barrier",
             "optimization-barrier"}


class Computation:
    def __init__(self, name: str):
        self.name = name
        self.defs: dict[str, int] = {}           # instr name -> result bytes
        self.coll: dict[str, int] = {op: 0 for op in COLLECTIVES}
        self.coll_count: dict[str, int] = {op: 0 for op in COLLECTIVES}
        self.calls: list[tuple[str, int]] = []   # (callee, multiplier)
        self.fusion_calls: list[str] = []        # fusion bodies (flops only)
        self.max_const: int = 0                  # largest s32 const (fallback)
        self.upcast_bytes: int = 0               # f32 outputs of bf16 converts
        self.dot_flops: int = 0                  # 2*out_elems*contract per dot
        self.io_bytes: int = 0                   # operand+output bytes of
                                                 # top-level (fused) instrs
        self.dims: dict[str, tuple[int, ...]] = {}


def _op_of(rhs: str) -> Optional[str]:
    """Op name after the result type.  Handles tuple types with layout
    annotations and /*index=k*/ comments by scanning for the first
    lowercase identifier followed by '(' at paren depth 0."""
    depth = 0
    i = 0
    n = len(rhs)
    while i < n:
        ch = rhs[i]
        if ch == "(":
            depth += 1
        elif ch == ")":
            depth -= 1
        elif depth == 0 and ch.isalpha():
            m = re.match(r"[a-z][a-z0-9\-]*", rhs[i:])
            if m:
                word = m.group(0)
                j = i + len(word)
                if j < n and rhs[j] == "(":
                    return word
                i = j
                continue
        i += 1
    return None


def parse_module(text: str) -> tuple[dict[str, Computation], Optional[str]]:
    comps: dict[str, Computation] = {}
    cur: Optional[Computation] = None
    entry: Optional[str] = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if line and not line[0].isspace():
            hdr = _COMP_HDR.match(line)
            if hdr:
                cur = Computation(hdr.group(2))
                comps[cur.name] = cur
                if hdr.group(1):
                    entry = cur.name
                continue
        if cur is None or "=" not in line:
            continue
        m = _DEF_RE.match(line)
        if not m:
            continue
        name, rhs = m.group(1), m.group(2)
        tm = re.match(r"(\(.*?\)|[a-z0-9]+\[[^\]]*\])(?=\S*\s+[a-z])", rhs)
        out_bytes = _type_bytes(tm.group(1)) if tm else 0
        if tm:
            cur.defs[name] = out_bytes
            cur.dims[name] = _first_dims(tm.group(1))
        cm = re.match(r"s32\[\]\s*constant\((\d+)\)", rhs)
        if cm:
            cur.max_const = max(cur.max_const, int(cm.group(1)))
        op = _op_of(rhs)
        if op is None:
            continue
        call = rhs.split(op + "(", 1)[1].split(")", 1)[0] if op + "(" in rhs \
            else ""
        args = re.findall(r"%([\w.\-]+)", call)
        base = op[:-6] if op.endswith("-start") else op
        if base in COLLECTIVES:
            cur.coll[base] += sum(cur.defs.get(a, 0) for a in args)
            cur.coll_count[base] += 1
        if op == "convert" and rhs.startswith("f32["):
            # bf16->f32 upcast (XLA-CPU artifact / ref-path accumulation):
            # native TPU bf16 execution never materializes these buffers.
            if args and cur.defs.get(args[0], 0) * 2 == out_bytes:
                cur.upcast_bytes += out_bytes
        if op == "dot":
            out_dims = _first_dims(rhs)
            lhs_dims = cur.dims.get(args[0], ()) if args else ()
            cd = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", rhs)
            csize = 1
            if cd and lhs_dims:
                for i in cd.group(1).split(","):
                    if i and int(i) < len(lhs_dims):
                        csize *= lhs_dims[int(i)]
            out_elems = 1
            for d in out_dims:
                out_elems *= d
            cur.dot_flops += 2 * out_elems * csize
        # HBM traffic: operands + output of every top-level (fused) instr
        if op not in _FREE_OPS and op not in ("while", "call", "conditional"):
            cur.io_bytes += out_bytes + sum(cur.defs.get(a, 0) for a in args)
        if op == "while":
            body = re.search(r"body=%?([\w.\-]+)", rhs)
            trip = _TRIP_RE.search(rhs)
            trips = int(trip.group(1)) if trip else 1   # conservative fallback
            if body:
                cur.calls.append((body.group(1), max(trips, 1)))
        elif op in ("call", "async-start"):
            cal = re.search(r"(?:to_apply|calls)=%?([\w.\-]+)", rhs)
            if cal:
                cur.calls.append((cal.group(1), 1))
        elif op == "fusion":
            cal = re.search(r"calls=%?([\w.\-]+)", rhs)
            if cal:
                cur.fusion_calls.append(cal.group(1))
        elif op == "conditional":
            for cal in re.findall(r"computations?=\{?%([\w.\-]+)", rhs):
                cur.calls.append((cal, 1))
    return comps, entry


def analyze_module(text: str) -> dict:
    """Trip-count-expanded per-device totals: dot FLOPs, HBM io bytes,
    collective bytes, upcast bytes.  (XLA's cost_analysis counts while
    bodies ONCE — verified empirically — so we expand ourselves.)"""
    comps, entry_name = parse_module(text)
    entry = comps.get(entry_name) if entry_name else None
    if entry is None and comps:
        entry = next(iter(comps.values()))

    totals = {op: 0.0 for op in COLLECTIVES}
    counts = {op: 0.0 for op in COLLECTIVES}
    acc = {"upcast": 0.0, "flops": 0.0, "io": 0.0}

    def visit(comp: Computation, mult: float, depth: int = 0) -> None:
        if depth > 48:
            return
        for op in COLLECTIVES:
            totals[op] += comp.coll[op] * mult
            counts[op] += comp.coll_count[op] * mult
        acc["upcast"] += comp.upcast_bytes * mult
        acc["flops"] += comp.dot_flops * mult
        acc["io"] += comp.io_bytes * mult
        for callee, trips in comp.calls:
            sub = comps.get(callee)
            if sub is not None:
                visit(sub, mult * trips, depth + 1)
        # fusion bodies: FLOPs only (their internals live in VMEM/registers)
        for callee in comp.fusion_calls:
            sub = comps.get(callee)
            if sub is not None:
                _visit_flops(sub, mult, depth + 1)

    def _visit_flops(comp: Computation, mult: float, depth: int = 0) -> None:
        if depth > 48:
            return
        acc["flops"] += comp.dot_flops * mult
        acc["upcast"] += comp.upcast_bytes * mult
        for callee, trips in comp.calls:
            sub = comps.get(callee)
            if sub is not None:
                _visit_flops(sub, mult * trips, depth + 1)
        for callee in comp.fusion_calls:
            sub = comps.get(callee)
            if sub is not None:
                _visit_flops(sub, mult, depth + 1)

    if entry is not None:
        visit(entry, 1.0)
    return {
        "bytes": {k: int(v) for k, v in totals.items()},
        "count": {k: int(v) for k, v in counts.items()},
        "total_bytes": int(sum(totals.values())),
        "total_count": int(sum(counts.values())),
        # f32 buffers materialized by bf16->f32 converts (per device, trip-
        # multiplied).  Native-bf16 traffic estimate: io_bytes - 2*upcast
        # (remove the f32 write + the consumer's f32 re-read, keep the
        # original bf16 read) — see EXPERIMENTS.md §Roofline methodology.
        "upcast_bytes": int(acc["upcast"]),
        "dot_flops": int(acc["flops"]),
        "io_bytes": int(acc["io"]),
    }


def collective_bytes(text: str) -> dict:   # back-compat alias
    return analyze_module(text)
