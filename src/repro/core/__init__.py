from repro.core import costmodel, nanobatch, pipeline  # noqa: F401
