"""NanoFlow §4.3: the operation-level pipeline (Figure 4) as an explicit
dependency graph over nano-batched operations.

The graph is consumed by ``autosearch`` (critical-path scheduling) and by
``benchmarks/resource_usage.py`` (Fig. 14 occupancy timeline).  Node kinds
carry the *bottleneck resource*; durations come from the cost model profiles.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

COMPUTE, MEMORY, NETWORK = "compute", "memory", "network"


@dataclasses.dataclass
class OpNode:
    name: str
    kind: str                      # compute | memory | network
    nano: int                      # nano-batch index
    work: float                    # seconds at full-device resource share
    deps: tuple[str, ...] = ()
    units: float = 1.0             # assigned execution-unit fraction (0..1]
    start: float = 0.0             # filled by the scheduler
    end: float = 0.0


@dataclasses.dataclass
class Pipeline:
    """One transformer layer's op graph, replicated per iteration."""
    nodes: dict[str, OpNode]
    nano_kqv: int                  # nano-batch counts (paper: 4 for KQV/GEMV)
    nano_dense: int                # and 2 for O/UGD/network ops

    def topo_order(self) -> list[OpNode]:
        order, seen = [], set()

        def visit(n: OpNode):
            if n.name in seen:
                return
            for d in n.deps:
                visit(self.nodes[d])
            seen.add(n.name)
            order.append(n)

        for n in self.nodes.values():
            visit(n)
        return order

    def critical_path(self) -> tuple[float, list[str]]:
        """Longest dependency chain under current durations (units applied)."""
        dist: dict[str, float] = {}
        pred: dict[str, Optional[str]] = {}
        for n in self.topo_order():
            base = max((dist[d] for d in n.deps), default=0.0)
            dist[n.name] = base + n.work / max(n.units, 1e-6)
            pred[n.name] = max(n.deps, key=lambda d: dist[d], default=None) \
                if n.deps else None
        end = max(dist, key=lambda k: dist[k])
        path, cur = [], end
        while cur is not None:
            path.append(cur)
            cur = pred[cur]
        return dist[end], list(reversed(path))


def build_nanoflow_pipeline(profiles: dict[str, tuple[str, float]], *,
                            nano_kqv: int = 4, nano_dense: int = 2,
                            has_network: bool = True,
                            has_decode_attn: bool = True) -> Pipeline:
    """Construct the paper's Figure-4 pipeline.

    ``profiles``: op base name -> (kind, seconds for the *whole* dense batch).
    Per-nano-batch work = total / nano_count.  Ops and dependencies follow
    Figure 4: KQV split 4-ways feeding GEMV (decode attention) 4-ways; O
    split 2-ways (O2 row-parallel: AR after, not AG before); UGD 2-ways; AG1
    after O1, AR after O2 overlapped by UGD1.
    """
    nodes: dict[str, OpNode] = {}

    def add(name, base, kind, nano, frac, deps=()):
        nodes[name] = OpNode(name=name, kind=kind, nano=nano,
                             work=base * frac, deps=tuple(deps))

    kqv_kind, kqv_t = profiles["KQV"]
    for i in range(nano_kqv):
        add(f"KQV{i+1}", kqv_t, kqv_kind, i, 1 / nano_kqv,
            deps=() if i == 0 else (f"KQV{i}",))

    last_attn: list[str] = []
    if has_decode_attn:
        gemv_kind, gemv_t = profiles["GEMV"]
        for i in range(nano_kqv):
            add(f"GEMV{i+1}", gemv_t, gemv_kind, i, 1 / nano_kqv,
                deps=(f"KQV{i+1}",))
        pf_kind, pf_t = profiles.get("PF", (COMPUTE, 0.0))
        if pf_t:
            add("PF", pf_t, pf_kind, 0, 1.0, deps=("KQV1",))
            last_attn.append("PF")
        last_attn += [f"GEMV{i+1}" for i in range(nano_kqv)]
    else:
        last_attn += [f"KQV{i+1}" for i in range(nano_kqv)]

    o_kind, o_t = profiles["O"]
    half = nano_kqv // nano_dense
    add("O1", o_t, o_kind, 0, 1 / nano_dense,
        deps=tuple(last_attn[: max(1, len(last_attn) // 2)]))
    add("O2", o_t, o_kind, 1, 1 / nano_dense, deps=tuple(last_attn))

    ug_kind, ug_t = profiles["UGD"]
    if has_network:
        ag_kind, ag_t = profiles["AG"]
        ar_kind, ar_t = profiles["AR"]
        add("AG1", ag_t, ag_kind, 0, 1 / nano_dense, deps=("O1",))
        # O2 is row-parallel: AR after it (overlapped by UGD1) — paper §4.3
        add("UGD1", ug_t, ug_kind, 0, 1 / nano_dense, deps=("AG1",))
        add("AR2", ar_t, ar_kind, 1, 1 / nano_dense, deps=("O2",))
        add("UGD2", ug_t, ug_kind, 1, 1 / nano_dense, deps=("AR2", "UGD1"))
        add("AG-next1", ag_t, ag_kind, 0, 1 / nano_dense, deps=("UGD1",))
        add("AG-next2", ag_t, ag_kind, 1, 1 / nano_dense, deps=("UGD2",))
    else:
        add("UGD1", ug_t, ug_kind, 0, 1 / nano_dense, deps=("O1",))
        add("UGD2", ug_t, ug_kind, 1, 1 / nano_dense, deps=("O2", "UGD1"))

    return Pipeline(nodes=nodes, nano_kqv=nano_kqv, nano_dense=nano_dense)


def sequential_pipeline(profiles: dict[str, tuple[str, float]], *,
                        has_network: bool = True,
                        has_decode_attn: bool = True) -> Pipeline:
    """The non-overlapping baseline (Fig. 3): every op depends on the last."""
    order = ["KQV"]
    if has_decode_attn:
        order += ["GEMV", "PF"]
    order += ["O"]
    if has_network:
        order += ["AG"]
    order += ["UGD"]
    if has_network:
        order += ["AG2", "AR"]
    nodes: dict[str, OpNode] = {}
    prev = None
    for name in order:
        base = name.rstrip("2")
        if base not in profiles or profiles[base][1] == 0.0:
            continue
        kind, t = profiles[base]
        nodes[name] = OpNode(name=name, kind=kind, nano=0, work=t,
                             deps=(prev,) if prev else ())
        prev = name
    return Pipeline(nodes=nodes, nano_kqv=1, nano_dense=1)
