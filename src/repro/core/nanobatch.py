"""NanoFlow §4.3: nano-batching — execution-level batch splitting.

On TPU the overlap itself is realized by (a) the XLA async-collective
scheduler once nano-batching has broken the all-or-nothing dependency chain,
(b) the decomposed collective matmul (distributed/collective_matmul.py) and
(c) the fused Pallas kernel (kernels/fused_overlap.py).  This module provides
the *semantics-preserving splitting machinery* those consumers share:

  * ``split``/``merge``        — slice a dense token batch into nano-batches
  * ``NanoBatchPlan``          — sizes chosen by autosearch (§5.5)
  * ``interleaved_apply``      — run a two-stage op pair over nano-batches in
                                 the paper's Figure-6 interleave order so the
                                 network stage of chunk i is dependency-free
                                 of the compute stage of chunk i+1

Correctness invariant (tested): for any plan, outputs equal the unsplit op.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class NanoBatchPlan:
    """Nano-batch sizes along the token axis.  sum(sizes) == batch tokens."""
    sizes: tuple[int, ...]

    @staticmethod
    def even(total: int, n: int) -> "NanoBatchPlan":
        base, rem = divmod(total, n)
        sizes = tuple(base + (1 if i < rem else 0) for i in range(n))
        return NanoBatchPlan(sizes=tuple(s for s in sizes if s > 0))

    @property
    def offsets(self) -> tuple[int, ...]:
        out, acc = [], 0
        for s in self.sizes:
            out.append(acc)
            acc += s
        return tuple(out)

    def assign_segments(self, lengths: Sequence[int]) -> tuple[int, ...]:
        """Map packed-stream segments (contiguous per-request token runs,
        laid out in order) to nano-batches: segment i belongs to the
        nano-batch containing its first token.  Recorded on ``PackedPlan``
        as observability for the TPU overlap path (which launches per
        nano-batch); the CPU ref path launches the stream whole, with its
        layout fixed by ``packed_segment_order``."""
        bounds = self.offsets + (sum(self.sizes),)
        out, pos = [], 0
        for ln in lengths:
            nb = 0
            while nb + 1 < len(self.sizes) and pos >= bounds[nb + 1]:
                nb += 1
            out.append(nb)
            pos += ln
        return tuple(out)


def split(x: jax.Array, plan: NanoBatchPlan, axis: int = 0) -> list[jax.Array]:
    assert x.shape[axis] == sum(plan.sizes), (x.shape, plan)
    outs, start = [], 0
    for s in plan.sizes:
        idx = [slice(None)] * x.ndim
        idx[axis] = slice(start, start + s)
        outs.append(x[tuple(idx)])
        start += s
    return outs


def merge(parts: Sequence[jax.Array], axis: int = 0) -> jax.Array:
    return jnp.concatenate(parts, axis=axis)


def interleaved_apply(stage_compute: Callable[[jax.Array], jax.Array],
                      stage_network: Callable[[jax.Array], jax.Array],
                      x: jax.Array, plan: NanoBatchPlan,
                      axis: int = 0) -> jax.Array:
    """Figure-6 interleave: Com(1) ; [Net(1) ∥ Com(2)] ; Net(2) ; ...

    In JAX the parallelism is expressed as *dependency freedom*: Net(i) only
    depends on Com(i), so the TPU latency-hiding scheduler overlaps Net(i)
    with Com(i+1).  Semantics are unchanged (tested vs the unsplit path).
    """
    chunks = split(x, plan, axis)
    computed = [stage_compute(c) for c in chunks]
    netted = [stage_network(c) for c in computed]
    return merge(netted, axis)


def packed_segment_order(kinds: Sequence[str],
                         lengths: Sequence[int]) -> tuple[int, ...]:
    """Figure-6 interleave order for the segments of a token-packed dense
    batch (the engine's single-dispatch step, DESIGN.md §8).

    Decode segments are memory-bound (KV-cache reads per token); prefill
    chunks are compute-bound (dense GEMMs over many tokens).  Issuing the
    memory-bound segments first and the compute-bound chunks in descending
    length gives the device scheduler the same dependency-freedom shape as
    ``interleaved_apply``: the cache reads of nano-batch i overlap the GEMMs
    of nano-batch i+1.  On the CPU ref path the order fixes the recurrent
    token-scan order and the stream layout; semantics are order-invariant
    (tested) because segments only touch their own slot's state.

    kinds: "decode" | "verify" | "prefill" per segment; lengths: token
    count per segment.  "verify" is a speculative-decoding verify segment
    (DESIGN.md §13) — a short multi-token run over one slot's KV tail,
    memory-bound like decode, so it rides in the decode group (stable
    order) rather than with the compute-bound prefill chunks its length
    would otherwise sort it into.  Returns the permutation of segment
    indices.
    """
    decode = [i for i, k in enumerate(kinds) if k in ("decode", "verify")]
    prefill = sorted((i for i, k in enumerate(kinds)
                      if k not in ("decode", "verify")),
                     key=lambda i: (-lengths[i], i))
    return tuple(decode + prefill)


def nano_batch_sizes_for(total_tokens: int, nano: int,
                         multiple_of: int = 8) -> NanoBatchPlan:
    """Sizes rounded to hardware-friendly multiples (paper's discrete
    batching insight applied at nano-batch granularity)."""
    if nano <= 1 or total_tokens <= multiple_of:
        return NanoBatchPlan((total_tokens,))
    base = max(multiple_of, (total_tokens // nano) // multiple_of * multiple_of)
    sizes = []
    left = total_tokens
    for _ in range(nano - 1):
        take = min(base, left)
        if take <= 0:
            break
        sizes.append(take)
        left -= take
    if left > 0:
        sizes.append(left)
    return NanoBatchPlan(tuple(sizes))
