"""NanoFlow §5.5: automatic parameter search.

Topological sort -> critical path -> greedy unit re-assignment, iterated over
nano-batch size combinations, exactly as the paper describes — with the GPU
"SM fraction" knob replaced by the TPU resource-share knob (DESIGN.md §2):
the fraction of interleaved grid steps / collective chunks an op receives.

Non-linearity (paper Fig. 7): an op at unit share u runs at relative
efficiency eff(u) = min(1, u / u_sat), u_sat per resource kind — network
kernels saturate at ~32% of units reaching ~92% throughput; memory streams
saturate around 60%; compute is linear to 100%.  We encode the same shape.

The search consumes *offline profiles* from the analytical cost model (this
container has no TPU to profile); on hardware the same interface accepts
measured profiles.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Optional

from repro.core import costmodel as cm
from repro.core.pipeline import (COMPUTE, MEMORY, NETWORK, OpNode, Pipeline,
                                 build_nanoflow_pipeline, sequential_pipeline)

# resource-share saturation points (paper Fig. 7 shape, TPU interpretation)
U_SAT = {COMPUTE: 1.0, MEMORY: 0.6, NETWORK: 0.32}


def efficiency(kind: str, units: float) -> float:
    return min(1.0, units / U_SAT[kind])


@dataclasses.dataclass
class Schedule:
    pipeline: Pipeline
    iter_time: float               # seconds per layer-iteration
    critical_path: list[str]
    unit_assignment: dict[str, float]
    nano_kqv: int
    nano_dense: int
    compute_busy: float            # fraction of iter_time compute is active

    def summary(self) -> dict:
        return {
            "iter_time_ms": self.iter_time * 1e3,
            "critical_path": "->".join(self.critical_path),
            "nano_kqv": self.nano_kqv, "nano_dense": self.nano_dense,
            "compute_busy": round(self.compute_busy, 4),
            "units": {k: round(v, 3) for k, v in self.unit_assignment.items()},
        }


def _schedule_times(pipe: Pipeline) -> float:
    """Resource-aware list scheduling under two constraints (DESIGN.md §2):

      (a) execution-unit budget: Σ units of ALL in-flight ops ≤ 1.0
          (the SM-partition / grid-partition budget);
      (b) bandwidth: Σ rate of in-flight ops of the SAME kind ≤ 1.0, where
          rate = eff(units) — two network kernels can each saturate the wire
          with 32% of the units, but they still share one wire.

    Fills node.start/end; returns makespan."""
    order = pipe.topo_order()
    running: list[OpNode] = []
    time = 0.0
    ready: dict[str, float] = {}
    for n in order:
        t_ready = max((ready[d] for d in n.deps), default=0.0)
        rate = max(efficiency(n.kind, n.units), 1e-9)
        dur = n.work / rate
        events = sorted({t_ready} | {r.end for r in running if r.end > t_ready})
        start = None
        for t0 in events:
            units_inflight = sum(r.units for r in running
                                 if r.start <= t0 < r.end)
            rate_inflight = sum(efficiency(r.kind, r.units) for r in running
                                if r.kind == n.kind and r.start <= t0 < r.end)
            if (units_inflight + n.units <= 1.0 + 1e-9
                    and rate_inflight + rate <= 1.0 + 1e-9):
                start = t0
                break
        if start is None:
            start = max((r.end for r in running), default=t_ready)
        n.start, n.end = start, start + dur
        ready[n.name] = n.end
        running.append(n)
        time = max(time, n.end)
    return time


def _greedy_units(pipe: Pipeline, *, iters: int = 64) -> float:
    """Paper's greedy loop: assign more units to critical-path ops, bounded
    by the total unit budget per overlapping set; re-derive the critical path
    each round until converged."""
    # start with a partition that leaves overlap headroom: compute takes the
    # bulk, memory/network take (roughly) their saturation shares — the
    # paper's Fig.-7 insight that small unit shares already saturate them.
    init = {COMPUTE: 0.6, MEMORY: 0.25, NETWORK: 0.32}
    for n in pipe.nodes.values():
        n.units = init[n.kind]
    best = _schedule_times(pipe)
    for _ in range(iters):
        _, path = pipe.critical_path()
        changed = False
        for name in path:
            n = pipe.nodes[name]
            if n.units < 1.0 - 1e-6:
                old = n.units
                n.units = min(1.0, n.units + 0.125)
                t = _schedule_times(pipe)
                if t < best - 1e-12:
                    best = t
                    changed = True
                else:
                    n.units = old
                    _schedule_times(pipe)
        # try shrinking off-path ops (frees resource headroom for overlap)
        for n in pipe.nodes.values():
            if n.name in path or n.units <= 0.25:
                continue
            old = n.units
            n.units = max(0.25, n.units - 0.125)
            t = _schedule_times(pipe)
            if t < best - 1e-12:
                best = t
                changed = True
            else:
                n.units = old
                _schedule_times(pipe)
        if not changed:
            break
    return best


def _profiles_from_costs(cfg, workload: cm.Workload, hw: cm.Hardware,
                         n_dev: int, bdense: Optional[float] = None
                         ) -> dict[str, tuple[str, float]]:
    """Collapse the Table-2 op costs into the Figure-4 op classes."""
    costs = cm.op_costs(cfg, workload, hw, n_dev, bdense)
    per_layer = 1.0 / max(cfg.n_layers, 1)

    def t_of(c: cm.OpCost) -> float:
        return max(c.times(hw, n_dev)) * per_layer

    prof: dict[str, tuple[str, float]] = {}
    acc: dict[str, float] = {}
    kindmap: dict[str, str] = {}
    for c in costs:
        if c.name.startswith(("GEMM-KQV", "GEMM-Q", "GEMM-KV")):
            key = "KQV"
        elif c.name.startswith("GEMM-O"):
            key = "O"
        elif c.name.startswith(("GEMM-UG", "GEMM-D", "MoE")) \
                and "AllToAll" not in c.name:
            key = "UGD"
        elif c.name == "DecodeAttention" or c.name == "RecurrentScan":
            key = "GEMV"
        elif c.name == "PrefillAttention":
            key = "PF"
        elif "AG" in c.name:
            key = "AG"
        elif "AR" in c.name or "AllToAll" in c.name:
            key = "AR"
        else:
            key = "UGD"
        acc[key] = acc.get(key, 0.0) + t_of(c)
        kindmap.setdefault(key, c.kind)
    for k, t in acc.items():
        prof[k] = (kindmap[k], t)
    for k in ("KQV", "O", "UGD", "GEMV", "PF", "AG", "AR"):
        prof.setdefault(k, (COMPUTE if k in ("KQV", "O", "UGD", "PF")
                            else (MEMORY if k == "GEMV" else NETWORK), 0.0))
    return prof


def autosearch(cfg, workload: cm.Workload, hw: cm.Hardware = cm.TPU_V5E,
               n_dev: int = 256, *, bdense: Optional[float] = None,
               nano_kqv_options=(2, 4), nano_dense_options=(2,),
               has_network: Optional[bool] = None) -> Schedule:
    """Search nano-batch counts × unit assignments; return the best schedule."""
    prof = _profiles_from_costs(cfg, workload, hw, n_dev, bdense)
    if has_network is None:
        has_network = n_dev > 1 and (prof["AG"][1] > 0 or prof["AR"][1] > 0)
    best: Optional[Schedule] = None
    for nk, nd in itertools.product(nano_kqv_options, nano_dense_options):
        pipe = build_nanoflow_pipeline(
            prof, nano_kqv=nk, nano_dense=nd, has_network=has_network,
            has_decode_attn=prof["GEMV"][1] > 0)
        t = _greedy_units(pipe)
        _, path = pipe.critical_path()
        busy = _compute_busy(pipe, t)
        sched = Schedule(pipeline=pipe, iter_time=t, critical_path=path,
                         unit_assignment={n.name: n.units
                                          for n in pipe.nodes.values()},
                         nano_kqv=nk, nano_dense=nd, compute_busy=busy)
        if best is None or t < best.iter_time:
            best = sched
    assert best is not None
    # the search space includes the non-overlapped plan: when overlap can't
    # win (tiny models, no network/GEMV to hide) deploy sequential (nano=1)
    seq = sequential_schedule(cfg, workload, hw, n_dev, bdense=bdense)
    if seq.iter_time < best.iter_time:
        return seq
    return best


def sequential_schedule(cfg, workload: cm.Workload,
                        hw: cm.Hardware = cm.TPU_V5E, n_dev: int = 256, *,
                        bdense: Optional[float] = None,
                        nano_split: int = 1) -> Schedule:
    """Non-overlap baseline (paper Fig. 3 / ablation Fig. 13).

    nano_split > 1 models the 'nano-batch-only' ablation: the batching-
    efficiency penalty of splitting without overlapping (paper: ~13.2% at 4
    splits — we charge the dense ops the paper's measured efficiency loss)."""
    prof = _profiles_from_costs(cfg, workload, hw, n_dev, bdense)
    if nano_split > 1:
        penalty = 1.0 + 0.132 * (nano_split / 4.0)
        prof = {k: (kind, t * penalty if kind == COMPUTE else t)
                for k, (kind, t) in prof.items()}
    pipe = sequential_pipeline(prof, has_network=n_dev > 1,
                               has_decode_attn=prof["GEMV"][1] > 0)
    t = _schedule_times(pipe)
    _, path = pipe.critical_path()
    return Schedule(pipeline=pipe, iter_time=t, critical_path=path,
                    unit_assignment={n.name: n.units for n in pipe.nodes.values()},
                    nano_kqv=1, nano_dense=1,
                    compute_busy=_compute_busy(pipe, t))


def _compute_busy(pipe: Pipeline, total: float) -> float:
    if total <= 0:
        return 0.0
    # union of compute-op intervals
    ivals = sorted((n.start, n.end) for n in pipe.nodes.values()
                   if n.kind == COMPUTE and n.end > n.start)
    busy, cur_s, cur_e = 0.0, None, None
    for s, e in ivals:
        if cur_s is None:
            cur_s, cur_e = s, e
        elif s <= cur_e:
            cur_e = max(cur_e, e)
        else:
            busy += cur_e - cur_s
            cur_s, cur_e = s, e
    if cur_s is not None:
        busy += cur_e - cur_s
    return busy / total


def throughput_estimate(cfg, sched: Schedule, workload: cm.Workload,
                        hw: cm.Hardware = cm.TPU_V5E, n_dev: int = 256,
                        bdense: Optional[float] = None) -> float:
    """tokens/s/device implied by a schedule (layer iter time × n_layers),
    clamped at the Eq.-9 bound (the per-layer profile sum slightly
    under-counts embedding/head work for shallow, attention-free models)."""
    ms = cm.model_stats(cfg)
    bd = bdense if bdense is not None else cm.b_dense(hw, ms, workload, n_dev)
    iter_total = sched.iter_time * cfg.n_layers
    opt = cm.optimal_throughput(hw, ms, n_dev) / n_dev
    return min(bd / iter_total / n_dev, opt)
