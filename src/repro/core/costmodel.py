"""NanoFlow §3: analytical cost model of LLM serving.

Implements Eqs. 1–9 and the Table-2 per-operation breakdown, parameterized by
(hardware, model config, user query statistics).  Used by:
  * ``benchmarks/workload_class.py``  — Fig. 2 reproduction (T_R classifier)
  * ``benchmarks/cost_model_validation.py`` — Table 2 reproduction
  * ``core/autosearch.py``            — offline op profiles for the schedule
  * ``benchmarks/roofline.py``        — v5e roofline terms

Hardware table reproduces the paper's Table 1 (GPUs) and adds the TPU v5e
target of this repo (197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI).
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.configs.base import ATTN, ModelConfig


@dataclasses.dataclass(frozen=True)
class Hardware:
    name: str
    compute: float          # FLOP/s (peak, serving dtype)
    mem_bw: float           # B/s
    mem_size: float         # B per chip
    net_bw: float           # B/s per chip (interconnect, one-way)
    year: int = 0

    @property
    def ratio(self) -> float:
        """FLOP per byte of HBM — paper Table 1 last column (~250 modern)."""
        return self.compute / self.mem_bw

    @property
    def net_bw_oneway(self) -> float:
        """Paper Table-2 footnote: one-way bandwidth for T_net."""
        return self.net_bw / 2


TB, GB = 1e12, 1e9

HARDWARE: dict[str, Hardware] = {h.name: h for h in [
    Hardware("V100", 125e12, 900 * GB, 32 * GB, 300 * GB, 2017),
    Hardware("A100-40G", 312e12, 1555 * GB, 40 * GB, 600 * GB, 2020),
    Hardware("A100-80G", 312e12, 2000 * GB, 80 * GB, 600 * GB, 2021),
    Hardware("H100", 989e12, 3352 * GB, 80 * GB, 600 * GB, 2023),
    Hardware("H200", 989e12, 4800 * GB, 141 * GB, 900 * GB, 2024),
    Hardware("B100", 1800e12, 8000 * GB, 192 * GB, 1800 * GB, 2024),
    Hardware("B200", 2250e12, 8000 * GB, 192 * GB, 1800 * GB, 2024),
    Hardware("MI250", 362e12, 3352 * GB, 128 * GB, 800 * GB, 2021),
    Hardware("MI300", 1307e12, 5300 * GB, 192 * GB, 1024 * GB, 2023),
    # This repo's target (assignment constants: 197 TF bf16, 819 GB/s HBM,
    # ~50 GB/s/link ICI one-way => 100 GB/s bidirectional here).
    Hardware("TPUv5e", 197e12, 819 * GB, 16 * GB, 100 * GB, 2023),
]}

TPU_V5E = HARDWARE["TPUv5e"]
A100_80G = HARDWARE["A100-80G"]


@dataclasses.dataclass(frozen=True)
class Workload:
    """User query statistics (paper §3.1): avg prompt / decode lengths."""
    p: float
    d: float
    name: str = ""


# paper's evaluation workloads (Table 3)
WORKLOADS = {
    "splitwise": Workload(1155, 211, "splitwise"),
    "lmsys": Workload(102, 222, "lmsys"),
    "sharegpt": Workload(246, 322, "sharegpt"),
    "const_512_1024": Workload(512, 1024, "const_512_1024"),
    "const_1024_512": Workload(1024, 512, "const_1024_512"),
}


@dataclasses.dataclass(frozen=True)
class ModelStats:
    """The model-side quantities the paper's equations consume."""
    p_model: int             # total params
    p_active: int            # active params / token (MoE)
    d_model: int
    n_layers: int
    r_gqa: float             # q heads per kv head
    kv_per_token: int        # KV-cache elements per token (all layers)
    dtype_bytes: int = 2
    # bytes per stored KV element (DESIGN.md §15).  Equals ``dtype_bytes``
    # for native caches; for int8 it is <~1.1: one byte per element plus the
    # amortized f32 per-(row, kv-head) scale overhead.  Weight/activation
    # terms keep using ``dtype_bytes`` — only the cache residency (e_kv) and
    # the KV streaming terms (DecodeAttention, prefill KV write) see it.
    kv_bytes_per_elem: float = 0.0

    def __post_init__(self):
        if self.kv_bytes_per_elem == 0.0:
            object.__setattr__(self, "kv_bytes_per_elem",
                               float(self.dtype_bytes))


def model_stats(cfg: ModelConfig,
                kv_dtype: Optional[str] = None) -> ModelStats:
    """``kv_dtype`` mirrors ``EngineConfig.kv_dtype``: None/"bf16" keeps the
    serving dtype for the cache; "int8" prices the quantized layout —
    1 B/element plus one f32 scale per (row, kv-head) for GQA or per row
    (latent + rope leaves) for absorbed MLA."""
    from repro.models.model import active_params, num_params
    kv_elems = 0
    kv_bytes = 0.0
    hd = cfg.resolved_head_dim
    for spec in cfg.layer_specs():
        if spec.mixer == ATTN:
            if cfg.mla is not None:
                e = cfg.mla.kv_lora_rank + cfg.mla.qk_rope_dim
                s = 2                       # c_kv + k_rope scale rows
            else:
                e = 2 * cfg.n_kv_heads * hd
                s = 2 * cfg.n_kv_heads      # k + v scale per kv head
            kv_elems += e
            kv_bytes += e * 1 + s * 4       # int8 pricing (scales are f32)
        # recurrent mixers hold O(1) state — no per-token KV
    dt = 2 if cfg.dtype in ("bfloat16", "float16") else 4
    return ModelStats(
        p_model=num_params(cfg),
        p_active=active_params(cfg),
        d_model=cfg.d_model,
        n_layers=cfg.n_layers,
        r_gqa=cfg.n_heads / max(cfg.n_kv_heads, 1),
        kv_per_token=kv_elems,
        dtype_bytes=dt,
        kv_bytes_per_elem=(kv_bytes / kv_elems
                           if kv_dtype == "int8" and kv_elems else float(dt)),
    )


# ---------------------------------------------------------------------------
# Eqs. 1–9
# ---------------------------------------------------------------------------
def e_kv(hw: Hardware, ms: ModelStats, n_dev: int) -> float:
    """Max KV-cache elements the cluster can hold (Appendix A).

    Weights stay at ``dtype_bytes``; the leftover bytes are divided by the
    cache's *storage* rate (``kv_bytes_per_elem``), so an int8 cache holds
    ~2x the elements at the same residency (DESIGN.md §15)."""
    free = n_dev * hw.mem_size - ms.p_model * ms.dtype_bytes
    return max(free / ms.kv_bytes_per_elem, 0.0)


def b_req(hw: Hardware, ms: ModelStats, w: Workload, n_dev: int) -> float:
    """Eq. 5 — largest request batch the KV capacity sustains."""
    if ms.kv_per_token == 0:
        # attention-free: state is O(1); batch bounded by activations — use a
        # large nominal cap so dense batch is compute-limited instead.
        return 4096.0
    per_req = (w.p + w.d / 2) * ms.kv_per_token
    return e_kv(hw, ms, n_dev) / per_req


def b_dense(hw: Hardware, ms: ModelStats, w: Workload, n_dev: int) -> float:
    """Eq. 2 — average dense-op token batch per iteration."""
    return b_req(hw, ms, w, n_dev) * (w.p + w.d) / (w.d + 1)


def t_mem(hw: Hardware) -> float:
    """Eq. 1 — whole-device-memory sweep per iteration."""
    return hw.mem_size / hw.mem_bw


def t_compute(hw: Hardware, ms: ModelStats, w: Workload, n_dev: int,
              bdense: Optional[float] = None) -> float:
    """Eq. 3/4/6 — dense-GEMM-dominated compute time per iteration."""
    bd = bdense if bdense is not None else b_dense(hw, ms, w, n_dev)
    return 2 * bd * ms.p_active / (n_dev * hw.compute)


def t_net(hw: Hardware, ms: ModelStats, w: Workload, n_dev: int,
          bdense: Optional[float] = None) -> float:
    """Eq. 7 — two AllGathers + one AllReduce of the dense activations."""
    bd = bdense if bdense is not None else b_dense(hw, ms, w, n_dev)
    total = 4 * bd * ms.d_model * ms.dtype_bytes * ms.n_layers
    return total / (n_dev * hw.net_bw)


def t_r(hw: Hardware, ms: ModelStats, w: Workload, n_dev: int) -> float:
    """Eq. 8 — memory/compute time ratio.  >1 memory-bound, <1 compute-bound."""
    return t_mem(hw) / t_compute(hw, ms, w, n_dev)


def classify(hw: Hardware, ms: ModelStats, w: Workload, n_dev: int) -> str:
    tr = t_r(hw, ms, w, n_dev)
    tn = t_net(hw, ms, w, n_dev) / t_compute(hw, ms, w, n_dev)
    if tn > 1 and tn > tr:
        return "network-bound"
    return "memory-bound" if tr > 1 else "compute-bound"


def optimal_throughput(hw: Hardware, ms: ModelStats, n_dev: int) -> float:
    """Eq. 9 — tokens/s at full compute utilization (total, prefill+decode).

    Depends only on aggregate compute and (active) parameter count."""
    return n_dev * hw.compute / (2 * ms.p_active)


# ---------------------------------------------------------------------------
# Table 2: per-operation resource usage for one iteration
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class OpCost:
    name: str
    kind: str                # compute | memory | network
    flops: float
    mem_bytes: float
    net_bytes: float

    def times(self, hw: Hardware, n_dev: int) -> tuple[float, float, float]:
        # T_net uses one-way bandwidth (paper Table-2 footnote 5)
        return (self.flops / (n_dev * hw.compute),
                self.mem_bytes / (n_dev * hw.mem_bw),
                self.net_bytes / (n_dev * hw.net_bw_oneway))

    def bound(self, hw: Hardware, n_dev: int) -> str:
        tc, tm, tn = self.times(hw, n_dev)
        return ("compute", "memory", "network")[max(range(3), key=lambda i: (tc, tm, tn)[i])]


def op_costs(cfg: ModelConfig, w: Workload, hw: Hardware, n_dev: int,
             bdense: Optional[float] = None,
             kv_dtype: Optional[str] = None) -> list[OpCost]:
    """NanoFlow Table-2-style per-op breakdown, generalized over configs.

    All quantities are *global* (whole iteration across all layers / devices);
    divide by n_dev for per-device.  Decode attention loads the entire KV
    cache once (paper's model); prefill attention is quadratic in p.
    ``kv_dtype="int8"`` prices the quantized cache (DESIGN.md §15): more
    resident elements at fewer bytes each, so DecodeAttention streams the
    bigger cache at the int8 rate and prefill's KV writes shrink.
    """
    ms = model_stats(cfg, kv_dtype)
    dt = ms.dtype_bytes
    bd = bdense if bdense is not None else b_dense(hw, ms, w, n_dev)
    breq = b_req(hw, ms, w, n_dev)
    d, L, hd = cfg.d_model, cfg.n_layers, cfg.resolved_head_dim
    nh, kv = cfg.n_heads, cfg.n_kv_heads

    costs: list[OpCost] = []

    def gemm(name, n_in, n_out, count=1.0, batch=None):
        b = bd if batch is None else batch
        w_bytes = n_in * n_out * dt * count
        costs.append(OpCost(
            name, "compute",
            flops=2 * b * n_in * n_out * count * L,
            mem_bytes=(w_bytes + b * (n_in + n_out) * dt * count) * L,
            net_bytes=0.0))

    if cfg.mla is not None:
        m = cfg.mla
        qk = m.qk_nope_dim + m.qk_rope_dim
        gemm("GEMM-Q(lora)", d, m.q_lora_rank)
        gemm("GEMM-Q(up)", m.q_lora_rank, nh * qk)
        gemm("GEMM-KV(lora)", d, m.kv_lora_rank + m.qk_rope_dim)
        gemm("GEMM-KV(up)", m.kv_lora_rank, nh * (m.qk_nope_dim + m.v_head_dim))
        gemm("GEMM-O", nh * m.v_head_dim, d)
    else:
        gemm("GEMM-KQV", d, (nh + 2 * kv) * hd)
        gemm("GEMM-O", nh * hd, d)

    if cfg.moe is not None:
        mo = cfg.moe
        n_moe = sum(1 for s in cfg.layer_specs() if "moe" in s.ffn)
        n_dense = sum(1 for s in cfg.layer_specs() if s.ffn == "dense"
                      or s.ffn == "moe+dense")
        if n_dense:
            ug = 2 * bd * d * (2 * cfg.d_ff) * n_dense
            dn = 2 * bd * d * cfg.d_ff * n_dense
            costs.append(OpCost("GEMM-UG(dense)", "compute", ug,
                                (2 * d * cfg.d_ff * dt + bd * (d + 2 * cfg.d_ff) * dt) * n_dense, 0))
            costs.append(OpCost("GEMM-D(dense)", "compute", dn,
                                (d * cfg.d_ff * dt + bd * (cfg.d_ff + d) * dt) * n_dense, 0))
        # routed experts: top_k active per token; weights for *all* experts
        # stream from HBM only insofar as tokens hit them — at large batch all
        # experts are hit, so weight bytes = full expert set.
        eff = mo.expert_d_ff
        act_flops = 2 * bd * mo.top_k * d * 3 * eff * n_moe
        w_bytes = mo.num_experts * 3 * d * eff * dt * n_moe
        costs.append(OpCost("MoE-experts", "compute", act_flops,
                            w_bytes + bd * mo.top_k * (2 * d + 3 * eff) * dt * n_moe, 0))
        if mo.num_shared_experts:
            sh = mo.shared_d_ff
            costs.append(OpCost("MoE-shared", "compute",
                                2 * bd * d * 3 * sh * n_moe,
                                (3 * d * sh * dt + bd * (2 * d + 3 * sh) * dt) * n_moe, 0))
        costs.append(OpCost("MoE-router", "compute",
                            2 * bd * d * mo.num_experts * n_moe,
                            (d * mo.num_experts * dt + bd * d * dt) * n_moe, 0))
        # EP all-to-all: tokens leave/return to their home shard
        a2a = 2 * bd * mo.top_k * d * dt * n_moe
        costs.append(OpCost("MoE-AllToAll", "network", 0, a2a, a2a))
    elif cfg.d_ff:
        gemm("GEMM-UG", d, (2 if cfg.ffn_gated else 1) * cfg.d_ff)
        gemm("GEMM-D", cfg.d_ff, d)

    # ---- attention ----
    if ms.kv_per_token:
        # decode attention: stream the whole KV cache (memory-bound GEMV).
        # Bytes use the cache *storage* rate — int8 streams ~2x the elements
        # at ~half the bytes each, so the byte term is ~unchanged while the
        # resident batch (b_req) doubles (DESIGN.md §15).
        kv_bytes = e_kv(hw, ms, n_dev) * ms.kv_bytes_per_elem
        dec_flops = 2 * e_kv(hw, ms, n_dev) * ms.r_gqa
        costs.append(OpCost("DecodeAttention", "memory", dec_flops, kv_bytes, 0))
        # prefill attention: (B_req/(d+1)) requests of p tokens, 4·p²·D per layer
        n_prefill = breq / (w.d + 1)
        pf_flops = 4 * n_prefill * w.p * w.p * d * L
        pf_bytes = n_prefill * w.p * (
            2 * (ms.kv_per_token / L) * ms.kv_bytes_per_elem
            + 2 * nh * hd * dt) * L
        costs.append(OpCost("PrefillAttention", "compute", pf_flops, pf_bytes, 0))
    else:
        # recurrent mixers: state update streams the state per token
        costs.append(OpCost("RecurrentScan", "memory",
                            2 * bd * d * 32 * L, bd * d * 32 * dt * L, 0))

    # ---- TP collectives: 2 AG + 1 AR of the dense activations (paper §2.3).
    # Wire bytes include the (N-1)/N ring amplification so the Table-2 row
    # reproduces the paper's 75.2 GB for LLaMA-2-70B @ B_dense=2048, TP=8.
    act = bd * d * dt * L
    costs.append(OpCost("Comm-AG1", "network", 0, act,
                        act * (n_dev - 1) if n_dev > 1 else 0))
    costs.append(OpCost("Comm-AG2", "network", 0, act,
                        act * (n_dev - 1) if n_dev > 1 else 0))
    costs.append(OpCost("Comm-AR", "network",
                        (n_dev - 1) * bd * d * L, 2 * act,
                        2 * act * (n_dev - 1) if n_dev > 1 else 0))
    return costs


def table2(cfg: ModelConfig, w: Workload, hw: Hardware, n_dev: int,
           bdense: Optional[float] = None,
           kv_dtype: Optional[str] = None) -> list[dict]:
    """Paper Table 2 rows: per-op estimated times + the dominant resource."""
    rows = []
    for c in op_costs(cfg, w, hw, n_dev, bdense, kv_dtype):
        tc, tm, tn = c.times(hw, n_dev)
        rows.append({
            "op": c.name, "kind": c.kind,
            "gflops": c.flops / 1e9, "mem_gb": c.mem_bytes / 1e9,
            "net_gb": c.net_bytes / 1e9,
            "t_compute_ms": tc * 1e3, "t_mem_ms": tm * 1e3, "t_net_ms": tn * 1e3,
            "bound": c.bound(hw, n_dev),
        })
    return rows
