"""Block-table KV cache: refcounted fixed-size blocks, cross-request prefix
sharing, copy-on-write, pluggable eviction (paper §4.4 / §5.4; DESIGN.md §12).

The allocator manages a pool of ``total_pages`` fixed-size blocks (one block
= ``page_size`` token rows of every attention-cache leaf).  Each request
holds a *block table* — an ordered list of block ids — instead of a
contiguous slot row, which buys:

  * **Cross-request prefix caching** — full blocks are content-hashed with a
    chained digest (parent digest + the block's token ids, so a block's key
    pins its entire prefix).  A new request's prompt is matched block-by-
    block against the hash table; matched blocks are shared (refcount++) and
    their tokens are never prefilled again (``KVStats.prefix_hit_tokens``).
  * **Copy-on-write** — a shared or hash-registered block is immutable.  A
    request that diverges mid-block gets a private copy: a fresh block is
    allocated, a (src, dst) device copy is queued (the engine drains
    ``take_pending_copies()`` before its next dispatch), and only the copy
    is written (``KVStats.cow_copies``).
  * **Pluggable eviction** — blocks whose refcount drops to 0 but that are
    still hash-registered go to the ``Evictor`` (default LRU) instead of
    the free list: they keep serving prefix hits until capacity pressure
    reclaims them (``KVStats.evicted_blocks``).

Accounting (peak-memory admission, host offload pool) is unchanged from the
page-granular design: blocks are the unit of admission control, the §4.4
finish-time sweep runs on launch-side state (committed + in-flight tokens,
DESIGN.md §10), and finished requests' KV is offloaded to a host LRU pool.
With ``prefix_caching=False`` (the default) no block is ever shared or
registered and the allocator behaves exactly like the per-request paged
accounting it replaces.
"""
from __future__ import annotations

import dataclasses
import hashlib
from collections import OrderedDict
from typing import Iterable, Optional, Protocol, Sequence, runtime_checkable

import numpy as np

from repro.serving.request import Request


@dataclasses.dataclass
class KVStats:
    device_pages_total: int
    device_pages_used: int = 0
    host_bytes: int = 0
    offload_bytes: int = 0          # cumulative D2H traffic
    upload_bytes: int = 0           # cumulative H2D traffic
    aggregated_copies: int = 0
    discarded_requests: int = 0
    # extend() calls that found no free page: admission overshoot — the
    # peak estimate promised room that launch-time growth consumed.  Must
    # stay 0 now that peak_pages counts in-flight tokens (regression
    # signal; tests/test_kv_accounting.py)
    extend_failures: int = 0
    # ---- prefix caching (DESIGN.md §12) ------------------------------------
    prefix_hit_tokens: int = 0      # prompt tokens served from shared blocks
    cow_copies: int = 0             # block copies queued on divergence
    evicted_blocks: int = 0         # cached ref-0 blocks reclaimed

    def snapshot(self) -> dict:
        """Common stats schema (consumed by serve.py prints, benchmark JSON
        artifacts, and tests): every counter field, plainly."""
        return {f.name: getattr(self, f.name)
                for f in dataclasses.fields(self)}


class Evictor(Protocol):
    """Eviction policy over cached-but-unreferenced blocks: blocks enter
    when their refcount drops to 0 while still hash-registered, leave either
    by being re-shared (``remove``) or reclaimed for allocation (``pop``)."""

    def add(self, block: int) -> None: ...
    def remove(self, block: int) -> None: ...
    def pop(self) -> int: ...
    def __len__(self) -> int: ...
    def __contains__(self, block: int) -> bool: ...


class LRUEvictor:
    """Default policy: reclaim the least-recently-cached block first."""

    def __init__(self):
        self._order: OrderedDict[int, None] = OrderedDict()

    def add(self, block: int) -> None:
        self._order[block] = None
        self._order.move_to_end(block)

    def remove(self, block: int) -> None:
        self._order.pop(block, None)

    def pop(self) -> int:
        block, _ = self._order.popitem(last=False)
        return block

    def __len__(self) -> int:
        return len(self._order)

    def __contains__(self, block: int) -> bool:
        return block in self._order


@runtime_checkable
class BlockAllocator(Protocol):
    """The engine/scheduler-facing cache interface (DESIGN.md §12).  All
    sizes are token counts; all storage is block-granular.  Implementations
    must keep the invariants the engine relies on:

      * a block with refcount > 0 is never freed or handed out again;
      * hash-table entries only point at *immutable* full blocks (registered
        blocks are never written in place — divergence copies first);
      * ``allocate``/``ensure``/``extend`` never hand the same block to two
        tables without bumping its refcount.
    """

    page_size: int
    bytes_per_token: int
    stats: KVStats

    def pages_for(self, tokens: int) -> int: ...
    def peak_pages(self, active: list[Request],
                   candidate: Optional[Request] = None) -> int: ...
    def can_admit(self, req: Request, active: list[Request]) -> bool: ...
    def allocate(self, rid: int, tokens: int, *,
                 token_ids: Optional[Sequence[int]] = None) -> bool: ...
    def extend(self, rid: int, new_len: int, *,
               token_ids: Optional[Sequence[int]] = None) -> bool: ...
    def ensure(self, rid: int, new_len: int) -> bool: ...
    def free(self, rid: int) -> None: ...
    def table(self, rid: int) -> list[int]: ...
    def cached_tokens(self, rid: int) -> int: ...
    def take_pending_copies(self) -> list[tuple[int, int]]: ...
    def offload(self, rid: int, kv_data: Optional[np.ndarray] = None, *,
                nbytes: Optional[int] = None) -> None: ...
    def upload(self, rid: int, dtype, shape) -> Optional[np.ndarray]: ...


def _block_digest(parent: bytes, token_ids: Iterable[int]) -> bytes:
    """Chained content hash: a block's key commits to its own tokens *and*
    its whole prefix (the parent's key), so equal keys mean equal KV."""
    h = hashlib.sha256(parent)
    h.update(np.asarray(list(token_ids), np.int64).tobytes())
    return h.digest()


def _common_prefix(a: Sequence[int], b: Sequence[int]) -> int:
    n = 0
    for x, y in zip(a, b):
        if x != y:
            break
        n += 1
    return n


class PagedKVManager:
    """Block-table allocator (implements ``BlockAllocator``).

    ``prefix_caching=False`` (default): every block is private, the hash
    table and evictor stay empty, and behaviour is identical to the old
    per-request paged accounting.  ``prefix_caching=True`` enables the
    content-hash table, block sharing, and CoW described in the module
    docstring; ``evictor`` plugs the reclaim policy (default LRU)."""

    def __init__(self, *, total_pages: int, page_size: int,
                 bytes_per_token: int, avg_decode_len: float,
                 host_capacity_bytes: int = 1 << 30,
                 prefix_caching: bool = False,
                 evictor: Optional[Evictor] = None):
        self.page_size = page_size
        self.bytes_per_token = bytes_per_token
        self.avg_decode_len = avg_decode_len
        self.host_capacity = host_capacity_bytes
        self.prefix_caching = bool(prefix_caching)
        self.free_pages = list(range(total_pages))
        self.tables: dict[int, list[int]] = {}        # rid -> block ids
        self.lengths: dict[int, int] = {}             # rid -> token count
        self.host_pool: OrderedDict[int, tuple[int, bytes]] = OrderedDict()
        self.stats = KVStats(device_pages_total=total_pages)
        # ---- block-table state (DESIGN.md §12) -----------------------------
        self.evictor: Evictor = evictor if evictor is not None else LRUEvictor()
        self._ref: dict[int, int] = {}                # block -> refcount (>0)
        self._hash: dict[bytes, int] = {}             # chain key -> block
        self._key: dict[int, bytes] = {}              # registered block -> key
        self._tokens: dict[int, tuple[int, ...]] = {}  # registered block ids
        self._parent: dict[int, bytes] = {}           # registered -> parent key
        self._children: dict[bytes, list[int]] = {}   # parent key -> blocks
        self._cached: dict[int, int] = {}             # rid -> prefix-hit tokens
        # rid -> (full blocks promoted/walked, chain digest at that point)
        self._promoted: dict[int, tuple[int, bytes]] = {}
        self._pending_copies: list[tuple[int, int]] = []

    # ---- accounting -------------------------------------------------------
    def pages_for(self, tokens: int) -> int:
        return -(-tokens // self.page_size)

    @property
    def pages_used(self) -> int:
        """Distinct blocks referenced by at least one table (shared blocks
        count once; equals the per-table sum when nothing is shared)."""
        return len(self._ref)

    @property
    def pages_free(self) -> int:
        """Blocks allocatable right now: the free list plus cached ref-0
        blocks the evictor can reclaim (empty without prefix caching)."""
        return len(self.free_pages) + len(self.evictor)

    # ---- peak-memory admission (§4.4) --------------------------------------
    def peak_pages(self, active: list[Request],
                   candidate: Optional[Request] = None) -> int:
        """Max page demand over the future, assuming one token/iteration and
        avg-decode completion (requests free their pages when they finish).

        The sweep starts from each request's **launch-side** occupancy, not
        just committed tokens: with a pipelined engine (DESIGN.md §10) up to
        ``async_depth`` sampled tokens per request are launched but
        uncommitted (``Request.inflight``) — they already occupy cache rows
        that ``extend`` will claim at commit time, and a request decoding
        past its predicted length would otherwise be under-counted by
        exactly those rows, letting admission overshoot the pool and
        ``extend`` fail at commit.  (``prefill_launched`` ahead of
        ``prefill_done`` is covered by the ``prompt_len`` floor — admission
        allocates the full prompt up front.)

        Prefix sharing is deliberately ignored: shared blocks make the
        sweep *conservative* (it can defer an admission that would fit,
        never admit one that would not)."""
        reqs = list(active) + ([candidate] if candidate is not None else [])
        if not reqs:
            return 0
        remaining = []
        current = []
        for r in reqs:
            pred = r.predicted_final_len(self.avg_decode_len)
            cur = max(r.total_tokens + r.inflight, min(r.prompt_len, pred))
            remaining.append(max(pred - cur, 0))
            current.append(cur)
        order = sorted(range(len(reqs)), key=lambda i: remaining[i])
        peak = 0
        alive = set(range(len(reqs)))
        for i in order:
            t = remaining[i]
            # just before request i finishes, everyone alive grew by t tokens
            demand = sum(self.pages_for(current[j] + min(t, remaining[j]))
                         for j in alive)
            peak = max(peak, demand)
            alive.discard(i)
        return peak

    def can_admit(self, req: Request, active: list[Request]) -> bool:
        return self.peak_pages(active, req) <= self.stats.device_pages_total

    # ---- refcounted block pool ---------------------------------------------
    def _available(self) -> int:
        return len(self.free_pages) + len(self.evictor)

    def _incref(self, block: int) -> None:
        self._ref[block] = self._ref.get(block, 0) + 1
        self.evictor.remove(block)

    def _decref(self, block: int) -> None:
        n = self._ref[block] - 1
        if n > 0:
            self._ref[block] = n
            return
        del self._ref[block]
        if block in self._key:
            # still hash-registered: keep it cached for future prefix hits
            # until capacity pressure reclaims it (_take_block)
            self.evictor.add(block)
        else:
            self.free_pages.append(block)

    def _take_block(self) -> int:
        """A writable private block: the free list first, then reclaim the
        evictor's pick (unregistering its hash entry — the cached prefix is
        gone for good, counted in ``evicted_blocks``)."""
        if self.free_pages:
            return self.free_pages.pop()
        block = self.evictor.pop()
        self._unregister(block)
        self.stats.evicted_blocks += 1
        return block

    def _unregister(self, block: int) -> None:
        key = self._key.pop(block)
        self._hash.pop(key, None)
        self._tokens.pop(block, None)
        parent = self._parent.pop(block, b"")
        kids = self._children.get(parent)
        if kids is not None:
            try:
                kids.remove(block)
            except ValueError:
                pass
            if not kids:
                del self._children[parent]

    def _fresh(self, table: list[int]) -> None:
        block = self._take_block()
        self._ref[block] = self._ref.get(block, 0) + 1
        table.append(block)

    def _queue_cow(self, src: int, table: list[int], j: int) -> None:
        """Replace ``table[j]`` (== src, shared/immutable) with a private
        copy: allocate dst, queue the (src, dst) device copy, swap the table
        entry.  src is pinned (extra ref) until the engine drains the copy —
        an eviction in between could hand src to a new request whose write
        would race the copy's read."""
        dst = self._take_block()
        self._ref[dst] = self._ref.get(dst, 0) + 1
        self._incref(src)                      # copy-source pin
        self._pending_copies.append((src, dst))
        self.stats.cow_copies += 1
        table[j] = dst
        self._decref(src)                      # the table's own ref

    def take_pending_copies(self) -> list[tuple[int, int]]:
        """Queued CoW block copies, (src, dst), cleared on read.  The engine
        applies them on device *before* its next packed dispatch; copy
        sources are unpinned here."""
        out, self._pending_copies = self._pending_copies, []
        for src, _ in out:
            self._decref(src)
        return out

    # ---- allocation --------------------------------------------------------
    def allocate(self, rid: int, tokens: int, *,
                 token_ids: Optional[Sequence[int]] = None) -> bool:
        """Build ``rid``'s block table for a ``tokens``-token prompt.  With
        prefix caching and ``token_ids``, the prompt is first matched
        against the content-hash table: whole matched blocks are shared
        (refcount++), and a divergence *inside* a cached block takes a CoW
        copy of it.  At most ``len(token_ids) - 1`` tokens are served from
        cache — the final prompt token is always recomputed so the prefill
        still produces the first sampled token."""
        if rid in self.tables:
            self.free(rid)
        need = self.pages_for(tokens)
        matched: list[int] = []
        cow_src = None
        cached = 0
        chain = b""
        if self.prefix_caching and token_ids is not None and len(token_ids):
            bs = self.page_size
            ids = tuple(token_ids)
            cap = len(ids) - 1          # always recompute >= 1 prompt token
            j = 0
            while (j + 1) * bs <= cap:
                key = _block_digest(chain, ids[j * bs:(j + 1) * bs])
                block = self._hash.get(key)
                if block is None:
                    break
                matched.append(block)
                chain = key
                j += 1
            cached = j * bs
            if cached < cap:
                # partial-tail match: a registered sibling whose leading
                # tokens agree — share via CoW, overwrite the divergent tail
                tail = ids[cached:min(cached + bs, cap)]
                best = 0
                for block in self._children.get(chain, ()):
                    m = _common_prefix(self._tokens[block], tail)
                    if m > best:
                        best, cow_src = m, block
                if best == 0:
                    cow_src = None
                else:
                    cached += best
        if need - len(matched) > self._available():
            return False
        for block in matched:
            self._incref(block)
        table = list(matched)
        if cow_src is not None:
            self._queue_cow_new(cow_src, table)
        while len(table) < need:
            self._fresh(table)
        self.tables[rid] = table
        self.lengths[rid] = tokens
        if self.prefix_caching:
            self._cached[rid] = cached
            self._promoted[rid] = (len(matched), chain)
            self.stats.prefix_hit_tokens += cached
        self._sync_used()
        return True

    def _queue_cow_new(self, src: int, table: list[int]) -> None:
        """Append a fresh private copy of ``src`` to ``table`` (admission-
        time partial-block hit: the request owns the copy from the start)."""
        dst = self._take_block()
        self._ref[dst] = self._ref.get(dst, 0) + 1
        self._incref(src)                      # copy-source pin
        self._pending_copies.append((src, dst))
        self.stats.cow_copies += 1
        table.append(dst)

    def ensure(self, rid: int, new_len: int) -> bool:
        """Launch-side growth (the engine calls this when it *writes* row
        ``new_len - 1``, before commit): append blocks to cover ``new_len``
        and make the written block private — a shared or hash-registered
        block is immutable, so a write there takes a CoW copy first."""
        table = self.tables.get(rid)
        if table is None:
            return False
        need = self.pages_for(new_len)
        while len(table) < need:
            if not self._available():
                self.stats.extend_failures += 1
                return False
            self._fresh(table)
        j = (new_len - 1) // self.page_size
        block = table[j]
        if self._ref.get(block, 0) > 1 or block in self._key:
            if not self._available():
                self.stats.extend_failures += 1
                return False
            self._queue_cow(block, table, j)
        self._sync_used()
        return True

    def extend(self, rid: int, new_len: int, *,
               token_ids: Optional[Sequence[int]] = None) -> bool:
        """Commit-side growth: cover ``new_len`` tokens (idempotent after a
        launch-side ``ensure``).  With prefix caching, ``token_ids`` — the
        request's *committed* token stream — promotes newly completed full
        blocks into the content-hash table (registration makes them
        immutable; their owner only ever writes beyond them)."""
        have = len(self.tables[rid])
        need = self.pages_for(new_len)
        extra = need - have
        if extra > self._available():
            self.stats.extend_failures += 1
            return False
        for _ in range(extra):
            self._fresh(self.tables[rid])
        self.lengths[rid] = new_len
        if self.prefix_caching and token_ids is not None:
            self._promote(rid, token_ids)
        self._sync_used()
        return True

    def _promote(self, rid: int, token_ids: Sequence[int]) -> None:
        """Register every *complete* committed block of ``rid`` whose chain
        position is still unclaimed.  On a hash collision (another request
        already registered identical content) the private duplicate stays
        private — the chain still advances through the canonical key, so
        later blocks can register."""
        j, chain = self._promoted.get(rid, (0, b""))
        table = self.tables[rid]
        bs = self.page_size
        committed = len(token_ids)
        while (j + 1) * bs <= committed and j < len(table):
            blk_ids = tuple(token_ids[j * bs:(j + 1) * bs])
            key = _block_digest(chain, blk_ids)
            block = table[j]
            if key not in self._hash and block not in self._key:
                self._hash[key] = block
                self._key[block] = key
                self._tokens[block] = blk_ids
                self._parent[block] = chain
                self._children.setdefault(chain, []).append(block)
            chain = key
            j += 1
        self._promoted[rid] = (j, chain)

    def free(self, rid: int) -> None:
        for block in self.tables.pop(rid, []):
            self._decref(block)
        self.lengths.pop(rid, None)
        self._cached.pop(rid, None)
        self._promoted.pop(rid, None)
        self._sync_used()

    def table(self, rid: int) -> list[int]:
        """The request's block table (block id of logical block j)."""
        return self.tables[rid]

    def cached_tokens(self, rid: int) -> int:
        """Prompt tokens served from shared blocks at admission — the
        scheduler skips prefilling them (DESIGN.md §12)."""
        return self._cached.get(rid, 0)

    def _sync_used(self):
        self.stats.device_pages_used = self.pages_used

    # ---- offload / upload (§5.4) -------------------------------------------
    @staticmethod
    def _entry_bytes(payload) -> int:
        """Host-pool payload size: real blobs carry their bytes, size-only
        entries carry just the byte count."""
        return payload if isinstance(payload, int) else len(payload)

    def offload(self, rid: int, kv_data: Optional[np.ndarray] = None, *,
                nbytes: Optional[int] = None) -> None:
        """Aggregate the request's scattered pages into one contiguous buffer
        (page-aggregation kernel) and move it to the host pool (LRU).

        ``kv_data`` is the real KV buffer; ``nbytes`` instead records a
        *size-only* entry — full byte/copy/LRU accounting with no host copy
        materialized.  The engine's per-finished-request path uses this (it
        used to allocate a garbage ``np.zeros`` proportional to the
        request's KV purely to feed the byte counter).  Device blocks are
        released at the end: hash-registered ones stay cached (evictor)
        and keep serving prefix hits."""
        assert (kv_data is None) != (nbytes is None), \
            "offload takes exactly one of kv_data / nbytes"
        tokens = self.lengths.get(rid, 0)
        if tokens == 0:
            return
        if kv_data is not None:
            contiguous = np.ascontiguousarray(kv_data)   # the aggregation
            payload = contiguous.tobytes()
        else:
            payload = int(nbytes)            # size-only: bytes never copied
        size = self._entry_bytes(payload)
        self.stats.aggregated_copies += 1
        self.stats.offload_bytes += size
        # re-offload of a rid still pooled (multi-round turnarounds, and the
        # steady state for size-only entries, which upload() never pops)
        # replaces its entry — release the old bytes or host_bytes drifts
        # past capacity and the LRU loop evicts the whole pool
        prev = self.host_pool.get(rid)
        if prev is not None:
            self.stats.host_bytes -= self._entry_bytes(prev[1])
        self.host_pool[rid] = (tokens, payload)
        self.host_pool.move_to_end(rid)
        self.stats.host_bytes += size
        while self.stats.host_bytes > self.host_capacity and self.host_pool:
            _, (_, evicted) = self.host_pool.popitem(last=False)   # LRU
            self.stats.host_bytes -= self._entry_bytes(evicted)
            # the evicted request's KV is gone for good — a future upload()
            # will miss and the conversation re-prefills from scratch
            self.stats.discarded_requests += 1
        self.free(rid)

    def upload(self, rid: int, dtype, shape) -> Optional[np.ndarray]:
        """Multi-round re-activation: restore KV from host, re-allocating
        device blocks (page distribution kernel).

        Device re-allocation can fail under pressure; the blob must then
        *stay* in the host pool so the caller can retry later (it used to be
        popped first and silently lost — the request's KV discarded without
        even counting it).  Size-only entries (``offload(nbytes=...)``)
        carry no data, so they restore nothing: a miss, without touching
        device pages or the pool entry."""
        entry = self.host_pool.get(rid)
        if entry is None:
            return None
        tokens, blob = entry
        if isinstance(blob, int):
            return None                     # size-only entry: no data
        if not self.allocate(rid, tokens):
            return None                     # kept on host; retryable
        self.host_pool.pop(rid)
        self.stats.host_bytes -= len(blob)
        self.stats.upload_bytes += len(blob)
        return np.frombuffer(blob, dtype=dtype).reshape(shape).copy()
