"""Paged KV accounting + asynchronous host offload (paper §4.4 / §5.4).

Pages are the unit of memory accounting, admission control, and offload:

  * **Peak-memory estimation** — before admitting a request, simulate every
    active request growing one token/iteration until its predicted end
    (prompt + avg decode length) and take the max in-flight page count over
    the finish-time sweep; admit only if the peak fits (paper §4.4).
  * **Page aggregation before offload** — offloaded pages are first gathered
    into one contiguous buffer (the paper's on-device rearrangement kernel;
    Fig. 8 shows scattered D2H is ~an order of magnitude slower), then copied
    host-side in one shot.  We model it with a real gather + a byte counter.
  * **Host pool with LRU** — finished requests' KV lives on the host (the
    paper's CPU/SSD tiers collapse into one host tier here), re-uploadable
    for multi-round conversations; LRU-evicted beyond capacity.

The compute path (engine.py) uses contiguous per-slot caches — on TPU the
paged decode kernel (kernels/decode_attention.paged_decode_attention) reads
through the page table directly; equivalence is covered by kernel tests.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Optional

import numpy as np

from repro.serving.request import Request


@dataclasses.dataclass
class KVStats:
    device_pages_total: int
    device_pages_used: int = 0
    host_bytes: int = 0
    offload_bytes: int = 0          # cumulative D2H traffic
    upload_bytes: int = 0           # cumulative H2D traffic
    aggregated_copies: int = 0
    discarded_requests: int = 0
    # extend() calls that found no free page: admission overshoot — the
    # peak estimate promised room that launch-time growth consumed.  Must
    # stay 0 now that peak_pages counts in-flight tokens (regression
    # signal; tests/test_kv_accounting.py)
    extend_failures: int = 0


class PagedKVManager:
    def __init__(self, *, total_pages: int, page_size: int,
                 bytes_per_token: int, avg_decode_len: float,
                 host_capacity_bytes: int = 1 << 30):
        self.page_size = page_size
        self.bytes_per_token = bytes_per_token
        self.avg_decode_len = avg_decode_len
        self.host_capacity = host_capacity_bytes
        self.free_pages = list(range(total_pages))
        self.tables: dict[int, list[int]] = {}        # rid -> page ids
        self.lengths: dict[int, int] = {}             # rid -> token count
        self.host_pool: OrderedDict[int, tuple[int, bytes]] = OrderedDict()
        self.stats = KVStats(device_pages_total=total_pages)

    # ---- accounting -------------------------------------------------------
    def pages_for(self, tokens: int) -> int:
        return -(-tokens // self.page_size)

    @property
    def pages_used(self) -> int:
        return sum(len(t) for t in self.tables.values())

    @property
    def pages_free(self) -> int:
        return len(self.free_pages)

    # ---- peak-memory admission (§4.4) --------------------------------------
    def peak_pages(self, active: list[Request],
                   candidate: Optional[Request] = None) -> int:
        """Max page demand over the future, assuming one token/iteration and
        avg-decode completion (requests free their pages when they finish).

        The sweep starts from each request's **launch-side** occupancy, not
        just committed tokens: with a pipelined engine (DESIGN.md §10) up to
        ``async_depth`` sampled tokens per request are launched but
        uncommitted (``Request.inflight``) — they already occupy cache rows
        that ``extend`` will claim at commit time, and a request decoding
        past its predicted length would otherwise be under-counted by
        exactly those rows, letting admission overshoot the pool and
        ``extend`` fail at commit.  (``prefill_launched`` ahead of
        ``prefill_done`` is covered by the ``prompt_len`` floor — admission
        allocates the full prompt up front.)"""
        reqs = list(active) + ([candidate] if candidate is not None else [])
        if not reqs:
            return 0
        remaining = []
        current = []
        for r in reqs:
            pred = r.predicted_final_len(self.avg_decode_len)
            cur = max(r.total_tokens + r.inflight, min(r.prompt_len, pred))
            remaining.append(max(pred - cur, 0))
            current.append(cur)
        order = sorted(range(len(reqs)), key=lambda i: remaining[i])
        peak = 0
        alive = set(range(len(reqs)))
        for i in order:
            t = remaining[i]
            # just before request i finishes, everyone alive grew by t tokens
            demand = sum(self.pages_for(current[j] + min(t, remaining[j]))
                         for j in alive)
            peak = max(peak, demand)
            alive.discard(i)
        return peak

    def can_admit(self, req: Request, active: list[Request]) -> bool:
        return self.peak_pages(active, req) <= self.stats.device_pages_total

    # ---- allocation --------------------------------------------------------
    def allocate(self, rid: int, tokens: int) -> bool:
        need = self.pages_for(tokens)
        if need > len(self.free_pages):
            return False
        self.tables[rid] = [self.free_pages.pop() for _ in range(need)]
        self.lengths[rid] = tokens
        self._sync_used()
        return True

    def extend(self, rid: int, new_len: int) -> bool:
        have = len(self.tables[rid])
        need = self.pages_for(new_len)
        extra = need - have
        if extra > len(self.free_pages):
            self.stats.extend_failures += 1
            return False
        for _ in range(extra):
            self.tables[rid].append(self.free_pages.pop())
        self.lengths[rid] = new_len
        self._sync_used()
        return True

    def free(self, rid: int) -> None:
        self.free_pages.extend(self.tables.pop(rid, []))
        self.lengths.pop(rid, None)
        self._sync_used()

    def _sync_used(self):
        self.stats.device_pages_used = self.pages_used

    # ---- offload / upload (§5.4) -------------------------------------------
    @staticmethod
    def _entry_bytes(payload) -> int:
        """Host-pool payload size: real blobs carry their bytes, size-only
        entries carry just the byte count."""
        return payload if isinstance(payload, int) else len(payload)

    def offload(self, rid: int, kv_data: Optional[np.ndarray] = None, *,
                nbytes: Optional[int] = None) -> None:
        """Aggregate the request's scattered pages into one contiguous buffer
        (page-aggregation kernel) and move it to the host pool (LRU).

        ``kv_data`` is the real KV buffer; ``nbytes`` instead records a
        *size-only* entry — full byte/copy/LRU accounting with no host copy
        materialized.  The engine's per-finished-request path uses this (it
        used to allocate a garbage ``np.zeros`` proportional to the
        request's KV purely to feed the byte counter)."""
        assert (kv_data is None) != (nbytes is None), \
            "offload takes exactly one of kv_data / nbytes"
        tokens = self.lengths.get(rid, 0)
        if tokens == 0:
            return
        if kv_data is not None:
            contiguous = np.ascontiguousarray(kv_data)   # the aggregation
            payload = contiguous.tobytes()
        else:
            payload = int(nbytes)            # size-only: bytes never copied
        size = self._entry_bytes(payload)
        self.stats.aggregated_copies += 1
        self.stats.offload_bytes += size
        # re-offload of a rid still pooled (multi-round turnarounds, and the
        # steady state for size-only entries, which upload() never pops)
        # replaces its entry — release the old bytes or host_bytes drifts
        # past capacity and the LRU loop evicts the whole pool
        prev = self.host_pool.get(rid)
        if prev is not None:
            self.stats.host_bytes -= self._entry_bytes(prev[1])
        self.host_pool[rid] = (tokens, payload)
        self.host_pool.move_to_end(rid)
        self.stats.host_bytes += size
        while self.stats.host_bytes > self.host_capacity and self.host_pool:
            _, (_, evicted) = self.host_pool.popitem(last=False)   # LRU
            self.stats.host_bytes -= self._entry_bytes(evicted)
            # the evicted request's KV is gone for good — a future upload()
            # will miss and the conversation re-prefills from scratch
            self.stats.discarded_requests += 1
        self.free(rid)

    def upload(self, rid: int, dtype, shape) -> Optional[np.ndarray]:
        """Multi-round re-activation: restore KV from host, re-allocating
        device pages (page distribution kernel).

        Device re-allocation can fail under pressure; the blob must then
        *stay* in the host pool so the caller can retry later (it used to be
        popped first and silently lost — the request's KV discarded without
        even counting it).  Size-only entries (``offload(nbytes=...)``)
        carry no data, so they restore nothing: a miss, without touching
        device pages or the pool entry."""
        entry = self.host_pool.get(rid)
        if entry is None:
            return None
        tokens, blob = entry
        if isinstance(blob, int):
            return None                     # size-only entry: no data
        if not self.allocate(rid, tokens):
            return None                     # kept on host; retryable
        self.host_pool.pop(rid)
        self.stats.host_bytes -= len(blob)
        self.stats.upload_bytes += len(blob)
        return np.frombuffer(blob, dtype=dtype).reshape(shape).copy()
