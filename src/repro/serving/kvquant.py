"""int8 KV-cache quantization (beyond-paper; QServe-style per-token scales).

Halves KV-cache HBM footprint and stream traffic — the decode-attention
memory term (the paper's GEMV bottleneck) drops ~2× on hardware; the paged
decode kernel dequantizes in-register after the int8 HBM read.

Scheme: symmetric int8 per (token, kv-head) — one f32 scale per (B, S, KV)
row (0.8% overhead at head_dim 128).  Error is bounded by scale/2 per
element; end-to-end logit error is validated in tests/test_kvquant.py.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def quantize_kv(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x: (..., D) -> (int8 values (..., D), f32 scales (...,))."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_kv(q: jax.Array, scale: jax.Array,
                  dtype=jnp.bfloat16) -> jax.Array:
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)


def init_quant_cache(batch: int, max_len: int, kv_heads: int,
                     head_dim: int) -> dict:
    return {
        "k_q": jnp.zeros((batch, max_len, kv_heads, head_dim), jnp.int8),
        "v_q": jnp.zeros((batch, max_len, kv_heads, head_dim), jnp.int8),
        "k_s": jnp.zeros((batch, max_len, kv_heads), jnp.float32),
        "v_s": jnp.zeros((batch, max_len, kv_heads), jnp.float32),
    }


def write_token(cache: dict, k_new: jax.Array, v_new: jax.Array,
                idx: jax.Array) -> dict:
    """k_new/v_new: (B, KV, D) bf16; idx: (B,) write positions."""
    kq, ks = quantize_kv(k_new)
    vq, vs = quantize_kv(v_new)

    def w(buf, val):
        def one(c, n, i):
            return jax.lax.dynamic_update_slice(
                c, n[None].astype(c.dtype), (i,) + (0,) * (c.ndim - 1))
        return jax.vmap(one)(buf, val, idx)

    return {"k_q": w(cache["k_q"], kq), "v_q": w(cache["v_q"], vq),
            "k_s": w(cache["k_s"], ks), "v_s": w(cache["v_s"], vs)}


def quant_decode_attention(q: jax.Array, cache: dict, cache_len: jax.Array,
                           *, logit_scale: Optional[float] = None,
                           dtype=jnp.bfloat16) -> jax.Array:
    """Decode attention over the int8 cache.  On TPU the dequant fuses into
    the kernel's VMEM load; this XLA form keeps the same math."""
    from repro.kernels.ref import decode_attention_ref
    k = dequantize_kv(cache["k_q"], cache["k_s"], dtype)
    v = dequantize_kv(cache["v_q"], cache["v_s"], dtype)
    return decode_attention_ref(q, k, v, cache_len, logit_scale=logit_scale)


def cache_bytes(batch: int, max_len: int, kv_heads: int, head_dim: int,
                quantized: bool) -> int:
    per_tok = kv_heads * head_dim
    if quantized:
        return batch * max_len * (2 * per_tok * 1 + 2 * kv_heads * 4)
    return batch * max_len * 2 * per_tok * 2      # bf16 k+v
