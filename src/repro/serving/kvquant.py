"""int8 KV-cache quantization primitives (DESIGN.md §15).

Symmetric int8 per (token, kv-head) row: one f32 scale per (..., D) row
(0.8% overhead at head_dim 128).  Per-element error is bounded by
``max|row| / 254``; end-to-end logit drift is gated per mixer family in
tests/test_kvquant.py and tests/test_kv_int8_engine.py.

These two functions are the *only* quant primitive in the repo: the packed
step quantizes K/V at scatter time (models/attention.py) and the
packed-attention kernel dequantizes in-register after the int8 HBM read
(kernels/packed_attention.py); the ref oracle dequantizes densely
(kernels/ref.py).  The cache storage dtype is selected by
``EngineConfig(kv_dtype="int8")`` — the int8 value leaves and f32 scale
leaves live in the same per-mixer cache dict (``k``/``v`` + ``k_s``/``v_s``
for GQA, ``c_kv``/``k_rope`` + ``_s`` for absorbed MLA), sharing the
``(layers, slots, max_len, ...)`` physical layout so §11 TP sharding and
§12 block tables / CoW / prefix hashing are untouched.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_kv(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x: (..., D) -> (int8 values (..., D), f32 scales (...,))."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_kv(q: jax.Array, scale: jax.Array,
                  dtype=jnp.bfloat16) -> jax.Array:
    return (q.astype(jnp.float32) * scale[..., None]).astype(dtype)
