"""End-to-end serving engine: scheduler + paged KV + model execution.

Slot-based execution: the decode path runs over a fixed-capacity slot array
(static shapes — one compiled program; the paper's discrete-batching insight
applied to the XLA compilation cache).  Prefill runs in chunks (chunked
prefill, §4.2) whose KV states are written into the request's slot.

Chunked prefill is *incremental* (DESIGN.md §7): each chunk runs
``model.forward_chunk`` against the slot's carried cache — attention K/V
(latents) are written at the prefix offset, recurrent mixers resume from
their cached state — so every prompt token passes through the model exactly
once (O(p) FLOPs for a p-token prompt).  The chunk step is jitted with
*bucketed* chunk lengths: the scheduler quantizes chunk lengths to its
discrete sizes, so the XLA compile cache is bounded by
``len(discrete_sizes) + chunk_min - 1`` programs.  The pre-refactor
recompute path (re-run ``forward_full`` over ``[0, upto)`` per chunk,
O(p²/chunk) FLOPs) is kept as ``prefill_mode="recompute"`` for A/B
benchmarking.

Iteration order: decode first, then prefill.  The decode step executes over
*all* slots (static shape); mid-prefill slots are masked out of the cache
update (``active``), so their carried prefill state is never perturbed —
this mirrors NanoFlow's asynchronous top-level scheduling where batch
formation for iteration i+1 happens before iteration i's results are
inspected (§5.3).

On TPU the per-iteration program is the NanoFlow pipeline (nano-batched,
overlapped ops); on this CPU container the same engine logic drives the ref
execution path, and the intra-device overlap is *modeled* by core/autosearch
(benchmarks report both).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ATTN, ModelConfig
from repro.models import model as model_lib
from repro.serving import sampling
from repro.serving.kvcache import PagedKVManager
from repro.serving.request import Request
from repro.serving.scheduler import BatchPlan, GlobalBatchScheduler


@dataclasses.dataclass
class EngineStats:
    iterations: int = 0
    prefill_tokens: int = 0          # prompt tokens admitted to the cache
    prefill_model_tokens: int = 0    # token-positions actually run through
    #                                  the model during prefill: == prefill
    #                                  _tokens on the incremental path (O(p)),
    #                                  strictly greater on the recompute path
    decode_tokens: int = 0
    wall_time: float = 0.0
    prefill_time: float = 0.0
    dense_batch_hist: dict = dataclasses.field(default_factory=dict)

    @property
    def total_tokens(self) -> int:
        return self.prefill_tokens + self.decode_tokens

    @property
    def throughput(self) -> float:
        return self.total_tokens / self.wall_time if self.wall_time else 0.0

    @property
    def prefill_expansion(self) -> float:
        """Model-token-positions per prompt token (1.0 == linear prefill)."""
        return (self.prefill_model_tokens / self.prefill_tokens
                if self.prefill_tokens else 0.0)


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, max_slots: int = 8,
                 max_len: int = 512, page_size: int = 16,
                 total_pages: Optional[int] = None,
                 avg_decode_len: float = 64.0,
                 discrete_sizes: tuple[int, ...] = (256, 128, 64, 32, 16, 8),
                 prefill_mode: str = "incremental",
                 seed: int = 0):
        assert prefill_mode in ("incremental", "recompute"), prefill_mode
        self.cfg = cfg
        self.params = params
        self.max_slots = max_slots
        self.max_len = max_len
        self.prefill_mode = prefill_mode
        self.key = jax.random.PRNGKey(seed)

        hd = cfg.resolved_head_dim
        n_attn = max(sum(1 for s in cfg.layer_specs() if s.mixer == ATTN), 1)
        kv_bytes = 2 * cfg.n_kv_heads * hd * 2 * n_attn
        pages = total_pages or (max_slots * max_len // page_size)
        self.kv = PagedKVManager(total_pages=pages, page_size=page_size,
                                 bytes_per_token=kv_bytes,
                                 avg_decode_len=avg_decode_len)
        self.scheduler = GlobalBatchScheduler(
            self.kv, discrete_sizes=discrete_sizes, max_active=max_slots)

        # slot caches: model cache trees with leading batch = max_slots
        self.cache = model_lib.init_cache(cfg, 1, max_slots, max_len)
        self.cache_len = jnp.zeros((max_slots,), jnp.int32)
        self.slot_free = list(range(max_slots))
        self.stats = EngineStats()

        # fresh one-slot cache, scattered into a slot on (re)assignment so a
        # reused slot never leaks the previous request's recurrent state
        self._slot_init = model_lib.init_cache(cfg, 1, 1, max_len)

        self._decode_step = jax.jit(self._decode_impl, donate_argnums=(1,))
        # one compiled program per bucketed chunk length (scheduler-quantized)
        self._prefill_step = jax.jit(self._prefill_impl, donate_argnums=(1,))
        self._reset_step = jax.jit(_reset_slot, donate_argnums=(0,))

    # ---- jitted decode over all slots (static shapes) -----------------------
    def _decode_impl(self, params, cache, tokens, cache_len, active):
        logits, new_cache = model_lib.forward_decode(
            self.cfg, params, tokens, cache, cache_len)
        next_tok = sampling.greedy(logits)
        # Mask the *recurrent* state update to decoding slots: a mid-prefill
        # slot's carried SSM/LSTM state must not be advanced by its garbage
        # decode token.  Attention K/V leaves keep the donated in-place
        # update: the garbage row lands at the slot's cache_len, which the
        # next prefill chunk overwrites before attending — selecting the big
        # seq-dim leaves would force a full cache copy per decode step.
        def sel(n, o):
            m = active.reshape((1, -1) + (1,) * (n.ndim - 2))
            return jnp.where(m, n, o)
        out = []
        for gi, (pattern, reps) in enumerate(self.cfg.layer_groups()):
            g = {}
            for i, spec in enumerate(pattern):
                n_sub = new_cache[gi][f"sub{i}"]
                g[f"sub{i}"] = n_sub if spec.mixer == ATTN else jax.tree.map(
                    sel, n_sub, cache[gi][f"sub{i}"])
            out.append(g)
        return next_tok, out

    # ---- jitted incremental prefill chunk (one slot, bucketed length) -------
    def _prefill_impl(self, params, cache, tokens, slot, offset):
        """tokens: (1, L[, K]) — the next L prompt positions of ``slot``
        after an ``offset``-token prefix.  Gathers the slot's sub-cache,
        runs ``forward_chunk``, scatters the updated sub-cache back
        (partial-prefix write at an arbitrary offset).  ``slot`` and
        ``offset`` are traced, so one compiled program serves every slot and
        prefix depth of a given chunk length."""
        sub = jax.tree.map(
            lambda a: jax.lax.dynamic_slice_in_dim(a, slot, 1, axis=1),
            cache)
        logits, new_sub = model_lib.forward_chunk(
            self.cfg, params, tokens, sub, offset[None])
        new_cache = jax.tree.map(
            lambda c, s: jax.lax.dynamic_update_slice_in_dim(
                c, s.astype(c.dtype), slot, axis=1),
            cache, new_sub)
        return sampling.greedy(logits[:, -1]), new_cache

    # ---- public API ----------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.scheduler.submit(req)

    def run(self, max_iters: int = 10_000) -> list[Request]:
        done: list[Request] = []
        t0 = time.perf_counter()
        for _ in range(max_iters):
            plan = self.scheduler.plan()
            if plan is None:
                break
            done += self.step(plan)
        self.stats.wall_time += time.perf_counter() - t0
        return done

    def step(self, plan: BatchPlan) -> list[Request]:
        now = time.perf_counter()
        self.stats.iterations += 1
        self.stats.dense_batch_hist[plan.dense_batch] = \
            self.stats.dense_batch_hist.get(plan.dense_batch, 0) + 1
        sampled: dict[int, int] = {}

        # ---- batched decode over all slots (static shape) --------------------
        decode_reqs = [r for r in plan.decode if r.slot >= 0]
        if decode_reqs:
            tokens = np.zeros((self.max_slots, 1), np.int32)
            active = np.zeros((self.max_slots,), bool)
            for r in decode_reqs:
                tokens[r.slot, 0] = r.output[-1] if r.output else r.prompt[-1]
                active[r.slot] = True
            tok_in = jnp.asarray(tokens)
            if self.cfg.frontend == "audio":
                tok_in = jnp.repeat(tok_in[..., None], self.cfg.num_codebooks,
                                    axis=-1)
            next_tok, self.cache = self._decode_step(
                self.params, self.cache, tok_in, self.cache_len,
                jnp.asarray(active))
            self.cache_len = self.cache_len + jnp.asarray(active, jnp.int32)
            nt = np.asarray(next_tok)
            for r in decode_reqs:
                t = nt[r.slot]
                sampled[r.rid] = int(t) if np.ndim(t) == 0 else int(t.flat[0])
            self.stats.decode_tokens += len(decode_reqs)

        # ---- chunked prefill -------------------------------------------------
        t_prefill = time.perf_counter()
        for chunk in plan.prefill:
            r = chunk.req
            if r.slot < 0:
                assert self.slot_free, "scheduler admitted beyond slot capacity"
                r.slot = self.slot_free.pop()
                if self.prefill_mode == "incremental":
                    self.cache = self._reset_step(
                        self.cache, self._slot_init, jnp.int32(r.slot))
            if self.prefill_mode == "incremental":
                last_tok = self._prefill_chunk(r, chunk.offset, chunk.length)
                self.stats.prefill_model_tokens += chunk.length
            else:
                last_tok = self._prefill_to(r, chunk.offset + chunk.length)
                self.stats.prefill_model_tokens += chunk.offset + chunk.length
            self.stats.prefill_tokens += chunk.length
            if chunk.offset + chunk.length == r.prompt_len:
                sampled[r.rid] = last_tok
        self.stats.prefill_time += time.perf_counter() - t_prefill

        finished = self.scheduler.commit(plan, sampled, now)
        for r in finished:
            self._finalize(r)
        return finished

    # ---- internals -----------------------------------------------------------
    def _prefill_chunk(self, r: Request, offset: int, length: int) -> int:
        """Incremental path: run exactly ``length`` new prompt tokens against
        the slot's carried cache (O(length) model FLOPs)."""
        toks = np.asarray(r.prompt[offset:offset + length], np.int32)[None]
        tok_in = jnp.asarray(toks)
        if self.cfg.frontend == "audio":
            tok_in = jnp.repeat(tok_in[..., None], self.cfg.num_codebooks,
                                axis=-1)
        next_tok, self.cache = self._prefill_step(
            self.params, self.cache, tok_in, jnp.int32(r.slot),
            jnp.int32(offset))
        self.cache_len = self.cache_len.at[r.slot].set(offset + length)
        t = np.asarray(next_tok)
        return int(t) if t.ndim == 0 else int(t.flat[0])

    def _prefill_to(self, r: Request, upto: int) -> int:
        """Recompute path (``prefill_mode="recompute"``; pre-DESIGN.md-§7
        behaviour, kept for A/B benchmarks): re-run ``forward_full`` over the
        whole prefix [0, upto) and scatter its states into the request's
        slot — O(p²/chunk) FLOPs per prompt, correct for every mixer
        family."""
        cfg = self.cfg
        toks = np.asarray(r.prompt[:upto], np.int32)[None]
        tok_in = jnp.asarray(toks)
        if cfg.frontend == "audio":
            tok_in = jnp.repeat(tok_in[..., None], cfg.num_codebooks, axis=-1)
        logits, _aux, states = model_lib.forward_full(
            cfg, self.params, tok_in, return_states=True)
        self._scatter_states(r.slot, states)
        self.cache_len = self.cache_len.at[r.slot].set(upto)
        last = np.asarray(logits[0, -1])
        return int(last.argmax(-1)) if last.ndim == 1 else int(last.argmax(-1).flat[0])

    def _scatter_states(self, slot: int, states) -> None:
        """Write per-layer mixer states into a slot (recompute path: the
        whole prefix at offset 0).  The incremental path's partial-prefix
        writes at arbitrary offsets happen inside the jitted
        ``_prefill_impl`` via ``attention._write_seq_at``."""
        for gi, (pattern, reps) in enumerate(self.cfg.layer_groups()):
            for i, spec in enumerate(pattern):
                st = states[gi][f"sub{i}"]
                dst = self.cache[gi][f"sub{i}"]
                if spec.mixer == ATTN:
                    if self.cfg.mla is not None:
                        ck, kr = st["kv"]
                        dst["c_kv"] = _write_slot_seq(dst["c_kv"], ck, slot)
                        dst["k_rope"] = _write_slot_seq(dst["k_rope"], kr,
                                                        slot)
                    else:
                        k, v = st["kv"]
                        dst["k"] = _write_slot_seq(dst["k"], k, slot)
                        dst["v"] = _write_slot_seq(dst["v"], v, slot)
                else:
                    for name, val in st.items():
                        dst[name] = _write_slot(dst[name], val, slot)

    def _finalize(self, r: Request) -> None:
        if r.slot >= 0:
            self.slot_free.append(r.slot)
            self.cache_len = self.cache_len.at[r.slot].set(0)
            r.slot = -1
        # strip the one post-EOS token (async EOS, §5.3)
        if r.pending_eos and r.eos_id is not None and r.eos_id in r.output:
            r.output = r.output[: r.output.index(r.eos_id) + 1]
        # offload KV for multi-round reuse (byte-accurate accounting)
        kv_elems = max(r.total_tokens * self.kv.bytes_per_token // 4, 1)
        self.kv.offload(r.rid, np.zeros((kv_elems,), np.float32))


def _reset_slot(cache, init, slot):
    """Scatter a fresh one-slot cache into ``slot`` of the full cache."""
    return jax.tree.map(
        lambda c, z: jax.lax.dynamic_update_slice_in_dim(
            c, z.astype(c.dtype), slot, axis=1),
        cache, init)


def _write_slot_seq(cache: jax.Array, chunk: jax.Array, slot: int) -> jax.Array:
    """cache: (L, B, S, ...); chunk: (L, 1, s, ...) -> rows [0, s) of slot."""
    idx = (0, slot, 0) + (0,) * (cache.ndim - 3)
    return jax.lax.dynamic_update_slice(cache, chunk.astype(cache.dtype), idx)


def _write_slot(cache: jax.Array, state: jax.Array, slot: int) -> jax.Array:
    """cache: (L, B, ...); state: (L, 1, ...) -> write slot row."""
    idx = (0, slot) + (0,) * (cache.ndim - 2)
    return jax.lax.dynamic_update_slice(cache, state.astype(cache.dtype), idx)
