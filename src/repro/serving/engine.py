"""End-to-end serving engine: scheduler + paged KV + model execution.

Slot-based execution: model state lives in fixed-capacity slot caches
(static shapes — bounded compiled programs; the paper's discrete-batching
insight applied to the XLA compilation cache).  Prefill runs in chunks
(chunked prefill, §4.2) whose KV states are written into the request's slot.

**Packed step (default, DESIGN.md §8).**  One iteration = one jitted
program: the decode tokens (one per decoding slot) and *all* scheduled
prefill chunks are packed into a single ``(1, T)`` token stream with
per-token ``(slot, position)`` metadata and run through
``model.forward_packed`` — K/V (MLA latents) scattered at each segment's
offset, a segment-aware mask so segments never attend across each other,
recurrent state advanced per-slot with active-masking, greedy sampling
on-device.  Exactly one model dispatch and one device→host transfer per
iteration (``EngineStats.model_dispatches`` / ``host_syncs``), vs the
legacy path's ``1 + K`` dispatches with a blocking sync per chunk.  ``T``
is bucketed to the scheduler's discrete dense sizes, so
``BatchPlan.dense_batch`` is the *actual launched shape*; the iteration's
max KV extent is quantized to a KV-length bucket grid (DESIGN.md §9) and
passed statically into the step, so attention sweeps ``kv_bucket`` cache
rows per slot instead of ``max_len`` and the compile cache is bounded by
``(len(discrete_sizes) + 1) × len(kv_buckets)`` (the ``max_active`` floor
bucket for decode-only iterations, DESIGN.md §8).  Segment order inside
the stream follows the nano-batch interleave
(``core/nanobatch.packed_segment_order``), so the interleave governs the
real token layout of the launched program, not just the cost model.

**Asynchronous iteration pipeline (``async_depth``, DESIGN.md §10).**  The
packed step's one sync per iteration is *deferrable*: the program samples
on device and scatters each slot's token into a device-resident
``last_token`` buffer, and the next iteration's decode inputs are gathered
from that buffer *in-program* — so iteration i+1's entire input stream is
computable from scheduler state alone, before iteration i's results ever
reach the host.  With ``async_depth=k`` the engine keeps up to ``k``
iterations in flight (a ring of sampled-token handles), planning
speculatively (``scheduler.mark_launched``) and reconciling on commit
(lag-(1+k) EOS, late speculative tokens dropped).  The packed step
defaults to ``async_depth=1``; ``async_depth=0`` retires each iteration
immediately and is bit-identical to the pre-§10 lock-step engine (the
A/B baseline).  ``EngineStats`` splits the wall clock into host work /
dispatch / blocked-sync time so the overlap is measurable.

**Legacy step (``step_mode="legacy"``, kept for A/B).**  Decode first over
all slots, then one ``model.forward_chunk`` dispatch per prefill chunk,
each gathering/scattering the chunk's slot sub-cache (DESIGN.md §7).  The
pre-§7 recompute path (O(p²/chunk) FLOPs) remains as
``prefill_mode="recompute"`` (implies the legacy step).

**Tensor-parallel serving (``tp=N``, DESIGN.md §11).**  The same packed
step runs as **one ``shard_map`` program** over a 1-D ``("model",)`` mesh:
params and the slot KV caches are sharded along heads/channels per mixer
family (GQA kv heads; MLA keeps the latent replicated and shards the
absorbed per-head projections; SSM/xLSTM shard the state's head/channel
axis; sLSTM's tiny recurrence stays replicated), attention and FFN output
projections ride the ring-decomposed collective matmuls of
``distributed/collective_matmul`` launched *per nano-batch group* — so
segment group i's all-reduce is dependency-free of group i+1's GEMMs, the
paper's §4.3 network/compute overlap as real launched collectives.  The
``last_token`` buffer, sampled tokens and ``cache_len`` stay replicated
(sampling reads full-vocab logits on every shard), so the iteration is
still exactly one dispatch + one (deferred) sync and ``async_depth``
composes unchanged; the compile cache keeps the
(|T buckets| + 1) × |kv buckets| bound per mesh.  ``tp=1`` is exactly the
single-device path; on this CPU container the mesh comes from
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` and the
*intra*-device overlap is still modeled by core/autosearch.
"""
from __future__ import annotations

import dataclasses
import time
import warnings
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ATTN, ModelConfig
from repro.core.nanobatch import nano_batch_sizes_for
from repro.distributed import tp as tp_lib
from repro.distributed.sharding import shard_map_compat
from repro.kernels import ops
from repro.launch.mesh import make_tp_mesh
from repro.models import blocks
from repro.models import model as model_lib
from repro.serving import draft as draft_lib
from repro.serving import sampling
from repro.serving.config import EngineConfig
from repro.serving.kvcache import PagedKVManager
from repro.serving.request import Request, State
from repro.serving.scheduler import BatchPlan, GlobalBatchScheduler


def kv_bytes_per_token(cfg: ModelConfig,
                       kv_dtype: Optional[str] = None) -> int:
    """Per-token KV-cache bytes, derived from the *actual* attention cache
    leaves (``jax.eval_shape`` — no allocation): for each attention layer,
    the bytes of one sequence row of every leaf.  GQA: ``2·kv·hd·itemsize``
    per layer; MLA caches only the latent ``c_kv + k_rope`` (the absorbed
    path never materializes per-head K/V — charging the GQA formula made
    deepseek-style admission ~an order of magnitude too conservative);
    attention-free SSM/xLSTM models carry O(1) recurrent state and no
    per-token pages at all, so this is 0 for them (the old
    ``max(n_attn, 1)`` floor charged them per-token paging).

    ``kv_dtype="int8"`` (DESIGN.md §15) rates the quantized layout — int8
    value leaves plus the f32 scale leaves — so a fixed ``kv_budget_bytes``
    admits ~2× the tokens of the native-dtype cache."""
    per_spec: dict = {}
    total = 0
    for spec in cfg.layer_specs():
        if spec.mixer != ATTN:
            continue
        if spec not in per_spec:
            leaves = jax.eval_shape(
                lambda s=spec: blocks.block_init_cache(cfg, s, 1, 1, 2,
                                                       kv_dtype))
            per_spec[spec] = sum(
                int(np.prod(leaf.shape[2:])) * leaf.dtype.itemsize
                for leaf in jax.tree.leaves(leaves))
        total += per_spec[spec]
    return total


@dataclasses.dataclass
class EngineStats:
    iterations: int = 0
    prefill_tokens: int = 0          # prompt tokens admitted to the cache
    prefill_model_tokens: int = 0    # token-positions actually run through
    #                                  the model during prefill: == prefill
    #                                  _tokens on the incremental path (O(p)),
    #                                  strictly greater on the recompute path
    decode_tokens: int = 0
    wall_time: float = 0.0
    # host/device overlap split (DESIGN.md §10; replaces the old
    # ``prefill_time``, which only the legacy step ever updated):
    #   host_time        — scheduling, packing, metadata build, commit,
    #                      finalize (pure host work)
    #   dispatch_time    — time inside jitted calls (enqueue overhead on an
    #                      async backend, ≈ device compute on a sync one)
    #   blocked_sync_time— time spent *waiting* on device→host transfers
    #   blocking_syncs   — retrievals whose result was not already ready,
    #                      i.e. the syncs that actually stalled the host
    host_time: float = 0.0
    dispatch_time: float = 0.0
    blocked_sync_time: float = 0.0
    blocking_syncs: int = 0
    model_dispatches: int = 0        # hot-path model program launches
    host_syncs: int = 0              # device→host result transfers
    packed_pad_tokens: int = 0       # bucketing padding launched (packed step)
    dense_batch_hist: dict[int, int] = dataclasses.field(default_factory=dict)
    # iterations per launched KV-length bucket (DESIGN.md §9; packed step)
    kv_bucket_hist: dict[int, int] = dataclasses.field(default_factory=dict)
    # Σ launch_tokens × kv_bucket — the packed-attention score-work actually
    # launched; compare against launch_tokens × max_len to see the bucketing
    # saving (attention FLOPs/bytes scale with this, not with max_len)
    packed_attn_kv_rows: int = 0
    # modeled TP collective traffic (DESIGN.md §11; ring all-reduce wire
    # bytes per tp_lib.collective_bytes_per_iter) — 0 at tp=1
    tp_collective_bytes: int = 0
    # speculative decoding (DESIGN.md §13): drafts launched into verify
    # segments, drafts the target model accepted, and verify segments
    # retired — acceptance is counted at retire time (device truth), so
    # decode_tokens stays the committed-token trajectory
    spec_proposed_tokens: int = 0
    spec_accepted_tokens: int = 0
    spec_verify_segments: int = 0
    # fault-tolerant re-dispatch (DESIGN.md §14): requests checkpointed and
    # handed back by ``evacuate`` (replica failure or graceful leave), and
    # the committed tokens they fold into their replay prefix — the
    # re-prefill work another replica will absorb
    evacuated_requests: int = 0
    evacuated_tokens: int = 0
    # int8 KV quantization (DESIGN.md §15): cache bytes saved vs the
    # native-dtype layout for every token row written (counted at launch —
    # tokens × (native rate − quantized rate)), and the most recent measured
    # logit-drift sample (filled by benchmarks/tests that run the bf16 A/B;
    # the engine itself never pays for a second forward)
    kv_quant_bytes_saved: int = 0
    kv_quant_drift: Optional[float] = None

    @property
    def total_tokens(self) -> int:
        return self.prefill_tokens + self.decode_tokens

    @property
    def throughput(self) -> float:
        return self.total_tokens / self.wall_time if self.wall_time else 0.0

    @property
    def prefill_expansion(self) -> float:
        """Model-token-positions per prompt token (1.0 == linear prefill)."""
        return (self.prefill_model_tokens / self.prefill_tokens
                if self.prefill_tokens else 0.0)

    @property
    def dispatches_per_iter(self) -> float:
        return self.model_dispatches / self.iterations if self.iterations else 0.0

    @property
    def syncs_per_iter(self) -> float:
        return self.host_syncs / self.iterations if self.iterations else 0.0

    @property
    def blocking_syncs_per_iter(self) -> float:
        """Steady-state pipeline health (§10): < 1 means some iterations'
        results were already on host when the engine asked for them — the
        host/device overlap absorbed the sync."""
        return self.blocking_syncs / self.iterations if self.iterations \
            else 0.0

    @property
    def tp_collective_bytes_per_iter(self) -> float:
        return self.tp_collective_bytes / self.iterations \
            if self.iterations else 0.0

    @property
    def spec_acceptance_rate(self) -> float:
        """Fraction of launched draft tokens the target model accepted."""
        return self.spec_accepted_tokens / self.spec_proposed_tokens \
            if self.spec_proposed_tokens else 0.0

    @property
    def spec_accepted_per_verify(self) -> float:
        """Committed tokens per verify segment (the base sample plus
        accepted drafts): > 1 means speculation beats one-token decode."""
        return (self.spec_accepted_tokens + self.spec_verify_segments) \
            / self.spec_verify_segments if self.spec_verify_segments else 0.0

    _DERIVED = ("total_tokens", "throughput", "prefill_expansion",
                "dispatches_per_iter", "syncs_per_iter",
                "blocking_syncs_per_iter", "tp_collective_bytes_per_iter",
                "spec_acceptance_rate", "spec_accepted_per_verify")

    def snapshot(self) -> dict:
        """Common stats schema (same contract as ``KVStats.snapshot``):
        every counter field plus the derived ratios, consumed by serve.py
        prints, benchmark JSON artifacts, and tests."""
        out = {f.name: getattr(self, f.name)
               for f in dataclasses.fields(self)}
        out["dense_batch_hist"] = dict(self.dense_batch_hist)
        out["kv_bucket_hist"] = dict(self.kv_bucket_hist)
        for name in self._DERIVED:
            out[name] = getattr(self, name)
        return out


@dataclasses.dataclass
class _InFlight:
    """One launched-but-unretired packed iteration (DESIGN.md §10): the
    deferred device→host sync is the ``tokens`` handle — the per-slot
    payload ``(max_slots, W + 1)`` of token ring ‖ accept_len (§13), one
    transfer per iteration regardless of speculation width."""
    plan: BatchPlan
    sample_at: list              # (rid, slot, kind) triples; kind is
    #                              "decode" | "verify" | "prefill"
    tokens: jax.Array            # payload handle, not yet transferred


def _to_token(v) -> int:
    """Sampled array element -> token id (multi-codebook frontends keep
    codebook 0 — the one rule, shared by every step path)."""
    return int(v) if np.ndim(v) == 0 else int(v.flat[0])


class ServeEngine:
    #: legacy keyword -> EngineConfig field (one release of back-compat;
    #: ``page_size`` is the old name for the block-table block size)
    _LEGACY_KWARGS = {
        "max_slots": "max_slots", "max_len": "max_len",
        "page_size": "kv_block_size", "kv_block_size": "kv_block_size",
        "total_pages": "total_pages", "kv_budget_bytes": "kv_budget_bytes",
        "avg_decode_len": "avg_decode_len",
        "discrete_sizes": "discrete_sizes", "prefill_mode": "prefill_mode",
        "step_mode": "step_mode", "async_depth": "async_depth",
        "async_harvest": "async_harvest", "nano": "nano", "tp": "tp",
        "kv_buckets": "kv_buckets", "kv_bucketing": "kv_bucketing",
        "prefix_caching": "prefix_caching", "attn_fast": "attn_fast",
        "attn_stream": "attn_stream", "seed": "seed",
        "spec_k": "spec_k", "drafter": "drafter",
        "temperature": "temperature", "top_k": "top_k",
        "kv_dtype": "kv_dtype",
    }

    def __init__(self, cfg: ModelConfig, params,
                 config: Optional[EngineConfig] = None, **kwargs):
        """``ServeEngine(cfg, params, EngineConfig(...))`` is the
        configuration surface; every engine knob lives on the frozen
        ``EngineConfig`` (serving/config.py), validated in its
        ``__post_init__``.  ``**kwargs`` are accepted as overrides on top of
        ``config`` — and, with no ``config``, as the legacy keyword style
        (deprecated for one release; ``page_size`` maps to
        ``kv_block_size``)."""
        unknown = set(kwargs) - set(self._LEGACY_KWARGS)
        if unknown:
            raise TypeError(f"unknown engine kwargs: {sorted(unknown)}")
        mapped = {self._LEGACY_KWARGS[k]: v for k, v in kwargs.items()}
        if config is None:
            if mapped:
                warnings.warn(
                    "ServeEngine(cfg, params, max_slots=..., ...) keyword "
                    "configuration is deprecated; pass "
                    "ServeEngine(cfg, params, EngineConfig(...))",
                    DeprecationWarning, stacklevel=2)
            config = EngineConfig(**mapped)
        elif mapped:
            config = dataclasses.replace(config, **mapped)
        self.config = config
        self.cfg = cfg
        self.params = params
        self.max_slots = config.max_slots
        self.max_len = config.max_len
        self.prefill_mode = config.prefill_mode
        self.step_mode = config.resolved_step_mode
        # async pipeline (DESIGN.md §10): up to async_depth iterations stay
        # in flight; async_harvest additionally retires any already-finished
        # iteration without blocking, shrinking the speculation window
        # (tests pin it False to exercise worst-case lag deterministically)
        self.async_depth = config.resolved_async_depth
        self.async_harvest = bool(config.async_harvest)
        self._ring: deque[_InFlight] = deque()
        # commit/arrival timestamp source: the replica pool (DESIGN.md §14)
        # injects a virtual clock for deterministic SLO tests; duration
        # accounting (host/dispatch/blocked splits) always uses perf_counter
        self._clock = time.perf_counter
        self.nano = config.nano
        self.key = jax.random.PRNGKey(config.seed)
        # §Perf HC3 toggles (single source of truth: EngineConfig): resolved
        # ONCE here — an explicit config value wins, else the process
        # default (an active ops.attn_config pin, else one env read) — and
        # pinned around every jitted trace body, so a later env flip can
        # never silently change a retrace (EngineConfig.from_env pins env
        # into explicit field values for callers who want that eagerly)
        self.attn_fast = bool(config.attn_fast) \
            if config.attn_fast is not None else ops.attn_fast_default()
        self.attn_stream = bool(config.attn_stream) \
            if config.attn_stream is not None else ops.attn_stream_default()
        # KV-length bucket grid (DESIGN.md §9): the packed step sweeps only
        # the iteration's bucket, not max_len; kv_bucketing=False pins the
        # single max_len bucket (the pre-§9 dense-vs-full-cache behaviour,
        # kept for A/B)
        self.kv_buckets = config.resolved_kv_buckets()

        # KV storage dtype (DESIGN.md §15): "bf16" keeps the model's native
        # dtype; "int8" swaps the attention cache leaves for int8 values +
        # f32 per-(token, kv-head) scales — quantize-at-scatter in the
        # packed program, dequant-on-load in the attention kernel
        self.kv_dtype = config.kv_dtype
        self._cache_kv_dtype = "int8" if self.kv_dtype == "int8" else None
        # per-token KV bytes from the actual cache leaves — NOT the GQA
        # formula: MLA caches only the latent (c_kv + k_rope) and
        # attention-free recurrent models cache nothing per token
        page_size = config.kv_block_size
        kv_bytes = kv_bytes_per_token(cfg, self._cache_kv_dtype)
        # native-dtype rate, kept for the bytes-saved counter (== kv_bytes
        # when not quantizing, so the saving reads 0)
        self._kv_bytes_native = kv_bytes if self._cache_kv_dtype is None \
            else kv_bytes_per_token(cfg)
        if config.total_pages is not None:
            pages = config.total_pages
        elif config.kv_budget_bytes is not None and kv_bytes > 0:
            # device KV budget in bytes -> pages the budget actually buys
            # (what the wrong bytes-per-token used to corrupt: deepseek-style
            # MLA got ~an order of magnitude fewer pages than its latent
            # cache needs)
            pages = max(int(config.kv_budget_bytes)
                        // (kv_bytes * page_size), 1)
        else:
            pages = config.max_slots * config.max_len // page_size
        # cross-request prefix caching (DESIGN.md §12): block-table mode —
        # block ids ARE physical storage (flat slot-cache rows / block
        # size), so the pool is capped at what the leaves can hold, and the
        # model must be attention-only (recurrent mixers carry per-slot
        # state that cannot be block-shared)
        self.prefix_caching = bool(config.prefix_caching)
        if self.prefix_caching:
            assert all(s.mixer == ATTN for s in cfg.layer_specs()), \
                "prefix caching (DESIGN.md §12) needs attention-only models"
            for b in self.kv_buckets:
                assert b % page_size == 0, \
                    (f"kv bucket {b} not divisible by kv_block_size "
                     f"{page_size}")
            pages = min(pages, config.max_slots * config.max_len // page_size)
        self._nb_cols = config.max_len // page_size
        self.kv = PagedKVManager(total_pages=pages, page_size=page_size,
                                 bytes_per_token=kv_bytes,
                                 avg_decode_len=config.avg_decode_len,
                                 prefix_caching=self.prefix_caching)
        # speculative decoding (DESIGN.md §13): each decoding slot launches
        # a spec_k+1-token verify segment; acceptance/rollback happen
        # on-device, so the mode needs attention-only models — rejected
        # positions just stay unattended cache rows, whereas a recurrent
        # mixer's per-slot state would already have advanced through them
        self.spec_k = int(config.spec_k)
        if self.spec_k:
            assert all(s.mixer == ATTN for s in cfg.layer_specs()), \
                "speculative decoding (DESIGN.md §13) needs attention-only " \
                "models (recurrent state cannot roll back rejected positions)"
        self.drafter = (draft_lib.make_drafter(config.resolved_drafter)
                        if self.spec_k else None)
        # packed-step sampling (greedy when temperature == 0 — the default
        # and the spec-decode exactness baseline)
        self.temperature = float(config.temperature)
        self.top_k = config.top_k
        self.scheduler = GlobalBatchScheduler(
            self.kv, discrete_sizes=config.discrete_sizes,
            max_active=config.max_slots, kv_buckets=self.kv_buckets,
            max_request_len=self.max_len, spec_k=self.spec_k,
            drafter=self.drafter)

        # slot caches: model cache trees with leading batch = max_slots
        self.cache = model_lib.init_cache(cfg, 1, self.max_slots,
                                          self.max_len, self._cache_kv_dtype)
        self.cache_len = jnp.zeros((self.max_slots,), jnp.int32)
        # device-resident sampled-token feedback (DESIGN.md §10), generalized
        # to the per-slot token ring (§13): row = the W = spec_k+1 samples of
        # the slot's last verify segment, of which the first accept_len were
        # accepted.  The packed program scatters each sample point's tokens
        # here and gathers the next iteration's decode inputs from
        # ring[slot, accept_len-1] *in-program*, so accepted tokens never
        # touch the host to form the next input stream (multi-codebook
        # frontends keep codebook 0, matching the host feedback path).
        # W = 1 collapses exactly to the §10 single-token buffer.
        self.last_token = jnp.zeros((self.max_slots, self.spec_k + 1),
                                    jnp.int32)
        self.accept_len = jnp.ones((self.max_slots,), jnp.int32)
        self.slot_free = list(range(self.max_slots))
        self.stats = EngineStats()
        # host mirror of each slot's context length (packed step builds its
        # per-token positions from this without any device read).  With
        # speculation this is the *upper bound* — every verify launch
        # advances it by W; retire resyncs it to the committed truth
        # (total_tokens - 1 + inflight), so it never drifts past what the
        # scheduler's worst-case KV accounting already covers
        self._pos = np.zeros((self.max_slots,), np.int64)

        # fresh one-slot cache, scattered into a slot on (re)assignment so a
        # reused slot never leaks the previous request's recurrent state
        self._slot_init = model_lib.init_cache(cfg, 1, 1, self.max_len,
                                               self._cache_kv_dtype)

        # tensor parallelism (DESIGN.md §11): 1-D ("model",) mesh, params
        # and slot caches placed with the manual shard_map layout (fused
        # x‖z / u‖g projection columns re-interleaved so each shard holds
        # matching halves); the last_token / cache_len buffers stay
        # replicated so the §10 feedback loop closes without a collective
        self.tp = int(config.tp)
        self._mesh = None
        # modeled collective wire bytes per launched token (linear in T):
        # resolved once here so the per-iteration stats update off the §10
        # host hot path is a single multiply
        self._tp_iter_bytes = tp_lib.collective_bytes_per_iter(
            cfg, 1, self.tp, jnp.dtype(cfg.dtype).itemsize)
        if self.tp > 1:
            tp_lib.validate_tp(cfg, self.tp)
            self._mesh = make_tp_mesh(self.tp)
            self.params = tp_lib.shard_params_tp(cfg, self.params, self._mesh)
            self.cache = tp_lib.shard_cache_tp(cfg, self.cache, self._mesh,
                                               self._cache_kv_dtype)
            self._slot_init = tp_lib.shard_cache_tp(cfg, self._slot_init,
                                                    self._mesh,
                                                    self._cache_kv_dtype)
            rep = NamedSharding(self._mesh, P())
            self.cache_len = jax.device_put(self.cache_len, rep)
            self.last_token = jax.device_put(self.last_token, rep)
            self.accept_len = jax.device_put(self.accept_len, rep)

        # one compiled program per (bucketed launch length T, kv bucket) —
        # the compile cache is bounded by |discrete dense sizes| × |kv
        # buckets| (kv_bucket is static: it sets the swept cache extent;
        # the last_token buffer is a traced operand, NOT a trace axis).
        # tp=1 jits the body directly (the exact single-device path);
        # tp>1 wraps the same body in shard_map over the mesh — same
        # trace axes, so the compile-cache bound is preserved per mesh
        if self.tp == 1:
            self._packed_step = jax.jit(self._packed_impl,
                                        donate_argnums=(1, 8, 9),
                                        static_argnums=(16,))
        else:
            self._packed_step = self._build_packed_tp_step()
        # block-table operands (DESIGN.md §12) are traced arrays of static
        # shape, so they add no compile-cache axis; outside prefix mode the
        # step gets these (1,) dummies, which the python-constant
        # ``prefix_caching`` branch in ``_packed_core`` dead-code-eliminates
        self._dummy_dst = jnp.zeros((1,), jnp.int32)
        self._dummy_blk = jnp.zeros((1,), jnp.int32)
        # whole-block device copy for copy-on-write divergence: (src, dst)
        # are traced scalars, so ALL CoW traffic shares one compiled
        # program; the donated cache makes each copy a data dependency of
        # the following packed dispatch (device-order safety without a sync)
        self._cow_step = jax.jit(self._cow_impl, donate_argnums=(0,))
        self._decode_step = jax.jit(self._decode_impl, donate_argnums=(1,))
        # one compiled program per bucketed chunk length (scheduler-quantized)
        self._prefill_step = jax.jit(self._prefill_impl, donate_argnums=(1,))
        self._reset_step = jax.jit(_reset_slot, donate_argnums=(0,))

    # ---- jitted decode over all slots (static shapes) -----------------------
    def _decode_impl(self, params, cache, tokens, cache_len, active):
        with ops.attn_config(fast=self.attn_fast, stream=self.attn_stream):
            logits, new_cache = model_lib.forward_decode(
                self.cfg, params, tokens, cache, cache_len)
        next_tok = sampling.greedy(logits)
        # Mask the *recurrent* state update to decoding slots: a mid-prefill
        # slot's carried SSM/LSTM state must not be advanced by its garbage
        # decode token.  Attention K/V leaves keep the donated in-place
        # update: the garbage row lands at the slot's cache_len, which the
        # next prefill chunk overwrites before attending — selecting the big
        # seq-dim leaves would force a full cache copy per decode step.
        def sel(n, o):
            m = active.reshape((1, -1) + (1,) * (n.ndim - 2))
            return jnp.where(m, n, o)
        out = []
        for gi, (pattern, reps) in enumerate(self.cfg.layer_groups()):
            g = {}
            for i, spec in enumerate(pattern):
                n_sub = new_cache[gi][f"sub{i}"]
                g[f"sub{i}"] = n_sub if spec.mixer == ATTN else jax.tree.map(
                    sel, n_sub, cache[gi][f"sub{i}"])
            out.append(g)
        return next_tok, out

    # ---- jitted incremental prefill chunk (one slot, bucketed length) -------
    def _prefill_impl(self, params, cache, tokens, slot, offset):
        """tokens: (1, L[, K]) — the next L prompt positions of ``slot``
        after an ``offset``-token prefix.  Gathers the slot's sub-cache,
        runs ``forward_chunk``, scatters the updated sub-cache back
        (partial-prefix write at an arbitrary offset).  ``slot`` and
        ``offset`` are traced, so one compiled program serves every slot and
        prefix depth of a given chunk length."""
        sub = jax.tree.map(
            lambda a: jax.lax.dynamic_slice_in_dim(a, slot, 1, axis=1),
            cache)
        with ops.attn_config(fast=self.attn_fast, stream=self.attn_stream):
            logits, new_sub = model_lib.forward_chunk(
                self.cfg, params, tokens, sub, offset[None])
        new_cache = jax.tree.map(
            lambda c, s: jax.lax.dynamic_update_slice_in_dim(
                c, s.astype(c.dtype), slot, axis=1),
            cache, new_sub)
        return sampling.greedy(logits[:, -1]), new_cache

    # ---- jitted token-packed step (one dispatch per iteration) --------------
    def _packed_impl(self, params, cache, tokens, token_slot, token_pos,
                     token_active, cache_len, reset, last_token, accept_len,
                     from_last, sample_slot, verify_idx, token_rid, token_dst,
                     block_tables, kv_bucket):
        """tp=1 entry: the packed body with the fresh-slot cache closed over
        (the TP entry passes it as a shard_map operand instead)."""
        return self._packed_core(params, cache, tokens, token_slot, token_pos,
                                 token_active, cache_len, reset, last_token,
                                 accept_len, from_last, sample_slot,
                                 verify_idx, token_rid, token_dst,
                                 block_tables, self._slot_init, kv_bucket)

    def _packed_core(self, params, cache, tokens, token_slot, token_pos,
                     token_active, cache_len, reset, last_token, accept_len,
                     from_last, sample_slot, verify_idx, token_rid, token_dst,
                     block_tables, slot_init, kv_bucket):
        """The whole iteration as one program (DESIGN.md §8): reset reused
        slots' recurrent state, substitute the stream's decode placeholders
        with the device-resident token ring (§10/§13 — the previous
        iteration's samples never round-trip through the host), run the
        packed multi-segment forward, sample on-device (greedy by default),
        scatter the samples back into the ring at the stream's sample
        points, and advance ``cache_len`` from the per-token metadata — so
        the only device→host transfer is the per-slot payload (ring ‖
        accept_len), and even that one is deferrable (``async_depth``).

        With speculation (``spec_k > 0``, DESIGN.md §13) each decoding
        slot's row of ``verify_idx`` names its W = spec_k+1 stream
        positions.  Their true positions are computed HERE from the donated
        ``cache_len`` chain (``base + 0..k``), overwriting the host's
        worst-case values — that is what lets the host launch iteration
        i+1 before it knows how many of iteration i's drafts were
        accepted.  Acceptance is exact prefix matching (greedy) /
        sample-and-compare rejection sampling (stochastic, point-mass
        drafter): draft j is accepted iff it equals the target sample at
        position j-1; the committed run is the base sample plus the
        accepted prefix, ``accept_len = accepted + 1``, and ``cache_len``
        advances by exactly that (the on-device rollback — rejected
        positions' KV rows sit above the new length and are overwritten by
        the next verify segment before anything attends them).

        ``kv_bucket`` is static (DESIGN.md §9): attention sweeps only that
        many cache rows per slot, so the program's attention cost tracks
        the iteration's actual context, not ``max_len``.  Under TP this
        exact body runs inside ``shard_map`` (DESIGN.md §11) with a
        ``tp_ctx`` active, so the mixer families' reduction points become
        real collectives."""
        cache = self._reset_recurrent(cache, reset, slot_init)
        W = self.spec_k + 1
        T = token_slot.shape[0]
        pos = token_pos
        if self.spec_k:
            # device-true verify positions: segment j writes cache_len + j
            vpos = cache_len[:, None] + jnp.arange(W, dtype=jnp.int32)[None, :]
            pos = pos.at[verify_idx.reshape(-1)].set(
                vpos.reshape(-1).astype(pos.dtype), mode="drop")
            is_verify = jnp.zeros((T,), bool).at[
                verify_idx.reshape(-1)].set(True, mode="drop")
            verify_on = verify_idx[:, 0] < T            # (max_slots,)
        token_wpos = jnp.where(token_active, pos, self.max_len) \
            .astype(jnp.int32)
        toks = sampling.substitute_last(tokens, last_token, token_slot,
                                        from_last, accept_len=accept_len)
        if self.prefix_caching and self.spec_k:
            # verify write targets follow the device-true positions through
            # the slot's block table (the host left them OOB)
            bs = self.kv.page_size
            blk = block_tables[token_slot,
                               jnp.minimum(pos // bs, self._nb_cols - 1)]
            vdst = blk.astype(token_dst.dtype) * bs + pos % bs
            token_dst = jnp.where(is_verify & token_active, vdst, token_dst)
        with ops.attn_config(fast=self.attn_fast, stream=self.attn_stream):
            # self.prefix_caching is a python constant per engine, so the
            # non-prefix trace never sees the (dummy) block operands at all
            logits, new_cache = model_lib.forward_packed(
                self.cfg, params, toks, cache, token_slot, pos,
                token_wpos, token_active, kv_bucket=kv_bucket,
                token_dst=token_dst if self.prefix_caching else None,
                block_tables=block_tables if self.prefix_caching else None)
        if self.temperature > 0:
            # keys fold (rid, pos) ONLY — launch-index and slot independent
            # (sampling.packed_keys), so stochastic serving replays exactly
            # and §13 re-verifies of a rejected position repeat the same
            # draw (point-mass speculation stays token-exact)
            keys = sampling.packed_keys(self.key, token_rid, pos,
                                        self.max_len + 1)
            next_tok = sampling.sample_tokens(logits[0], keys,
                                              self.temperature, self.top_k)
        else:
            next_tok = sampling.greedy(logits[0])
        # multi-codebook frontends keep codebook 0 (the one rule, §10)
        tok0 = next_tok if next_tok.ndim == 1 else next_tok[:, 0]
        new_len = jnp.where(reset, 0, cache_len)
        if self.spec_k:
            in0 = toks[0] if toks.ndim == 2 else toks[0, :, 0]
            # per verify slot: the W inputs and W target samples of its
            # segment (fill values never match each other on OOB rows)
            seg_in = jnp.take(in0.astype(jnp.int32), verify_idx, axis=0,
                              mode="fill", fill_value=-1)
            seg_out = jnp.take(tok0, verify_idx, axis=0, mode="fill",
                               fill_value=-2)
            # draft j (input j) is accepted iff it equals target sample
            # j-1; the accepted run is the longest matching prefix
            match = (seg_in[:, 1:] == seg_out[:, :-1]).astype(jnp.int32)
            acc = jnp.cumprod(match, axis=1).sum(axis=1)
            n_acc = jnp.where(verify_on, acc + 1, accept_len)
            new_ring = jnp.where(verify_on[:, None], seg_out, last_token)
            nv = token_active & ~is_verify
            new_len = new_len.at[token_slot].max(jnp.where(nv, pos + 1, 0))
            # the §13 rollback: verify slots advance by the accepted count
            # only; rejected rows sit above new_len, overwritten next launch
            new_len = jnp.where(verify_on, cache_len + acc + 1, new_len)
        else:
            n_acc = accept_len
            new_ring = last_token
            new_len = new_len.at[token_slot].max(
                jnp.where(token_active, pos + 1, 0))
        # single-sample points (prefill-final; every decode at spec_k=0)
        # write ring column 0 with accept_len 1
        new_ring = new_ring.at[sample_slot, 0].set(
            tok0.astype(new_ring.dtype), mode="drop")
        n_acc = n_acc.at[sample_slot].set(1, mode="drop")
        payload = jnp.concatenate(
            [new_ring, n_acc[:, None].astype(new_ring.dtype)], axis=1)
        return payload, new_cache, new_len, new_ring, n_acc

    def _build_packed_tp_step(self):
        """jit(shard_map(packed body)) over the 1-D TP mesh (DESIGN.md
        §11).  The body is ``_packed_core`` unchanged, traced under a
        ``tp_ctx`` whose nano split comes from the (static) launch length —
        so the compile cache still keys only on (T bucket, kv bucket), and
        the nano-batch layout governs how the row-parallel all-reduces are
        chunked.  Returns a callable with the tp=1 step's signature (the
        fresh-slot cache is injected as a shard_map operand here; carries
        ``_cache_size`` for the compile-cache-bound assertions)."""
        mesh = self._mesh
        param_specs = tp_lib.param_pspecs_tp(self.cfg)
        cache_specs = tp_lib.cache_pspecs_tp(self.cfg, self._cache_kv_dtype)
        rep = P()
        # token_dst / block_tables / verify_idx ride as replicated
        # operands: the cache leaves shard on head/channel axes only, so
        # block ids (flat (slot, seq) rows / block size) and stream indices
        # are shard-local and identical on every shard (DESIGN.md §12/§13)
        in_specs = (param_specs, cache_specs) + (rep,) * 14 + (cache_specs,)
        out_specs = (rep, cache_specs, rep, rep, rep)

        def entry(params, cache, tokens, token_slot, token_pos,
                  token_active, cache_len, reset, last_token, accept_len,
                  from_last, sample_slot, verify_idx, token_rid, token_dst,
                  block_tables, slot_init, kv_bucket):
            def body(params, cache, tokens, token_slot, token_pos,
                     token_active, cache_len, reset, last_token, accept_len,
                     from_last, sample_slot, verify_idx, token_rid,
                     token_dst, block_tables, slot_init):
                nano = nano_batch_sizes_for(tokens.shape[1], self.nano).sizes
                with tp_lib.tp_ctx("model", self.tp, nano):
                    return self._packed_core(
                        params, cache, tokens, token_slot, token_pos,
                        token_active, cache_len, reset, last_token,
                        accept_len, from_last, sample_slot, verify_idx,
                        token_rid, token_dst, block_tables, slot_init,
                        kv_bucket)
            return shard_map_compat(body, mesh, in_specs, out_specs,
                                    check=False)(
                params, cache, tokens, token_slot, token_pos, token_active,
                cache_len, reset, last_token, accept_len, from_last,
                sample_slot, verify_idx, token_rid, token_dst, block_tables,
                slot_init)

        jitted = jax.jit(entry, donate_argnums=(1, 8, 9),
                         static_argnums=(17,))

        def step(params, cache, tokens, token_slot, token_pos, token_active,
                 cache_len, reset, last_token, accept_len, from_last,
                 sample_slot, verify_idx, token_rid, token_dst, block_tables,
                 kv_bucket):
            return jitted(params, cache, tokens, token_slot, token_pos,
                          token_active, cache_len, reset, last_token,
                          accept_len, from_last, sample_slot, verify_idx,
                          token_rid, token_dst, block_tables,
                          self._slot_init, kv_bucket)

        step._cache_size = jitted._cache_size
        return step

    def _reset_recurrent(self, cache, reset, slot_init):
        """Select fresh recurrent state for slots in ``reset`` (reused slots
        must not leak the previous request's SSM/LSTM state).  Attention
        leaves need no reset — rows at or beyond the new request's written
        extent are never attended — and skipping them keeps the masked
        select off the big (slots, max_len, ...) tensors."""
        out = []
        for gi, (pattern, reps) in enumerate(self.cfg.layer_groups()):
            g = {}
            for i, spec in enumerate(pattern):
                sub = cache[gi][f"sub{i}"]
                if spec.mixer == ATTN:
                    g[f"sub{i}"] = sub
                else:
                    g[f"sub{i}"] = jax.tree.map(
                        lambda c, z: jnp.where(
                            reset.reshape((1, -1) + (1,) * (c.ndim - 2)),
                            z.astype(c.dtype), c),
                        sub, slot_init[gi][f"sub{i}"])
            out.append(g)
        return out

    # ---- copy-on-write block copy (DESIGN.md §12) ---------------------------
    def _cow_impl(self, cache, src, dst):
        """Copy physical block ``src`` -> ``dst`` in every attention cache
        leaf (prefix caching implies an attention-only model).  ``src`` and
        ``dst`` are *traced* int32 scalars, so all CoW traffic shares ONE
        compiled program; the cache is donated, making each queued copy a
        data dependency of the next packed dispatch — device ordering
        without a host sync, and no extra ``model_dispatches``."""
        bs = self.kv.page_size

        def copy(c):
            # leaves are (L, slots, max_len, ...); blocks live in the flat
            # (slots*max_len) row space, sharded (if at all) on trailing
            # head/channel axes only — shard-local reshape is safe
            flat = c.reshape((c.shape[0], c.shape[1] * c.shape[2])
                             + c.shape[3:])
            blk = jax.lax.dynamic_slice_in_dim(flat, src * bs, bs, axis=1)
            flat = jax.lax.dynamic_update_slice_in_dim(flat, blk, dst * bs,
                                                       axis=1)
            return flat.reshape(c.shape)

        return jax.tree.map(copy, cache)

    # ---- public API ----------------------------------------------------------
    def submit(self, req: Request) -> None:
        # a slot holds max_len positions; without this clamp a request with
        # prompt_len + max_new_tokens > max_len decodes past the cache and
        # trips the kv-bucket bound mid-run (admission only checks pool
        # capacity, not per-slot extent).  Speculation reserves spec_k
        # extra rows of slack: a verify segment launched at the cap still
        # writes its (possibly rejected) draft positions (§13)
        req.max_new_tokens = min(
            req.max_new_tokens,
            max(self.max_len - req.prompt_len - self.spec_k, 0))
        self.scheduler.submit(req)

    @property
    def in_flight(self) -> int:
        """Launched-but-unretired iterations (§10); 0 once drained."""
        return len(self._ring)

    def run(self, max_iters: int = 10_000) -> list[Request]:
        done: list[Request] = []
        t0 = time.perf_counter()
        for _ in range(max_iters):
            tp = time.perf_counter()
            plan = self.scheduler.plan()
            self.stats.host_time += time.perf_counter() - tp
            if plan is None:
                if self._ring:
                    # nothing plannable until in-flight results land (e.g.
                    # every request sits in its post-EOS window): retire the
                    # oldest iteration and re-plan with its commits applied
                    done += self._retire_oldest()
                    continue
                break
            done += self.step(plan)
        done += self.drain()
        self.stats.wall_time += time.perf_counter() - t0
        return done

    def drain(self, max_retire: Optional[int] = None) -> list[Request]:
        """Retire in-flight iterations, oldest first (§10).  With no bound
        this is the exit barrier — ``run()`` drains before returning, and
        external plan/step drivers (online serving loops) must drain after
        their arrival loop so no sampled tokens are left on device.
        ``max_retire=1`` is the mid-loop idle step: retire just the oldest
        iteration (its commits may unblock planning) without flushing the
        whole pipeline and re-serializing host and device."""
        done: list[Request] = []
        retired = 0
        while self._ring and (max_retire is None or retired < max_retire):
            done += self._retire_oldest()
            retired += 1
        return done

    def evacuate(self, *, drain: bool = True) \
            -> tuple[list[Request], list[Request]]:
        """Checkpoint every unfinished request for re-dispatch on another
        replica (DESIGN.md §14) and release all engine-local state for them
        (slot, cache_len, KV blocks).  Returns ``(finished, moved)``.

        ``drain=True`` is the graceful drain-and-evacuate (replica leave):
        in-flight iterations retire first, so their sampled tokens commit
        and the replay prefix is as long as possible.  ``drain=False`` is
        the failure path: a dead replica's in-flight results are *lost* —
        the ring is abandoned unfetched and only committed tokens survive
        into the checkpoint (which is exactly what keeps the resumed
        generation token-exact: nothing uncommitted is ever replayed).

        Requests whose committed output already holds EOS (or whose budget
        is spent) finish here instead of moving — they have nothing left to
        generate, and re-running them would append past EOS."""
        finished: list[Request] = []
        if drain:
            finished += self.drain()
        else:
            self._ring.clear()
        moved: list[Request] = []
        sched = self.scheduler
        for r in list(sched.active) + list(sched.waiting):
            if r.state in (State.FINISHED, State.DISCARDED, State.REJECTED):
                continue
            if r.slot >= 0:
                self.slot_free.append(r.slot)
                self._pos[r.slot] = 0
                if drain:
                    # a live device: clear the slot length for reuse.  On
                    # the failure path the device is gone — skip the op.
                    self.cache_len = self.cache_len.at[r.slot].set(0)
                r.slot = -1
            self.kv.free(r.rid)
            folded = r.checkpoint_redispatch()
            if r.state == State.FINISHED:
                # EOS/budget already committed: finished at the checkpoint
                r.finished_at = self._clock()
                finished.append(r)
                continue
            self.stats.evacuated_requests += 1
            self.stats.evacuated_tokens += folded
            moved.append(r)
        sched.active = []
        sched.waiting.clear()
        return finished, moved

    def step(self, plan: BatchPlan) -> list[Request]:
        self.stats.iterations += 1
        self.stats.dense_batch_hist[plan.dense_batch] = \
            self.stats.dense_batch_hist.get(plan.dense_batch, 0) + 1
        if self.step_mode != "packed":
            self.scheduler.mark_launched(plan)
            sampled = self._step_legacy(plan)
            now = time.perf_counter()
            finished = self.scheduler.commit(plan, sampled, self._clock())
            for r in finished:
                self._finalize(r)
            self.stats.host_time += time.perf_counter() - now
            return finished
        # packed path: launch now, sync up to async_depth iterations later
        self._ring.append(self._launch_packed(plan))
        finished: list[Request] = []
        if self.async_harvest:
            # free retirement: anything whose result already landed commits
            # now, keeping the speculation window as small as possible
            while self._ring and self._ring[0].tokens.is_ready():
                finished += self._retire_oldest()
        while len(self._ring) > self.async_depth:
            finished += self._retire_oldest()
        return finished

    # ---- packed iteration: one dispatch, one (deferred) host sync -----------
    def _retire_oldest(self) -> list[Request]:
        """Transfer the oldest in-flight iteration's payload (the deferred
        sync — blocking only if the device hasn't caught up), commit its
        tokens to the scheduler, and finalize whatever finished.  The
        payload row for a verify slot is its token ring ‖ accept_len: the
        first ``accept_len`` ring entries are the committed run (§13);
        other sample points read ring column 0 (their accept_len is 1)."""
        inf = self._ring.popleft()
        payload = self._fetch(inf.tokens)        # (max_slots, W + 1)
        t1 = time.perf_counter()
        W = self.spec_k + 1
        sampled: dict[int, object] = {}
        for rid, s, kind in inf.sample_at:
            if kind == "verify":
                n_acc = int(min(max(payload[s, W], 1), W))
                sampled[rid] = [int(x) for x in payload[s, :n_acc]]
                self.stats.spec_verify_segments += 1
                self.stats.spec_proposed_tokens += self.spec_k
                self.stats.spec_accepted_tokens += n_acc - 1
                # launch counted the guaranteed base sample; add the rest
                self.stats.decode_tokens += n_acc - 1
            else:
                sampled[rid] = int(payload[s, 0])
        finished = self.scheduler.commit(inf.plan, sampled, self._clock())
        for r in finished:
            self._finalize(r)
        if self.spec_k:
            # resync the host position upper bound to the committed truth:
            # each launch advanced _pos by the worst case W while the
            # device advanced by the accepted count — without this the
            # bound would drift one rejected-draft's worth per commit.
            # (total_tokens - 1) is the device cache_len after this commit
            # with nothing in flight; each still-in-flight launch adds at
            # most its worst case, which `inflight` counts exactly.
            for r in inf.plan.decode:
                if r.slot >= 0 and r.state not in (State.FINISHED,
                                                   State.DISCARDED):
                    self._pos[r.slot] = r.total_tokens - 1 + r.inflight
        self.stats.host_time += time.perf_counter() - t1
        return finished

    def _fetch(self, handle: jax.Array) -> np.ndarray:
        """Device→host retrieval with overlap accounting: counts the sync,
        the time spent waiting, and whether it actually blocked (the result
        was not yet ready — §10's pipeline-health signal)."""
        t0 = time.perf_counter()
        ready = handle.is_ready()
        out = np.asarray(handle)
        self.stats.blocked_sync_time += time.perf_counter() - t0
        self.stats.host_syncs += 1
        if not ready:
            self.stats.blocking_syncs += 1
        return out

    def _launch_packed(self, plan: BatchPlan) -> _InFlight:
        t_host = time.perf_counter()
        packed = self.scheduler.pack(plan, nano=self.nano)
        W = self.spec_k + 1
        reset = np.zeros((self.max_slots,), bool)
        for seg in packed.segments:
            r = seg.req
            if r.slot < 0:
                assert self.slot_free, "scheduler admitted beyond slot capacity"
                r.slot = self.slot_free.pop()
                reset[r.slot] = True
                self._pos[r.slot] = 0

        bs = self.kv.page_size
        oob = self.max_slots * self.max_len
        if self.prefix_caching:
            # decode writes land at pos = _pos[slot] .. _pos[slot]+W-1 (not
            # yet advanced; the worst case under speculation): grow each
            # decoding request's block table NOW, launch-side, so the write
            # targets exist before the (possibly deferred-commit)
            # ``extend`` ever runs (DESIGN.md §12)
            for seg in packed.segments:
                if seg.is_decode:
                    self.kv.ensure(seg.req.rid,
                                   int(self._pos[seg.req.slot]) + W)

        t_total = packed.launch_tokens
        tokens = np.zeros((t_total,), np.int32)
        slot = np.zeros((t_total,), np.int32)
        pos = np.zeros((t_total,), np.int32)
        active = np.zeros((t_total,), bool)
        # decode positions take the ring's newest accepted token on device
        # (§10/§13): the host writes a placeholder and never needs the
        # sampled value
        from_last = np.zeros((t_total,), bool)
        # block-table operands (prefix mode): per-token flat scatter target
        # (OOB = dropped write, covers padding) and per-slot block tables
        token_dst = np.full((t_total,), oob, np.int64)
        tables_arr = np.zeros((self.max_slots, self._nb_cols), np.int32)
        # per-slot verify stream positions (§13); OOB rows (== t_total)
        # mark slots with no verify segment this iteration
        verify_idx = np.full((self.max_slots, W), t_total, np.int32)
        # per-token request id: the stochastic sampler's PRNG identity
        # (sampling.packed_keys folds (rid, pos) — slot- and
        # launch-independent); dead under greedy
        rid_arr = np.zeros((t_total,), np.int32)
        sample_at: list[tuple[int, int, str]] = []   # (rid, slot, kind)
        t = 0
        for seg in packed.segments:
            r = seg.req
            tbl = None
            if self.prefix_caching:
                tbl = np.asarray(self.kv.table(r.rid), np.int64)
                # the allocator sizes tables by *predicted* length
                # (prompt + avg_decode), which may exceed max_len — blocks
                # past max_len // bs hold no writable positions, so the
                # gather table only needs the addressable prefix
                nb = min(len(tbl), self._nb_cols)
                tables_arr[r.slot, :nb] = tbl[:nb]
            rid_arr[t:t + seg.length] = r.rid & 0x7fffffff
            if seg.is_decode:
                from_last[t] = True
                slot[t:t + W] = r.slot
                p = int(self._pos[r.slot])
                # host positions are the worst-case bound; with spec_k > 0
                # the program recomputes the true ones from cache_len
                pos[t:t + W] = p + np.arange(W)
                active[t:t + W] = True
                if self.spec_k:
                    tokens[t + 1:t + W] = seg.draft
                    verify_idx[r.slot] = np.arange(t, t + W)
                    sample_at.append((r.rid, r.slot, "verify"))
                else:
                    if tbl is not None and p // bs < len(tbl):
                        token_dst[t] = tbl[p // bs] * bs + p % bs
                    sample_at.append((r.rid, r.slot, "decode"))
                t += W
            else:
                ln = seg.length
                tokens[t:t + ln] = r.prompt[seg.offset:seg.offset + ln]
                slot[t:t + ln] = r.slot
                qs = np.arange(seg.offset, seg.offset + ln)
                pos[t:t + ln] = qs
                active[t:t + ln] = True
                if tbl is not None and len(tbl):
                    cov = qs // bs < len(tbl)
                    token_dst[t:t + ln] = np.where(
                        cov, tbl[np.minimum(qs // bs, len(tbl) - 1)] * bs
                        + qs % bs, oob)
                if seg.offset + ln == r.prompt_len:
                    sample_at.append((r.rid, r.slot, "prefill"))
                t += ln
        assert t == packed.tokens, (t, packed.tokens)
        # single-sample points scatter into ring column 0; non-sample
        # positions write out of bounds -> dropped.  Verify segments are
        # NOT sample points — their whole row lands via the acceptance path
        sample_slot = np.full((t_total,), self.max_slots, np.int32)
        t = 0
        for seg in packed.segments:
            if seg.is_decode:
                if not self.spec_k:
                    sample_slot[t] = seg.req.slot
                t += W
            else:
                if seg.offset + seg.length == seg.req.prompt_len:
                    sample_slot[t + seg.length - 1] = seg.req.slot
                t += seg.length

        # iteration's KV-length bucket (DESIGN.md §9): every attended row
        # must sit below it — the scheduler quantized the max extent up
        # (host pos is the §13 worst case, so the check stays sufficient)
        kv_bucket = packed.kv_bucket if packed.kv_bucket is not None \
            else self.max_len
        assert not active.any() or int(pos[active].max()) < kv_bucket, \
            (int(pos[active].max()), kv_bucket)
        self.stats.kv_bucket_hist[kv_bucket] = \
            self.stats.kv_bucket_hist.get(kv_bucket, 0) + 1
        self.stats.packed_attn_kv_rows += packed.launch_tokens * kv_bucket
        if self.tp > 1:
            self.stats.tp_collective_bytes += \
                packed.launch_tokens * self._tp_iter_bytes

        tok_in = jnp.asarray(tokens[None])
        if self.cfg.frontend == "audio":
            tok_in = jnp.repeat(tok_in[..., None], self.cfg.num_codebooks,
                                axis=-1)
        # launch-side bookkeeping BEFORE dispatch: the scheduler's next plan
        # may be formed while this iteration is still on device (§10)
        self.scheduler.mark_launched(plan)
        n_decode = 0
        for seg in packed.segments:
            if seg.is_decode:
                self._pos[seg.req.slot] += W
                n_decode += 1
            else:
                self._pos[seg.req.slot] = seg.offset + seg.length
        # count the guaranteed base sample per decode/verify segment here;
        # accepted drafts are added at retire time (device truth)
        self.stats.decode_tokens += n_decode
        self.stats.prefill_tokens += packed.tokens - n_decode * W
        self.stats.prefill_model_tokens += packed.tokens - n_decode * W
        self.stats.packed_pad_tokens += packed.padding
        if self._cache_kv_dtype is not None:
            # cache bytes this launch did NOT write vs the native-dtype
            # layout: every real token scatters one quantized row per
            # attention layer (DESIGN.md §15)
            self.stats.kv_quant_bytes_saved += packed.tokens * \
                (self._kv_bytes_native - self.kv.bytes_per_token)
        if self.prefix_caching:
            dst_op = jnp.asarray(token_dst.astype(np.int32))
            tbl_op = jnp.asarray(tables_arr)
            # drain queued copy-on-write block copies BEFORE the dispatch:
            # cache donation chains each copy in front of the forward pass
            # on device, with no host sync and no extra model dispatch
            for c_src, c_dst in self.kv.take_pending_copies():
                self.cache = self._cow_step(self.cache, jnp.int32(c_src),
                                            jnp.int32(c_dst))
        else:
            dst_op, tbl_op = self._dummy_dst, self._dummy_blk
        t_disp = time.perf_counter()
        self.stats.host_time += t_disp - t_host
        payload, self.cache, self.cache_len, self.last_token, \
            self.accept_len = self._packed_step(
                self.params, self.cache, tok_in, jnp.asarray(slot),
                jnp.asarray(pos), jnp.asarray(active), self.cache_len,
                jnp.asarray(reset), self.last_token, self.accept_len,
                jnp.asarray(from_last), jnp.asarray(sample_slot),
                jnp.asarray(verify_idx), jnp.asarray(rid_arr),
                dst_op, tbl_op, kv_bucket)
        self.stats.dispatch_time += time.perf_counter() - t_disp
        self.stats.model_dispatches += 1
        return _InFlight(plan=plan, sample_at=sample_at, tokens=payload)

    # ---- legacy iteration: decode dispatch + one dispatch per chunk ---------
    def _step_legacy(self, plan: BatchPlan) -> dict[int, int]:
        sampled: dict[int, int] = {}

        # ---- batched decode over all slots (static shape) --------------------
        decode_reqs = [r for r in plan.decode if r.slot >= 0]
        if decode_reqs:
            tokens = np.zeros((self.max_slots, 1), np.int32)
            active = np.zeros((self.max_slots,), bool)
            for r in decode_reqs:
                tokens[r.slot, 0] = r.output[-1] if r.output else r.prompt[-1]
                active[r.slot] = True
            tok_in = jnp.asarray(tokens)
            if self.cfg.frontend == "audio":
                tok_in = jnp.repeat(tok_in[..., None], self.cfg.num_codebooks,
                                    axis=-1)
            t_disp = time.perf_counter()
            next_tok, self.cache = self._decode_step(
                self.params, self.cache, tok_in, self.cache_len,
                jnp.asarray(active))
            self.stats.dispatch_time += time.perf_counter() - t_disp
            self.stats.model_dispatches += 1
            self.cache_len = self.cache_len + jnp.asarray(active, jnp.int32)
            nt = self._fetch(next_tok)
            for r in decode_reqs:
                sampled[r.rid] = _to_token(nt[r.slot])
                self._pos[r.slot] += 1
            self.stats.decode_tokens += len(decode_reqs)

        # ---- chunked prefill -------------------------------------------------
        for chunk in plan.prefill:
            r = chunk.req
            if r.slot < 0:
                assert self.slot_free, "scheduler admitted beyond slot capacity"
                r.slot = self.slot_free.pop()
                self._pos[r.slot] = 0
                if self.prefill_mode == "incremental":
                    self.cache = self._reset_step(
                        self.cache, self._slot_init, jnp.int32(r.slot))
                    self.stats.model_dispatches += 1
            if self.prefill_mode == "incremental":
                last_tok = self._prefill_chunk(r, chunk.offset, chunk.length)
                self.stats.prefill_model_tokens += chunk.length
            else:
                last_tok = self._prefill_to(r, chunk.offset + chunk.length)
                self.stats.prefill_model_tokens += chunk.offset + chunk.length
            self.stats.prefill_tokens += chunk.length
            self._pos[r.slot] = chunk.offset + chunk.length
            if chunk.offset + chunk.length == r.prompt_len:
                sampled[r.rid] = last_tok
        return sampled

    # ---- internals -----------------------------------------------------------
    def _prefill_chunk(self, r: Request, offset: int, length: int) -> int:
        """Incremental path: run exactly ``length`` new prompt tokens against
        the slot's carried cache (O(length) model FLOPs)."""
        toks = np.asarray(r.prompt[offset:offset + length], np.int32)[None]
        tok_in = jnp.asarray(toks)
        if self.cfg.frontend == "audio":
            tok_in = jnp.repeat(tok_in[..., None], self.cfg.num_codebooks,
                                axis=-1)
        t_disp = time.perf_counter()
        next_tok, self.cache = self._prefill_step(
            self.params, self.cache, tok_in, jnp.int32(r.slot),
            jnp.int32(offset))
        self.stats.dispatch_time += time.perf_counter() - t_disp
        self.stats.model_dispatches += 1
        self.cache_len = self.cache_len.at[r.slot].set(offset + length)
        return _to_token(self._fetch(next_tok))

    def _prefill_to(self, r: Request, upto: int) -> int:
        """Recompute path (``prefill_mode="recompute"``; pre-DESIGN.md-§7
        behaviour, kept for A/B benchmarks): re-run ``forward_full`` over the
        whole prefix [0, upto) and scatter its states into the request's
        slot — O(p²/chunk) FLOPs per prompt, correct for every mixer
        family."""
        cfg = self.cfg
        toks = np.asarray(r.prompt[:upto], np.int32)[None]
        tok_in = jnp.asarray(toks)
        if cfg.frontend == "audio":
            tok_in = jnp.repeat(tok_in[..., None], cfg.num_codebooks, axis=-1)
        t_disp = time.perf_counter()
        with ops.attn_config(fast=self.attn_fast, stream=self.attn_stream):
            logits, _aux, states = model_lib.forward_full(
                cfg, self.params, tok_in, return_states=True)
        self.stats.dispatch_time += time.perf_counter() - t_disp
        self.stats.model_dispatches += 1
        self._scatter_states(r.slot, states)
        self.cache_len = self.cache_len.at[r.slot].set(upto)
        last = self._fetch(logits[0, -1])
        return _to_token(last.argmax(-1))

    def _scatter_states(self, slot: int, states) -> None:
        """Write per-layer mixer states into a slot (recompute path: the
        whole prefix at offset 0).  The incremental path's partial-prefix
        writes at arbitrary offsets happen inside the jitted
        ``_prefill_impl`` via ``attention._write_seq_at``."""
        for gi, (pattern, reps) in enumerate(self.cfg.layer_groups()):
            for i, spec in enumerate(pattern):
                st = states[gi][f"sub{i}"]
                dst = self.cache[gi][f"sub{i}"]
                if spec.mixer == ATTN:
                    if self.cfg.mla is not None:
                        ck, kr = st["kv"]
                        dst["c_kv"] = _write_slot_seq(dst["c_kv"], ck, slot)
                        dst["k_rope"] = _write_slot_seq(dst["k_rope"], kr,
                                                        slot)
                    else:
                        k, v = st["kv"]
                        dst["k"] = _write_slot_seq(dst["k"], k, slot)
                        dst["v"] = _write_slot_seq(dst["v"], v, slot)
                else:
                    for name, val in st.items():
                        dst[name] = _write_slot(dst[name], val, slot)

    def _finalize(self, r: Request) -> None:
        if r.slot >= 0:
            self.slot_free.append(r.slot)
            self.cache_len = self.cache_len.at[r.slot].set(0)
            self._pos[r.slot] = 0
            r.slot = -1
        # strip the post-EOS overshoot (async EOS, §5.3; under the §10
        # pipeline, later speculative tokens were already dropped at commit)
        if r.pending_eos and r.eos_id is not None and r.eos_id in r.output:
            r.output = r.output[: r.output.index(r.eos_id) + 1]
        # offload KV for multi-round reuse — size-only accounting: no
        # per-finished-request garbage blob is materialized (kvcache.py)
        self.kv.offload(r.rid,
                        nbytes=max(r.total_tokens * self.kv.bytes_per_token,
                                   1))


def _reset_slot(cache, init, slot):
    """Scatter a fresh one-slot cache into ``slot`` of the full cache."""
    return jax.tree.map(
        lambda c, z: jax.lax.dynamic_update_slice_in_dim(
            c, z.astype(c.dtype), slot, axis=1),
        cache, init)


def _write_slot_seq(cache: jax.Array, chunk: jax.Array, slot: int) -> jax.Array:
    """cache: (L, B, S, ...); chunk: (L, 1, s, ...) -> rows [0, s) of slot."""
    idx = (0, slot, 0) + (0,) * (cache.ndim - 3)
    return jax.lax.dynamic_update_slice(cache, chunk.astype(cache.dtype), idx)


def _write_slot(cache: jax.Array, state: jax.Array, slot: int) -> jax.Array:
    """cache: (L, B, ...); state: (L, 1, ...) -> write slot row."""
    idx = (0, slot) + (0,) * (cache.ndim - 2)
    return jax.lax.dynamic_update_slice(cache, state.astype(cache.dtype), idx)
