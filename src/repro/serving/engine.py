"""End-to-end serving engine: scheduler + paged KV + model execution.

Slot-based execution: model state lives in fixed-capacity slot caches
(static shapes — bounded compiled programs; the paper's discrete-batching
insight applied to the XLA compilation cache).  Prefill runs in chunks
(chunked prefill, §4.2) whose KV states are written into the request's slot.

**Packed step (default, DESIGN.md §8).**  One iteration = one jitted
program: the decode tokens (one per decoding slot) and *all* scheduled
prefill chunks are packed into a single ``(1, T)`` token stream with
per-token ``(slot, position)`` metadata and run through
``model.forward_packed`` — K/V (MLA latents) scattered at each segment's
offset, a segment-aware mask so segments never attend across each other,
recurrent state advanced per-slot with active-masking, greedy sampling
on-device.  Exactly one model dispatch and one device→host transfer per
iteration (``EngineStats.model_dispatches`` / ``host_syncs``), vs the
legacy path's ``1 + K`` dispatches with a blocking sync per chunk.  ``T``
is bucketed to the scheduler's discrete dense sizes, so
``BatchPlan.dense_batch`` is the *actual launched shape*; the iteration's
max KV extent is quantized to a KV-length bucket grid (DESIGN.md §9) and
passed statically into the step, so attention sweeps ``kv_bucket`` cache
rows per slot instead of ``max_len`` and the compile cache is bounded by
``(len(discrete_sizes) + 1) × len(kv_buckets)`` (the ``max_active`` floor
bucket for decode-only iterations, DESIGN.md §8).  Segment order inside
the stream follows the nano-batch interleave
(``core/nanobatch.packed_segment_order``), so the interleave governs the
real token layout of the launched program, not just the cost model.

**Legacy step (``step_mode="legacy"``, kept for A/B).**  Decode first over
all slots, then one ``model.forward_chunk`` dispatch per prefill chunk,
each gathering/scattering the chunk's slot sub-cache (DESIGN.md §7).  The
pre-§7 recompute path (O(p²/chunk) FLOPs) remains as
``prefill_mode="recompute"`` (implies the legacy step).

On TPU the per-iteration program is the NanoFlow pipeline (nano-batched,
overlapped ops); on this CPU container the same engine logic drives the ref
execution path, and the intra-device overlap is *modeled* by core/autosearch
(benchmarks report both).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ATTN, ModelConfig
from repro.kernels import ops
from repro.models import model as model_lib
from repro.serving import sampling
from repro.serving.kvcache import PagedKVManager
from repro.serving.request import Request
from repro.serving.scheduler import (BatchPlan, GlobalBatchScheduler,
                                     default_kv_buckets)


@dataclasses.dataclass
class EngineStats:
    iterations: int = 0
    prefill_tokens: int = 0          # prompt tokens admitted to the cache
    prefill_model_tokens: int = 0    # token-positions actually run through
    #                                  the model during prefill: == prefill
    #                                  _tokens on the incremental path (O(p)),
    #                                  strictly greater on the recompute path
    decode_tokens: int = 0
    wall_time: float = 0.0
    prefill_time: float = 0.0
    model_dispatches: int = 0        # hot-path model program launches
    host_syncs: int = 0              # blocking device→host result transfers
    packed_pad_tokens: int = 0       # bucketing padding launched (packed step)
    dense_batch_hist: dict[int, int] = dataclasses.field(default_factory=dict)
    # iterations per launched KV-length bucket (DESIGN.md §9; packed step)
    kv_bucket_hist: dict[int, int] = dataclasses.field(default_factory=dict)
    # Σ launch_tokens × kv_bucket — the packed-attention score-work actually
    # launched; compare against launch_tokens × max_len to see the bucketing
    # saving (attention FLOPs/bytes scale with this, not with max_len)
    packed_attn_kv_rows: int = 0

    @property
    def total_tokens(self) -> int:
        return self.prefill_tokens + self.decode_tokens

    @property
    def throughput(self) -> float:
        return self.total_tokens / self.wall_time if self.wall_time else 0.0

    @property
    def prefill_expansion(self) -> float:
        """Model-token-positions per prompt token (1.0 == linear prefill)."""
        return (self.prefill_model_tokens / self.prefill_tokens
                if self.prefill_tokens else 0.0)

    @property
    def dispatches_per_iter(self) -> float:
        return self.model_dispatches / self.iterations if self.iterations else 0.0

    @property
    def syncs_per_iter(self) -> float:
        return self.host_syncs / self.iterations if self.iterations else 0.0


class ServeEngine:
    def __init__(self, cfg: ModelConfig, params, *, max_slots: int = 8,
                 max_len: int = 512, page_size: int = 16,
                 total_pages: Optional[int] = None,
                 avg_decode_len: float = 64.0,
                 discrete_sizes: tuple[int, ...] = (256, 128, 64, 32, 16, 8),
                 prefill_mode: str = "incremental",
                 step_mode: Optional[str] = None,
                 nano: int = 2,
                 kv_buckets: Optional[tuple[int, ...]] = None,
                 kv_bucketing: bool = True,
                 attn_fast: Optional[bool] = None,
                 attn_stream: Optional[bool] = None,
                 seed: int = 0):
        assert prefill_mode in ("incremental", "recompute"), prefill_mode
        if step_mode is None:
            # the recompute prefill path has no packed equivalent — A/B runs
            # that ask for it get the legacy per-chunk step automatically
            step_mode = "packed" if prefill_mode == "incremental" else "legacy"
        assert step_mode in ("packed", "legacy"), step_mode
        assert not (step_mode == "packed" and prefill_mode == "recompute"), \
            "packed step runs incremental prefill only"
        self.cfg = cfg
        self.params = params
        self.max_slots = max_slots
        self.max_len = max_len
        self.prefill_mode = prefill_mode
        self.step_mode = step_mode
        self.nano = nano
        self.key = jax.random.PRNGKey(seed)
        # §Perf HC3 toggles, promoted from trace-time env reads (a retrace
        # footgun) to explicit arguments: resolved ONCE here (env is only
        # the fallback default) and pinned around every jitted trace body,
        # so a later env flip can never silently change a retrace
        self.attn_fast = ops.attn_fast_default() if attn_fast is None \
            else bool(attn_fast)
        self.attn_stream = ops.attn_stream_default() if attn_stream is None \
            else bool(attn_stream)
        # KV-length bucket grid (DESIGN.md §9): the packed step sweeps only
        # the iteration's bucket, not max_len; kv_bucketing=False pins the
        # single max_len bucket (the pre-§9 dense-vs-full-cache behaviour,
        # kept for A/B)
        if not kv_bucketing:
            self.kv_buckets = (max_len,)
        elif kv_buckets is None:
            self.kv_buckets = default_kv_buckets(max_len)
        else:
            grid = tuple(sorted({min(b, max_len) for b in kv_buckets}))
            self.kv_buckets = grid if grid[-1] == max_len \
                else grid + (max_len,)

        hd = cfg.resolved_head_dim
        n_attn = max(sum(1 for s in cfg.layer_specs() if s.mixer == ATTN), 1)
        kv_bytes = 2 * cfg.n_kv_heads * hd * 2 * n_attn
        pages = total_pages or (max_slots * max_len // page_size)
        self.kv = PagedKVManager(total_pages=pages, page_size=page_size,
                                 bytes_per_token=kv_bytes,
                                 avg_decode_len=avg_decode_len)
        self.scheduler = GlobalBatchScheduler(
            self.kv, discrete_sizes=discrete_sizes, max_active=max_slots,
            kv_buckets=self.kv_buckets)

        # slot caches: model cache trees with leading batch = max_slots
        self.cache = model_lib.init_cache(cfg, 1, max_slots, max_len)
        self.cache_len = jnp.zeros((max_slots,), jnp.int32)
        self.slot_free = list(range(max_slots))
        self.stats = EngineStats()
        # host mirror of each slot's context length (packed step builds its
        # per-token positions from this without any device read)
        self._pos = np.zeros((max_slots,), np.int64)

        # fresh one-slot cache, scattered into a slot on (re)assignment so a
        # reused slot never leaks the previous request's recurrent state
        self._slot_init = model_lib.init_cache(cfg, 1, 1, max_len)

        # one compiled program per (bucketed launch length T, kv bucket) —
        # the compile cache is bounded by |discrete dense sizes| × |kv
        # buckets| (kv_bucket is static: it sets the swept cache extent)
        self._packed_step = jax.jit(self._packed_impl, donate_argnums=(1,),
                                    static_argnums=(9,))
        self._decode_step = jax.jit(self._decode_impl, donate_argnums=(1,))
        # one compiled program per bucketed chunk length (scheduler-quantized)
        self._prefill_step = jax.jit(self._prefill_impl, donate_argnums=(1,))
        self._reset_step = jax.jit(_reset_slot, donate_argnums=(0,))

    # ---- jitted decode over all slots (static shapes) -----------------------
    def _decode_impl(self, params, cache, tokens, cache_len, active):
        with ops.attn_config(fast=self.attn_fast, stream=self.attn_stream):
            logits, new_cache = model_lib.forward_decode(
                self.cfg, params, tokens, cache, cache_len)
        next_tok = sampling.greedy(logits)
        # Mask the *recurrent* state update to decoding slots: a mid-prefill
        # slot's carried SSM/LSTM state must not be advanced by its garbage
        # decode token.  Attention K/V leaves keep the donated in-place
        # update: the garbage row lands at the slot's cache_len, which the
        # next prefill chunk overwrites before attending — selecting the big
        # seq-dim leaves would force a full cache copy per decode step.
        def sel(n, o):
            m = active.reshape((1, -1) + (1,) * (n.ndim - 2))
            return jnp.where(m, n, o)
        out = []
        for gi, (pattern, reps) in enumerate(self.cfg.layer_groups()):
            g = {}
            for i, spec in enumerate(pattern):
                n_sub = new_cache[gi][f"sub{i}"]
                g[f"sub{i}"] = n_sub if spec.mixer == ATTN else jax.tree.map(
                    sel, n_sub, cache[gi][f"sub{i}"])
            out.append(g)
        return next_tok, out

    # ---- jitted incremental prefill chunk (one slot, bucketed length) -------
    def _prefill_impl(self, params, cache, tokens, slot, offset):
        """tokens: (1, L[, K]) — the next L prompt positions of ``slot``
        after an ``offset``-token prefix.  Gathers the slot's sub-cache,
        runs ``forward_chunk``, scatters the updated sub-cache back
        (partial-prefix write at an arbitrary offset).  ``slot`` and
        ``offset`` are traced, so one compiled program serves every slot and
        prefix depth of a given chunk length."""
        sub = jax.tree.map(
            lambda a: jax.lax.dynamic_slice_in_dim(a, slot, 1, axis=1),
            cache)
        with ops.attn_config(fast=self.attn_fast, stream=self.attn_stream):
            logits, new_sub = model_lib.forward_chunk(
                self.cfg, params, tokens, sub, offset[None])
        new_cache = jax.tree.map(
            lambda c, s: jax.lax.dynamic_update_slice_in_dim(
                c, s.astype(c.dtype), slot, axis=1),
            cache, new_sub)
        return sampling.greedy(logits[:, -1]), new_cache

    # ---- jitted token-packed step (one dispatch per iteration) --------------
    def _packed_impl(self, params, cache, tokens, token_slot, token_pos,
                     token_wpos, token_active, cache_len, reset, kv_bucket):
        """The whole iteration as one program (DESIGN.md §8): reset reused
        slots' recurrent state, run the packed multi-segment forward, sample
        greedily on-device, and advance ``cache_len`` from the per-token
        metadata — so the only device→host transfer is the sampled tokens.
        ``kv_bucket`` is static (DESIGN.md §9): attention sweeps only that
        many cache rows per slot, so the program's attention cost tracks the
        iteration's actual context, not ``max_len``."""
        cache = self._reset_recurrent(cache, reset)
        with ops.attn_config(fast=self.attn_fast, stream=self.attn_stream):
            logits, new_cache = model_lib.forward_packed(
                self.cfg, params, tokens, cache, token_slot, token_pos,
                token_wpos, token_active, kv_bucket=kv_bucket)
        next_tok = sampling.greedy(logits[0])
        new_len = jnp.where(reset, 0, cache_len)
        new_len = new_len.at[token_slot].max(
            jnp.where(token_active, token_pos + 1, 0))
        return next_tok, new_cache, new_len

    def _reset_recurrent(self, cache, reset):
        """Select fresh recurrent state for slots in ``reset`` (reused slots
        must not leak the previous request's SSM/LSTM state).  Attention
        leaves need no reset — rows at or beyond the new request's written
        extent are never attended — and skipping them keeps the masked
        select off the big (slots, max_len, ...) tensors."""
        out = []
        for gi, (pattern, reps) in enumerate(self.cfg.layer_groups()):
            g = {}
            for i, spec in enumerate(pattern):
                sub = cache[gi][f"sub{i}"]
                if spec.mixer == ATTN:
                    g[f"sub{i}"] = sub
                else:
                    g[f"sub{i}"] = jax.tree.map(
                        lambda c, z: jnp.where(
                            reset.reshape((1, -1) + (1,) * (c.ndim - 2)),
                            z.astype(c.dtype), c),
                        sub, self._slot_init[gi][f"sub{i}"])
            out.append(g)
        return out

    # ---- public API ----------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.scheduler.submit(req)

    def run(self, max_iters: int = 10_000) -> list[Request]:
        done: list[Request] = []
        t0 = time.perf_counter()
        for _ in range(max_iters):
            plan = self.scheduler.plan()
            if plan is None:
                break
            done += self.step(plan)
        self.stats.wall_time += time.perf_counter() - t0
        return done

    def step(self, plan: BatchPlan) -> list[Request]:
        now = time.perf_counter()
        self.stats.iterations += 1
        self.stats.dense_batch_hist[plan.dense_batch] = \
            self.stats.dense_batch_hist.get(plan.dense_batch, 0) + 1
        if self.step_mode == "packed":
            sampled = self._step_packed(plan)
        else:
            sampled = self._step_legacy(plan)
        finished = self.scheduler.commit(plan, sampled, now)
        for r in finished:
            self._finalize(r)
        return finished

    # ---- packed iteration: one dispatch, one host sync ----------------------
    def _step_packed(self, plan: BatchPlan) -> dict[int, int]:
        packed = self.scheduler.pack(plan, nano=self.nano)
        reset = np.zeros((self.max_slots,), bool)
        for seg in packed.segments:
            r = seg.req
            if r.slot < 0:
                assert self.slot_free, "scheduler admitted beyond slot capacity"
                r.slot = self.slot_free.pop()
                reset[r.slot] = True
                self._pos[r.slot] = 0

        t_total = packed.launch_tokens
        tokens = np.zeros((t_total,), np.int32)
        slot = np.zeros((t_total,), np.int32)
        pos = np.zeros((t_total,), np.int32)
        active = np.zeros((t_total,), bool)
        sample_at: list[tuple[int, int]] = []      # (rid, stream index)
        t = 0
        for seg in packed.segments:
            r = seg.req
            if seg.is_decode:
                tokens[t] = r.output[-1] if r.output else r.prompt[-1]
                slot[t] = r.slot
                pos[t] = self._pos[r.slot]
                active[t] = True
                sample_at.append((r.rid, t))
                t += 1
            else:
                ln = seg.length
                tokens[t:t + ln] = r.prompt[seg.offset:seg.offset + ln]
                slot[t:t + ln] = r.slot
                pos[t:t + ln] = np.arange(seg.offset, seg.offset + ln)
                active[t:t + ln] = True
                if seg.offset + ln == r.prompt_len:
                    sample_at.append((r.rid, t + ln - 1))
                t += ln
        assert t == packed.tokens, (t, packed.tokens)
        # padding tokens write out of bounds -> the scatter drops them
        wpos = np.where(active, pos, self.max_len).astype(np.int32)

        # iteration's KV-length bucket (DESIGN.md §9): every attended row
        # must sit below it — the scheduler quantized the max extent up
        kv_bucket = packed.kv_bucket if packed.kv_bucket is not None \
            else self.max_len
        assert not active.any() or int(pos[active].max()) < kv_bucket, \
            (int(pos[active].max()), kv_bucket)
        self.stats.kv_bucket_hist[kv_bucket] = \
            self.stats.kv_bucket_hist.get(kv_bucket, 0) + 1
        self.stats.packed_attn_kv_rows += packed.launch_tokens * kv_bucket

        tok_in = jnp.asarray(tokens[None])
        if self.cfg.frontend == "audio":
            tok_in = jnp.repeat(tok_in[..., None], self.cfg.num_codebooks,
                                axis=-1)
        next_tok, self.cache, self.cache_len = self._packed_step(
            self.params, self.cache, tok_in, jnp.asarray(slot),
            jnp.asarray(pos), jnp.asarray(wpos), jnp.asarray(active),
            self.cache_len, jnp.asarray(reset), kv_bucket)
        self.stats.model_dispatches += 1
        nt = np.asarray(next_tok)          # the iteration's one D2H transfer
        self.stats.host_syncs += 1

        sampled: dict[int, int] = {}
        for rid, idx in sample_at:
            v = nt[idx]
            sampled[rid] = int(v) if np.ndim(v) == 0 else int(v.flat[0])
        n_decode = 0
        for seg in packed.segments:
            if seg.is_decode:
                self._pos[seg.req.slot] += 1
                n_decode += 1
            else:
                self._pos[seg.req.slot] = seg.offset + seg.length
        self.stats.decode_tokens += n_decode
        self.stats.prefill_tokens += packed.tokens - n_decode
        self.stats.prefill_model_tokens += packed.tokens - n_decode
        self.stats.packed_pad_tokens += packed.padding
        return sampled

    # ---- legacy iteration: decode dispatch + one dispatch per chunk ---------
    def _step_legacy(self, plan: BatchPlan) -> dict[int, int]:
        sampled: dict[int, int] = {}

        # ---- batched decode over all slots (static shape) --------------------
        decode_reqs = [r for r in plan.decode if r.slot >= 0]
        if decode_reqs:
            tokens = np.zeros((self.max_slots, 1), np.int32)
            active = np.zeros((self.max_slots,), bool)
            for r in decode_reqs:
                tokens[r.slot, 0] = r.output[-1] if r.output else r.prompt[-1]
                active[r.slot] = True
            tok_in = jnp.asarray(tokens)
            if self.cfg.frontend == "audio":
                tok_in = jnp.repeat(tok_in[..., None], self.cfg.num_codebooks,
                                    axis=-1)
            next_tok, self.cache = self._decode_step(
                self.params, self.cache, tok_in, self.cache_len,
                jnp.asarray(active))
            self.stats.model_dispatches += 1
            self.cache_len = self.cache_len + jnp.asarray(active, jnp.int32)
            nt = np.asarray(next_tok)
            self.stats.host_syncs += 1
            for r in decode_reqs:
                t = nt[r.slot]
                sampled[r.rid] = int(t) if np.ndim(t) == 0 else int(t.flat[0])
                self._pos[r.slot] += 1
            self.stats.decode_tokens += len(decode_reqs)

        # ---- chunked prefill -------------------------------------------------
        t_prefill = time.perf_counter()
        for chunk in plan.prefill:
            r = chunk.req
            if r.slot < 0:
                assert self.slot_free, "scheduler admitted beyond slot capacity"
                r.slot = self.slot_free.pop()
                self._pos[r.slot] = 0
                if self.prefill_mode == "incremental":
                    self.cache = self._reset_step(
                        self.cache, self._slot_init, jnp.int32(r.slot))
                    self.stats.model_dispatches += 1
            if self.prefill_mode == "incremental":
                last_tok = self._prefill_chunk(r, chunk.offset, chunk.length)
                self.stats.prefill_model_tokens += chunk.length
            else:
                last_tok = self._prefill_to(r, chunk.offset + chunk.length)
                self.stats.prefill_model_tokens += chunk.offset + chunk.length
            self.stats.prefill_tokens += chunk.length
            self._pos[r.slot] = chunk.offset + chunk.length
            if chunk.offset + chunk.length == r.prompt_len:
                sampled[r.rid] = last_tok
        self.stats.prefill_time += time.perf_counter() - t_prefill
        return sampled

    # ---- internals -----------------------------------------------------------
    def _prefill_chunk(self, r: Request, offset: int, length: int) -> int:
        """Incremental path: run exactly ``length`` new prompt tokens against
        the slot's carried cache (O(length) model FLOPs)."""
        toks = np.asarray(r.prompt[offset:offset + length], np.int32)[None]
        tok_in = jnp.asarray(toks)
        if self.cfg.frontend == "audio":
            tok_in = jnp.repeat(tok_in[..., None], self.cfg.num_codebooks,
                                axis=-1)
        next_tok, self.cache = self._prefill_step(
            self.params, self.cache, tok_in, jnp.int32(r.slot),
            jnp.int32(offset))
        self.stats.model_dispatches += 1
        self.cache_len = self.cache_len.at[r.slot].set(offset + length)
        t = np.asarray(next_tok)
        self.stats.host_syncs += 1
        return int(t) if t.ndim == 0 else int(t.flat[0])

    def _prefill_to(self, r: Request, upto: int) -> int:
        """Recompute path (``prefill_mode="recompute"``; pre-DESIGN.md-§7
        behaviour, kept for A/B benchmarks): re-run ``forward_full`` over the
        whole prefix [0, upto) and scatter its states into the request's
        slot — O(p²/chunk) FLOPs per prompt, correct for every mixer
        family."""
        cfg = self.cfg
        toks = np.asarray(r.prompt[:upto], np.int32)[None]
        tok_in = jnp.asarray(toks)
        if cfg.frontend == "audio":
            tok_in = jnp.repeat(tok_in[..., None], cfg.num_codebooks, axis=-1)
        with ops.attn_config(fast=self.attn_fast, stream=self.attn_stream):
            logits, _aux, states = model_lib.forward_full(
                cfg, self.params, tok_in, return_states=True)
        self.stats.model_dispatches += 1
        self._scatter_states(r.slot, states)
        self.cache_len = self.cache_len.at[r.slot].set(upto)
        last = np.asarray(logits[0, -1])
        self.stats.host_syncs += 1
        return int(last.argmax(-1)) if last.ndim == 1 else int(last.argmax(-1).flat[0])

    def _scatter_states(self, slot: int, states) -> None:
        """Write per-layer mixer states into a slot (recompute path: the
        whole prefix at offset 0).  The incremental path's partial-prefix
        writes at arbitrary offsets happen inside the jitted
        ``_prefill_impl`` via ``attention._write_seq_at``."""
        for gi, (pattern, reps) in enumerate(self.cfg.layer_groups()):
            for i, spec in enumerate(pattern):
                st = states[gi][f"sub{i}"]
                dst = self.cache[gi][f"sub{i}"]
                if spec.mixer == ATTN:
                    if self.cfg.mla is not None:
                        ck, kr = st["kv"]
                        dst["c_kv"] = _write_slot_seq(dst["c_kv"], ck, slot)
                        dst["k_rope"] = _write_slot_seq(dst["k_rope"], kr,
                                                        slot)
                    else:
                        k, v = st["kv"]
                        dst["k"] = _write_slot_seq(dst["k"], k, slot)
                        dst["v"] = _write_slot_seq(dst["v"], v, slot)
                else:
                    for name, val in st.items():
                        dst[name] = _write_slot(dst[name], val, slot)

    def _finalize(self, r: Request) -> None:
        if r.slot >= 0:
            self.slot_free.append(r.slot)
            self.cache_len = self.cache_len.at[r.slot].set(0)
            self._pos[r.slot] = 0
            r.slot = -1
        # strip the one post-EOS token (async EOS, §5.3)
        if r.pending_eos and r.eos_id is not None and r.eos_id in r.output:
            r.output = r.output[: r.output.index(r.eos_id) + 1]
        # offload KV for multi-round reuse (byte-accurate accounting)
        kv_elems = max(r.total_tokens * self.kv.bytes_per_token // 4, 1)
        self.kv.offload(r.rid, np.zeros((kv_elems,), np.float32))


def _reset_slot(cache, init, slot):
    """Scatter a fresh one-slot cache into ``slot`` of the full cache."""
    return jax.tree.map(
        lambda c, z: jax.lax.dynamic_update_slice_in_dim(
            c, z.astype(c.dtype), slot, axis=1),
        cache, init)


def _write_slot_seq(cache: jax.Array, chunk: jax.Array, slot: int) -> jax.Array:
    """cache: (L, B, S, ...); chunk: (L, 1, s, ...) -> rows [0, s) of slot."""
    idx = (0, slot, 0) + (0,) * (cache.ndim - 3)
    return jax.lax.dynamic_update_slice(cache, chunk.astype(cache.dtype), idx)


def _write_slot(cache: jax.Array, state: jax.Array, slot: int) -> jax.Array:
    """cache: (L, B, ...); state: (L, 1, ...) -> write slot row."""
    idx = (0, slot) + (0,) * (cache.ndim - 2)
    return jax.lax.dynamic_update_slice(cache, state.astype(cache.dtype), idx)
