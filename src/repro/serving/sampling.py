"""Token samplers (jit-compatible)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def greedy(logits: jax.Array) -> jax.Array:
    """logits: (B, V) or (B, K, V) -> (B,) / (B, K) int32."""
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def temperature(logits: jax.Array, key: jax.Array, temp: float = 1.0) -> jax.Array:
    if temp <= 0:
        return greedy(logits)
    return jax.random.categorical(key, logits.astype(jnp.float32) / temp,
                                  axis=-1).astype(jnp.int32)


def top_k(logits: jax.Array, key: jax.Array, k: int = 50,
          temp: float = 1.0) -> jax.Array:
    vals, idx = jax.lax.top_k(logits, k)
    choice = jax.random.categorical(key, vals.astype(jnp.float32) / max(temp, 1e-6),
                                    axis=-1)
    return jnp.take_along_axis(idx, choice[..., None], axis=-1)[..., 0].astype(jnp.int32)


# ---------------------------------------------------------------------------
# device-resident sampled-token feedback (async pipeline, DESIGN.md §10)
# ---------------------------------------------------------------------------
def substitute_last(tokens: jax.Array, last_token: jax.Array,
                    token_slot: jax.Array, from_last: jax.Array) -> jax.Array:
    """Replace the packed stream's decode placeholders with the on-device
    ``last_token`` buffer, so the host never needs the previous iteration's
    sampled values to build an input stream.

    tokens: (1, T[, K]) host-built stream (decode positions hold
    placeholders); last_token: (n_slots,) per-slot feedback buffer;
    token_slot: (T,); from_last: (T,) bool — True at decode positions.
    Multi-codebook streams broadcast the feedback token across codebooks,
    matching the host path's ``repeat`` of the codebook-0 sample."""
    fed = last_token[token_slot]                         # (T,)
    fed = fed.reshape(fed.shape + (1,) * (tokens.ndim - 2))
    mask = from_last.reshape(from_last.shape + (1,) * (tokens.ndim - 2))
    return jnp.where(mask, fed.astype(tokens.dtype), tokens[0])[None]


def scatter_last(last_token: jax.Array, sample_slot: jax.Array,
                 sampled: jax.Array) -> jax.Array:
    """Scatter this iteration's samples into the feedback buffer at the
    stream's sample points (each decode token and each prefill-final
    token).  ``sample_slot`` is the token's slot at sample points and
    ``n_slots`` (out of bounds → dropped) elsewhere.  Multi-codebook
    samples keep codebook 0, matching the host feedback path."""
    if sampled.ndim == 2:
        sampled = sampled[:, 0]
    return last_token.at[sample_slot].set(
        sampled.astype(last_token.dtype), mode="drop")
