"""Token samplers (jit-compatible)."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp


def greedy(logits: jax.Array) -> jax.Array:
    """logits: (B, V) or (B, K, V) -> (B,) / (B, K) int32."""
    return jnp.argmax(logits, axis=-1).astype(jnp.int32)


def temperature(logits: jax.Array, key: jax.Array, temp: float = 1.0) -> jax.Array:
    if temp <= 0:
        return greedy(logits)
    return jax.random.categorical(key, logits.astype(jnp.float32) / temp,
                                  axis=-1).astype(jnp.int32)


def top_k(logits: jax.Array, key: jax.Array, k: int = 50,
          temp: float = 1.0) -> jax.Array:
    vals, idx = jax.lax.top_k(logits, k)
    choice = jax.random.categorical(key, vals.astype(jnp.float32) / max(temp, 1e-6),
                                    axis=-1)
    return jnp.take_along_axis(idx, choice[..., None], axis=-1)[..., 0].astype(jnp.int32)


# ---------------------------------------------------------------------------
# packed-step sampling (EngineConfig.temperature / top_k; DESIGN.md §13)
# ---------------------------------------------------------------------------
def packed_keys(key: jax.Array, token_rid: jax.Array, token_pos: jax.Array,
                stride: int) -> jax.Array:
    """Per-token PRNG keys for the packed stream: fold each token's
    ``(request id, position)`` into the engine key, so every sample point
    draws a stream that depends on nothing else — not the launch index,
    not the physical slot, not previously sampled values.  Consequences:
    stochastic serving is exactly reproducible, identical at any
    ``async_depth`` (slot reuse timing shifts under the §10 pipeline;
    request ids don't), and a §13 verify re-draw of a rejected position
    repeats the *same* sample — which makes point-mass-drafter speculation
    token-exact against the plain engine even under temperature/top-k
    sampling (common random numbers).  ``stride`` must exceed the max
    position (``max_len``) so (rid, pos) pairs never collide."""
    return jax.vmap(lambda r, p: jax.random.fold_in(key, r * stride + p))(
        token_rid, token_pos.astype(jnp.int32))


def sample_tokens(logits: jax.Array, keys: Optional[jax.Array],
                  temp: float = 0.0, topk: Optional[int] = None) -> jax.Array:
    """Sample the packed stream's next tokens: greedy when ``temp <= 0``
    (the default and the spec-decode exactness baseline), else
    temperature / top-k categorical with one ``packed_keys`` key per row.

    logits: (T, V) or (T, K, V) -> (T,) / (T, K) int32.  The Gumbel trick
    over per-row keys keeps every row (and every codebook) independent
    while staying a single fused program."""
    if temp <= 0:
        return greedy(logits)
    assert keys is not None, "stochastic sampling needs packed_keys"
    lg = logits.astype(jnp.float32) / max(temp, 1e-6)
    if topk is not None:
        vals, idx = jax.lax.top_k(lg, topk)
        noise = jax.vmap(lambda k: jax.random.gumbel(k, vals.shape[1:]))(keys)
        choice = jnp.argmax(vals + noise, axis=-1)
        return jnp.take_along_axis(idx, choice[..., None],
                                   axis=-1)[..., 0].astype(jnp.int32)
    noise = jax.vmap(lambda k: jax.random.gumbel(k, lg.shape[1:]))(keys)
    return jnp.argmax(lg + noise, axis=-1).astype(jnp.int32)


# ---------------------------------------------------------------------------
# device-resident sampled-token feedback (async pipeline, DESIGN.md §10;
# generalized to the per-slot token ring of DESIGN.md §13)
# ---------------------------------------------------------------------------
def substitute_last(tokens: jax.Array, last_token: jax.Array,
                    token_slot: jax.Array, from_last: jax.Array,
                    accept_len: Optional[jax.Array] = None) -> jax.Array:
    """Replace the packed stream's decode placeholders with the on-device
    ``last_token`` buffer, so the host never needs the previous iteration's
    sampled values to build an input stream.

    tokens: (1, T[, K]) host-built stream (decode positions hold
    placeholders); last_token: (n_slots,) per-slot feedback buffer, or its
    speculative-decoding generalization (n_slots, W) — the per-slot token
    ring whose row holds the last verify segment's W samples, of which the
    first ``accept_len[slot]`` were accepted (DESIGN.md §13).  The fed
    token is the newest *accepted* sample, ``ring[slot, accept_len-1]``;
    with a (n_slots,) buffer (or ``accept_len=None``) this is exactly the
    §10 behaviour.  token_slot: (T,); from_last: (T,) bool — True at
    decode positions.  Multi-codebook streams broadcast the feedback token
    across codebooks, matching the host path's ``repeat`` of the
    codebook-0 sample."""
    if last_token.ndim == 1:
        fed = last_token[token_slot]                     # (T,)
    else:
        if accept_len is None:
            col = jnp.zeros(token_slot.shape, jnp.int32)
        else:
            col = jnp.clip(accept_len[token_slot] - 1, 0,
                           last_token.shape[1] - 1)
        fed = last_token[token_slot, col]                # (T,)
    fed = fed.reshape(fed.shape + (1,) * (tokens.ndim - 2))
    mask = from_last.reshape(from_last.shape + (1,) * (tokens.ndim - 2))
    return jnp.where(mask, fed.astype(tokens.dtype), tokens[0])[None]


def scatter_last(last_token: jax.Array, sample_slot: jax.Array,
                 sampled: jax.Array) -> jax.Array:
    """Scatter this iteration's samples into the feedback buffer at the
    stream's sample points (each decode token and each prefill-final
    token).  ``sample_slot`` is the token's slot at sample points and
    ``n_slots`` (out of bounds → dropped) elsewhere.  A ring-shaped
    buffer (n_slots, W) takes single-sample points in column 0 (a
    one-sample "segment"; verify segments write whole rows in the engine's
    acceptance path instead).  Multi-codebook samples keep codebook 0,
    matching the host feedback path."""
    if sampled.ndim == 2:
        sampled = sampled[:, 0]
    if last_token.ndim == 2:
        return last_token.at[sample_slot, 0].set(
            sampled.astype(last_token.dtype), mode="drop")
    return last_token.at[sample_slot].set(
        sampled.astype(last_token.dtype), mode="drop")
