"""Multi-replica (pod-level) request router.

At 1000+ nodes the serving fleet is many independent NanoFlow engines (the
``pod`` mesh axis / separate pods).  This router implements the paper §4.1
deployment box around them (DESIGN.md §14):

  * **load-aware dispatch**: requests go to the replica with the lowest
    estimated backlog — queued prompt tokens *plus* launched-but-uncommitted
    tokens (§10 async depth keeps up to ``depth`` iterations of samples in
    flight; counting only committed work would make a saturated pipelined
    replica look idle) — scaled by straggler speed shares and penalized by
    KV-pool pressure,
  * **session affinity**: a multi-turn session is pinned to the replica
    holding its prefix-cached KV (§12) until that replica dies or its KV
    pool saturates,
  * **failure handling**: a replica marked dead is never selected again;
    ``mark_failed`` evacuates its *entire* backlog — queued AND in-flight
    requests, checkpointed so committed tokens replay as a forced prefix —
    and re-enters each survivor into the dispatch path exactly once
    (falling back to a pending queue when no live replica exists, so work
    is parked, never dropped).

The router is engine-agnostic: it only needs ``submit`` + queue metrics, so
the same logic drives real pods on a cluster.  Engine-backed handles read
their metrics straight off the engine's scheduler/KV state.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

from repro.distributed.elastic import StragglerMitigator
from repro.serving.request import Request, State

_DONE = (State.FINISHED, State.DISCARDED, State.REJECTED)


class NoLiveReplicas(RuntimeError):
    """Raised by ``submit`` when every replica is dead (callers with a
    shed/park policy — the pool — catch this; it never hangs)."""


@dataclasses.dataclass
class ReplicaStats:
    queued_tokens: int = 0       # prompt tokens not yet launched
    inflight_tokens: int = 0     # launched-but-uncommitted (§10 pipeline)
    active_requests: int = 0
    kv_used_frac: float = 0.0    # device KV pool pressure [0, 1]
    ema_step_s: float = 0.0
    alive: bool = True

    @property
    def backlog_tokens(self) -> int:
        """Work ahead of a newly routed request: queued + in-flight."""
        return self.queued_tokens + self.inflight_tokens


class ReplicaHandle:
    """Wraps one engine (or a remote pod endpoint).

    Chaos/runtime state (``stall_until``, ``degrade``, ``suspect``) is
    driven by the pool's fault harness; the router only reads ``alive`` and
    ``suspect`` (a stalled-but-alive replica should not receive retries)."""

    def __init__(self, rid: int, engine=None):
        self.rid = rid
        self.engine = engine
        self.alive = True
        self.suspect = False      # stalled/degraded: deprioritized, not dead
        self.stall_until = 0      # pool tick until which steps are skipped
        self.degrade = 1          # step only every `degrade` pool ticks
        self.assigned: dict[int, Request] = {}

    def _prune(self) -> None:
        self.assigned = {rid: r for rid, r in self.assigned.items()
                         if r.state not in _DONE}

    def stats(self) -> ReplicaStats:
        if not self.alive:
            return ReplicaStats(alive=False)
        if self.engine is None:
            self._prune()
            reqs = list(self.assigned.values())
            return ReplicaStats(
                queued_tokens=sum(r.prefill_unlaunched for r in reqs),
                inflight_tokens=sum(r.inflight for r in reqs),
                active_requests=len(reqs))
        sched = self.engine.scheduler
        queued = sum(r.prefill_unlaunched for r in sched.waiting) + \
            sum(r.prefill_unlaunched for r in sched.active)
        # launched-but-uncommitted: in-flight sampled tokens plus prefill
        # chunks past the committed boundary — the §10 pipeline's hidden
        # occupancy (committed-only metrics made a depth-k replica whose
        # every token was in flight look idle)
        inflight = sum(r.inflight + (r.prefill_launched - r.prefill_done)
                       for r in sched.active)
        kvs = self.engine.kv.stats
        return ReplicaStats(
            queued_tokens=queued, inflight_tokens=inflight,
            active_requests=sched.n_active + sched.n_waiting,
            kv_used_frac=kvs.device_pages_used
            / max(kvs.device_pages_total, 1))

    def submit(self, req: Request) -> None:
        req.replica = self.rid
        self.assigned[req.rid] = req
        if self.engine is not None:
            self.engine.submit(req)

    def evacuate(self, *, drain: bool) \
            -> tuple[list[Request], list[Request]]:
        """Checkpoint-and-collect the whole backlog: ``(finished, moved)``.
        Engine-backed handles delegate to ``ServeEngine.evacuate`` (which
        releases slots/KV); engine-less handles checkpoint their assigned
        list directly."""
        if self.engine is not None:
            finished, moved = self.engine.evacuate(drain=drain)
        else:
            finished, moved = [], []
            for r in self.assigned.values():
                if r.state in _DONE:
                    continue
                r.checkpoint_redispatch()
                (finished if r.state == State.FINISHED else moved).append(r)
        self.assigned = {}
        return finished, moved


class Router:
    def __init__(self, replicas: list[ReplicaHandle],
                 straggler_alpha: float = 0.2, affinity: bool = True,
                 decode_cost: int = 64, kv_spill: float = 0.9):
        assert replicas
        self.replicas = list(replicas)
        self.straggler_alpha = straggler_alpha
        self.straggler = StragglerMitigator(len(replicas),
                                            alpha=straggler_alpha)
        self.affinity = affinity
        self.decode_cost = decode_cost
        # KV pressure above this fraction breaks session affinity and
        # multiplies the replica's dispatch cost (pressure-aware routing)
        self.kv_spill = kv_spill
        self._session: dict[int, int] = {}     # session key -> replica idx
        # orphans with no live replica to take them: parked, never dropped;
        # drained by flush_pending() when capacity returns (join/recovery)
        self.pending: deque[Request] = deque()
        self.dispatched = 0
        self.redispatched = 0

    # ---- dispatch ----------------------------------------------------------
    def submit(self, req: Request) -> int:
        """Route to the cheapest live replica (see ``_select``).  Returns
        the replica index; raises ``NoLiveReplicas`` when none is alive."""
        best = self._select(req)
        if best is None:
            raise NoLiveReplicas("no live replicas")
        self._place(req, best)
        return best

    def _select(self, req: Request) -> Optional[int]:
        # session affinity: a pinned replica keeps the session's cached
        # prefix (§12) — stay there unless it died or its KV pool is full
        if self.affinity and req.session is not None:
            rid = self._session.get(req.session)
            if rid is not None:
                rep = self.replicas[rid]
                if rep.alive and not rep.suspect \
                        and rep.stats().kv_used_frac < self.kv_spill:
                    return rid
        # two passes: suspect (stalled/degraded) replicas only get work
        # when no healthy replica exists
        for include_suspect in (False, True):
            shares = self.straggler.shares()
            best, best_cost = None, None
            for i, rep in enumerate(self.replicas):
                if not rep.alive or (rep.suspect and not include_suspect):
                    continue
                st = rep.stats()
                backlog = (st.backlog_tokens
                           + self.decode_cost * st.active_requests
                           + req.prompt_len)
                cost = backlog / max(shares[i], 1e-9)
                if st.kv_used_frac >= self.kv_spill:
                    cost *= 1.0 + 4.0 * (st.kv_used_frac - self.kv_spill)
                if best_cost is None or cost < best_cost:
                    best, best_cost = i, cost
            if best is not None:
                return best
        return None

    def _place(self, req: Request, rid: int) -> None:
        if self.affinity and req.session is not None:
            self._session[req.session] = rid
        self.replicas[rid].submit(req)
        self.dispatched += 1

    def flush_pending(self) -> list[Request]:
        """Re-enter parked orphans once a live replica exists (called on
        join and every pool tick).  Stops at the first un-routable request
        so ordering is preserved."""
        placed = []
        while self.pending:
            req = self.pending[0]
            best = self._select(req)
            if best is None:
                break
            self.pending.popleft()
            self._place(req, best)
            placed.append(req)
        return placed

    # ---- membership --------------------------------------------------------
    def add_replica(self, handle: ReplicaHandle) -> int:
        """Replica join: the straggler state is rebuilt for the new fleet
        size (EMA restarts — a freshly joined replica has no history) and
        parked work is flushed onto the added capacity."""
        self.replicas.append(handle)
        self.straggler = StragglerMitigator(len(self.replicas),
                                            alpha=self.straggler_alpha)
        self.flush_pending()
        return len(self.replicas) - 1

    # ---- health ------------------------------------------------------------
    def observe_step_times(self, times: list[float]) -> None:
        self.straggler.observe(times)

    def retire_replica(self, rid: int, *, drain: bool) \
            -> tuple[list[Request], list[Request]]:
        """Shared failure/graceful-leave path: mark the replica dead,
        evacuate its entire backlog (queued and in-flight), and re-enter
        every still-unfinished request into the dispatch path **exactly
        once** (the evacuation clears the replica's queues, so a second
        call finds nothing).  Returns ``(finished, moved)`` — requests that
        finished at the checkpoint (committed EOS / spent budget, plus
        drained completions on the graceful path) and requests moved to
        other replicas or parked in ``pending``."""
        rep = self.replicas[rid]
        if not rep.alive:
            return [], []
        rep.alive = False
        finished, orphans = rep.evacuate(drain=drain)
        self._session = {k: v for k, v in self._session.items() if v != rid}
        moved = []
        for r in orphans:
            self.redispatched += 1
            r.retries += 1
            best = self._select(r)
            if best is None:
                self.pending.append(r)
            else:
                self._place(r, best)
            moved.append(r)
        return finished, moved

    def mark_failed(self, rid: int) -> list[Request]:
        """Kill a replica; re-dispatch its queued *and* in-flight requests
        (committed tokens checkpointed as a forced replay prefix).  Returns
        the moved requests; checkpoint-finished ones are retrievable from
        the pool path (``retire_replica``)."""
        _, moved = self.retire_replica(rid, drain=False)
        return moved

    @property
    def n_alive(self) -> int:
        return sum(1 for r in self.replicas if r.alive)
