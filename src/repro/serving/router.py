"""Multi-replica (pod-level) request router.

At 1000+ nodes the serving fleet is many independent NanoFlow engines (the
``pod`` mesh axis / separate pods).  This router implements the paper §4.1
deployment box around them:

  * **load-aware dispatch**: requests go to the replica with the lowest
    estimated backlog (queued prefill tokens + active decode slots),
  * **straggler routing**: replicas report EMA step times; slow replicas
    receive proportionally less work (distributed/elastic.StragglerMitigator
    policy applied to request streams),
  * **failure handling**: a dead replica's queued (not yet prefilled)
    requests are re-dispatched; in-flight requests are retried once.

The router is engine-agnostic: it only needs ``submit`` + queue metrics, so
the same logic drives real pods on a cluster.
"""
from __future__ import annotations

import dataclasses
from repro.distributed.elastic import StragglerMitigator
from repro.serving.request import Request, State


@dataclasses.dataclass
class ReplicaStats:
    queued_tokens: int = 0
    active_requests: int = 0
    ema_step_s: float = 0.0
    alive: bool = True


class ReplicaHandle:
    """Wraps one engine (or a remote pod endpoint)."""

    def __init__(self, rid: int, engine=None):
        self.rid = rid
        self.engine = engine
        self.alive = True
        self.assigned: list[Request] = []

    def stats(self) -> ReplicaStats:
        if not self.alive:
            return ReplicaStats(alive=False)
        if self.engine is None:
            return ReplicaStats(
                queued_tokens=sum(r.prefill_remaining for r in self.assigned),
                active_requests=len(self.assigned))
        sched = self.engine.scheduler
        queued = sum(r.prefill_remaining for r in sched.waiting) + \
            sum(r.prefill_remaining for r in sched.active)
        return ReplicaStats(queued_tokens=queued,
                            active_requests=sched.n_active + sched.n_waiting)

    def submit(self, req: Request) -> None:
        self.assigned.append(req)
        if self.engine is not None:
            self.engine.submit(req)


class Router:
    def __init__(self, replicas: list[ReplicaHandle],
                 straggler_alpha: float = 0.2):
        assert replicas
        self.replicas = replicas
        self.straggler = StragglerMitigator(len(replicas),
                                            alpha=straggler_alpha)
        self.dispatched = 0
        self.redispatched = 0

    # ---- dispatch ----------------------------------------------------------
    def submit(self, req: Request) -> int:
        """Route to argmin of (backlog / speed-share).  Returns replica id."""
        shares = self.straggler.shares()
        best, best_cost = None, None
        for i, rep in enumerate(self.replicas):
            if not rep.alive:
                continue
            st = rep.stats()
            backlog = st.queued_tokens + 64 * st.active_requests \
                + req.prompt_len
            cost = backlog / max(shares[i], 1e-9)
            if best_cost is None or cost < best_cost:
                best, best_cost = i, cost
        if best is None:
            raise RuntimeError("no live replicas")
        self.replicas[best].submit(req)
        self.dispatched += 1
        return best

    # ---- health ------------------------------------------------------------
    def observe_step_times(self, times: list[float]) -> None:
        self.straggler.observe(times)

    def mark_failed(self, rid: int) -> list[Request]:
        """Kill a replica; re-dispatch its un-prefilled requests."""
        rep = self.replicas[rid]
        rep.alive = False
        orphans = [r for r in rep.assigned
                   if r.state in (State.WAITING, State.PREFILL)]
        rep.assigned = []
        moved = []
        for r in orphans:
            r.state = State.WAITING
            r.prefill_done = 0
            r.prefill_launched = 0
            r.inflight = 0
            r.output = []
            r.slot = -1
            self.submit(r)
            self.redispatched += 1
            moved.append(r)
        return moved

    @property
    def n_alive(self) -> int:
        return sum(1 for r in self.replicas if r.alive)
