"""Draft-token proposers for speculative decoding (DESIGN.md §13).

The engine's verify segments are drafter-agnostic: anything that can
propose up to ``k`` next tokens for a request's committed history can
drive them.  ``Drafter`` is the protocol; ``NgramDrafter`` is the
reference implementation — prompt-lookup / self-history n-gram matching
(no model, no device work), the cheap end of the speculative-decoding
design space.  A tiny self-drafting model slots in later by implementing
``propose`` (its own forward pass happens *outside* the packed step, so
the 1-dispatch-per-iteration invariant is about the target model only).

Drafts are *proposals*, never trusted: the packed step verifies every
position against the target model and accepts only the longest matching
prefix (rejection sampling degenerates to exact prefix-match acceptance
for a point-mass drafter — see DESIGN.md §13), so a bad drafter costs
compute, not correctness.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Protocol, runtime_checkable

from repro.serving.request import Request


@runtime_checkable
class Drafter(Protocol):
    def propose(self, req: Request, k: int) -> list[int]:
        """Up to ``k`` draft tokens continuing ``req``'s committed history
        (prompt + committed output).  May return fewer than ``k`` (the
        scheduler pads the verify segment); must be cheap — this runs on
        the host scheduling path of every iteration."""
        ...


@dataclass
class NgramDrafter:
    """Prompt-lookup / self-history n-gram drafter: find the most recent
    earlier occurrence of the history's trailing n-gram (longest n first,
    down to a single token) and propose the tokens that followed it.

    The single-token floor (``min_n=1``) matters on decode-heavy
    workloads: greedy decoding frequently enters short cycles, and a
    length-1 suffix match catches period-1 fixed points that longer
    n-grams would miss early in the cycle."""
    max_n: int = 3
    min_n: int = 1

    def propose(self, req: Request, k: int) -> list[int]:
        hist = req.prompt + req.output
        if k <= 0 or len(hist) < 2:
            return []
        top = min(self.max_n, len(hist) - 1)
        for n in range(top, self.min_n - 1, -1):
            tail = hist[-n:]
            # most recent earlier occurrence whose continuation is nonempty
            for start in range(len(hist) - n - 1, -1, -1):
                if hist[start:start + n] == tail:
                    cont = hist[start + n:start + n + k]
                    if cont:
                        return cont
                    break
        return []


_REGISTRY = {"ngram": NgramDrafter}


def drafter_names() -> tuple[str, ...]:
    return tuple(sorted(_REGISTRY))


def make_drafter(name: str, **kwargs) -> Drafter:
    if name not in _REGISTRY:
        raise ValueError(f"unknown drafter {name!r}; "
                         f"available: {drafter_names()}")
    return _REGISTRY[name](**kwargs)
