"""Request lifecycle for the serving engine."""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional


class State(enum.Enum):
    WAITING = "waiting"        # admitted to queue, no KV yet
    PREFILL = "prefill"        # chunked prefill in progress
    DECODE = "decode"          # generating
    FINISHED = "finished"
    DISCARDED = "discarded"    # OOM victim (paper §4.4: rare reclaim)
    SWAPPED = "swapped"        # KV offloaded to host (multi-round)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new_tokens: int
    arrival: float = 0.0
    eos_id: Optional[int] = None

    state: State = State.WAITING
    prefill_done: int = 0              # tokens prefilled so far (chunked)
    output: list[int] = dataclasses.field(default_factory=list)
    slot: int = -1                     # engine cache slot while active
    first_token_at: Optional[float] = None
    finished_at: Optional[float] = None
    # async EOS (paper §5.3): EOS seen at iter i is acted on at iter i+1
    pending_eos: bool = False
    # ---- speculative launch state (async pipeline, DESIGN.md §10) ----------
    # prompt tokens *launched* into the model; runs ahead of ``prefill_done``
    # (which tracks committed results) by the in-flight iterations
    prefill_launched: int = 0
    # sampled tokens launched but not yet committed: in-flight decode tokens
    # plus the prefill-final token.  Planning bounds generation with
    # ``len(output) + inflight`` so speculation never launches past
    # ``max_new_tokens``, and caps post-EOS overshoot at one in-flight token.
    # Under §13 spec decoding this counts *worst-case* tokens — each verify
    # segment adds its full width ``spec_k + 1`` at launch and commit
    # reconciles down to the actual accept_len, so the bound stays safe
    inflight: int = 0

    @property
    def prompt_len(self) -> int:
        return len(self.prompt)

    @property
    def context_len(self) -> int:
        return self.prefill_done + len(self.output)

    @property
    def prefill_remaining(self) -> int:
        return self.prompt_len - self.prefill_done

    @property
    def prefill_unlaunched(self) -> int:
        """Prompt tokens not yet launched — what the *next* plan can chunk
        (``prefill_remaining`` counts committed progress and lags this by
        the in-flight iterations when the engine pipelines, §10)."""
        return self.prompt_len - self.prefill_launched

    @property
    def total_tokens(self) -> int:
        return self.prompt_len + len(self.output)

    def predicted_final_len(self, avg_decode: float) -> int:
        """Peak-memory estimator input (§4.4): assume avg decode length."""
        want = max(int(avg_decode), 1)
        return self.prompt_len + min(self.max_new_tokens, max(want, 1))
