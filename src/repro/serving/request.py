"""Request lifecycle for the serving engine."""
from __future__ import annotations

import dataclasses
import enum
from typing import Optional


class State(enum.Enum):
    WAITING = "waiting"        # admitted to queue, no KV yet
    PREFILL = "prefill"        # chunked prefill in progress
    DECODE = "decode"          # generating
    FINISHED = "finished"
    DISCARDED = "discarded"    # OOM victim (paper §4.4: rare reclaim)
    SWAPPED = "swapped"        # KV offloaded to host (multi-round)
    REJECTED = "rejected"      # shed by SLO admission control (DESIGN.md §14)


@dataclasses.dataclass
class Request:
    rid: int
    prompt: list[int]
    max_new_tokens: int
    arrival: float = 0.0
    eos_id: Optional[int] = None
    # multi-turn session key (DESIGN.md §14): the router pins a session to
    # one replica so follow-up turns land on their prefix-cached KV
    session: Optional[int] = None

    state: State = State.WAITING
    prefill_done: int = 0              # tokens prefilled so far (chunked)
    output: list[int] = dataclasses.field(default_factory=list)
    slot: int = -1                     # engine cache slot while active
    first_token_at: Optional[float] = None
    finished_at: Optional[float] = None
    # async EOS (paper §5.3): EOS seen at iter i is acted on at iter i+1
    pending_eos: bool = False
    # ---- speculative launch state (async pipeline, DESIGN.md §10) ----------
    # prompt tokens *launched* into the model; runs ahead of ``prefill_done``
    # (which tracks committed results) by the in-flight iterations
    prefill_launched: int = 0
    # sampled tokens launched but not yet committed: in-flight decode tokens
    # plus the prefill-final token.  Planning bounds generation with
    # ``len(output) + inflight`` so speculation never launches past
    # ``max_new_tokens``, and caps post-EOS overshoot at one in-flight token.
    # Under §13 spec decoding this counts *worst-case* tokens — each verify
    # segment adds its full width ``spec_k + 1`` at launch and commit
    # reconciles down to the actual accept_len, so the bound stays safe
    inflight: int = 0
    # ---- fault-tolerant re-dispatch (DESIGN.md §14) ------------------------
    # prompt length as the user submitted it; set on the first checkpoint
    # (``checkpoint_redispatch``) when committed output is folded into the
    # prompt as a forced replay prefix.  None == never re-dispatched.
    orig_prompt_len: Optional[int] = None
    # pool-level retry count (timeout / failure re-dispatch) and the shed
    # reason when admission control rejects the request outright
    retries: int = 0
    reject_reason: Optional[str] = None
    # replica the request last ran on (pool bookkeeping / session affinity)
    replica: Optional[int] = None

    @property
    def prompt_len(self) -> int:
        return len(self.prompt)

    @property
    def context_len(self) -> int:
        return self.prefill_done + len(self.output)

    @property
    def prefill_remaining(self) -> int:
        return self.prompt_len - self.prefill_done

    @property
    def prefill_unlaunched(self) -> int:
        """Prompt tokens not yet launched — what the *next* plan can chunk
        (``prefill_remaining`` counts committed progress and lags this by
        the in-flight iterations when the engine pipelines, §10)."""
        return self.prompt_len - self.prefill_launched

    @property
    def total_tokens(self) -> int:
        return self.prompt_len + len(self.output)

    def predicted_final_len(self, avg_decode: float) -> int:
        """Peak-memory estimator input (§4.4): assume avg decode length."""
        want = max(int(avg_decode), 1)
        return self.prompt_len + min(self.max_new_tokens, max(want, 1))

    @property
    def generated(self) -> list[int]:
        """All tokens this request generated, including any that were
        committed before a failure and replayed as a forced prefix
        (DESIGN.md §14).  For a never-re-dispatched request this is exactly
        ``output``; the chaos-exactness tests compare this stream."""
        if self.orig_prompt_len is None:
            return list(self.output)
        return list(self.prompt[self.orig_prompt_len:]) + list(self.output)

    def checkpoint_redispatch(self) -> int:
        """Reset to a re-dispatchable checkpoint: fold every *committed*
        output token into the prompt as a forced replay prefix and clear all
        engine-local state (slot, launch counters, in-flight samples — those
        died with the replica).  Replaying the committed tokens as prompt
        makes the resumed generation token-exact: under greedy decoding the
        next sample depends only on the prefix, and the stochastic sampler's
        keys fold (rid, position) only (§13), both of which the replay
        preserves.  Returns the number of tokens folded (the re-prefill cost
        the pool accounts as ``redispatched_tokens``).

        A request whose committed output already contains EOS — or whose
        token budget is exhausted — has nothing left to generate: it is
        finished here (output stripped to EOS exactly like the engine's
        finalize path) and the caller must not re-dispatch it."""
        if self.orig_prompt_len is None:
            self.orig_prompt_len = len(self.prompt)
        out = list(self.output)
        if self.eos_id is not None and self.eos_id in out:
            out = out[: out.index(self.eos_id) + 1]
            self.prompt = list(self.prompt) + out
            self.output = []
            self.state = State.FINISHED
            self.pending_eos = False
            self.inflight = 0
            return 0
        folded = len(out)
        self.prompt = list(self.prompt) + out
        self.max_new_tokens -= folded
        self.output = []
        self.prefill_done = 0
        self.prefill_launched = 0
        self.inflight = 0
        self.slot = -1
        self.pending_eos = False
        if self.max_new_tokens <= 0:
            self.state = State.FINISHED
            return 0
        self.state = State.WAITING
        return folded
