"""Engine configuration (`EngineConfig`): one frozen dataclass instead of
``ServeEngine.__init__``'s ~17 loose keyword arguments.

Design rules:

  * **Fields store what the caller said** — ``step_mode=None`` stays
    ``None``; the ``resolved_*`` accessors apply the defaulting rules
    (packed step for incremental prefill, async depth 1 for the packed
    step, env fallbacks for the attention toggles).  This keeps
    ``dataclasses.replace`` composable: overriding one field never bakes a
    stale resolution of another into the copy.
  * **Validation lives in ``__post_init__``** — every invariant the engine
    used to assert at construction (mode combinations, tp/packed coupling,
    block-size divisibility for prefix caching) fails fast here, before any
    device work.
  * **Env is read at construction, never at trace time** — the
    ``REPRO_ATTN_FAST`` / ``REPRO_ATTN_STREAM`` fallbacks are captured by
    ``resolved_attn_fast()`` / ``resolved_attn_stream()``, which the engine
    calls exactly once in ``__init__`` (and ``from_env`` calls once to
    pin them into explicit field values).  No jitted body ever consults
    ``os.environ``.
  * **Flags are defined once** — ``add_args(parser)`` registers the CLI
    surface shared by ``launch/serve.py`` and
    ``benchmarks/offline_throughput.py``; ``from_args(ns, **overrides)``
    turns the parsed namespace back into a config.
"""
from __future__ import annotations

import argparse
import dataclasses
import os
from typing import Optional


def _env_flag(name: str) -> bool:
    return os.environ.get(name, "0") == "1"


@dataclasses.dataclass(frozen=True)
class EngineConfig:
    """Serving-engine knobs (model-independent).  ``None`` fields mean
    "apply the documented default" — see the ``resolved_*`` accessors."""
    # ---- capacity -----------------------------------------------------------
    max_slots: int = 8
    max_len: int = 512
    # KV block size: the unit of the block-table allocator (and of the
    # legacy page accounting — ``page_size`` is accepted as an alias)
    kv_block_size: int = 16
    total_pages: Optional[int] = None
    kv_budget_bytes: Optional[int] = None
    avg_decode_len: float = 64.0
    # ---- batching -----------------------------------------------------------
    discrete_sizes: tuple[int, ...] = (256, 128, 64, 32, 16, 8)
    nano: int = 2
    # ---- step / pipeline ----------------------------------------------------
    prefill_mode: str = "incremental"
    step_mode: Optional[str] = None          # None -> packed iff incremental
    async_depth: Optional[int] = None        # None -> 1 packed / 0 legacy
    async_harvest: bool = True
    tp: int = 1
    # ---- KV-length bucketing (DESIGN.md §9) ---------------------------------
    kv_buckets: Optional[tuple[int, ...]] = None
    kv_bucketing: bool = True
    # ---- cross-request prefix caching (DESIGN.md §12) -----------------------
    prefix_caching: bool = False
    # ---- KV storage dtype (DESIGN.md §15) -----------------------------------
    # "bf16" = the model's native dtype (pre-§15 behavior); "int8" stores
    # int8 value leaves + per-(token, kv-head) f32 scale leaves and the
    # packed step quantizes at scatter / dequantizes in-register on load
    kv_dtype: str = "bf16"
    # ---- speculative decoding (DESIGN.md §13) -------------------------------
    # draft tokens verified per decoding slot per iteration; 0 disables
    # (each decode segment is then the plain single token of §8/§10)
    spec_k: int = 0
    drafter: Optional[str] = None            # None -> "ngram" when spec_k > 0
    # ---- sampling (packed step; greedy when temperature == 0) ---------------
    temperature: float = 0.0
    top_k: Optional[int] = None
    # ---- attention toggles (§Perf HC3; None -> env fallback) ----------------
    attn_fast: Optional[bool] = None
    attn_stream: Optional[bool] = None
    seed: int = 0

    # ------------------------------------------------------------------------
    def __post_init__(self):
        assert self.prefill_mode in ("incremental", "recompute"), \
            self.prefill_mode
        step = self.resolved_step_mode
        assert step in ("packed", "legacy"), step
        assert not (step == "packed" and self.prefill_mode == "recompute"), \
            "packed step runs incremental prefill only"
        assert self.tp >= 1, self.tp
        assert self.tp == 1 or step == "packed", \
            "tensor-parallel serving (DESIGN.md §11) requires the packed step"
        depth = self.resolved_async_depth
        assert depth >= 0, depth
        assert depth == 0 or step == "packed", \
            "the async pipeline (DESIGN.md §10) requires the packed step"
        assert self.kv_block_size >= 1, self.kv_block_size
        assert self.max_slots >= 1 and self.max_len >= 1
        if self.prefix_caching:
            assert step == "packed", \
                "prefix caching (DESIGN.md §12) requires the packed step"
            assert self.max_len % self.kv_block_size == 0, \
                (self.max_len, self.kv_block_size)
        assert self.kv_dtype in ("bf16", "int8"), self.kv_dtype
        if self.kv_dtype == "int8":
            assert step == "packed", \
                "int8 KV (DESIGN.md §15) requires the packed step — the " \
                "legacy decode/chunk paths write native-dtype rows"
        assert self.spec_k >= 0, self.spec_k
        if self.spec_k > 0:
            assert step == "packed", \
                "speculative decoding (DESIGN.md §13) requires the packed step"
            assert self.spec_k < self.max_len, (self.spec_k, self.max_len)
        if self.drafter is not None:
            from repro.serving.draft import drafter_names
            assert self.drafter in drafter_names(), \
                (self.drafter, drafter_names())
        assert self.temperature >= 0.0, self.temperature
        if self.top_k is not None:
            assert self.top_k >= 1, self.top_k
            assert self.temperature > 0, \
                "top_k sampling needs temperature > 0 (temperature == 0 " \
                "is greedy and ignores top_k)"

    # ---- defaulting rules (never baked into the stored fields) --------------
    @property
    def resolved_step_mode(self) -> str:
        if self.step_mode is not None:
            return self.step_mode
        # the recompute prefill path has no packed equivalent — A/B runs
        # that ask for it get the legacy per-chunk step automatically
        return "packed" if self.prefill_mode == "incremental" else "legacy"

    @property
    def resolved_async_depth(self) -> int:
        if self.async_depth is not None:
            return int(self.async_depth)
        # the pipeline is the default serving mode (§5.3 / DESIGN.md §10);
        # the legacy step has no deferred-sync path
        return 1 if self.resolved_step_mode == "packed" else 0

    @property
    def resolved_drafter(self) -> Optional[str]:
        """The drafter name to instantiate: explicit value, else the n-gram
        reference drafter whenever speculation is on."""
        if self.spec_k <= 0:
            return None
        return self.drafter if self.drafter is not None else "ngram"

    def resolved_attn_fast(self) -> bool:
        """Explicit value, else one env read — call once at construction."""
        return _env_flag("REPRO_ATTN_FAST") if self.attn_fast is None \
            else bool(self.attn_fast)

    def resolved_attn_stream(self) -> bool:
        return _env_flag("REPRO_ATTN_STREAM") if self.attn_stream is None \
            else bool(self.attn_stream)

    def resolved_kv_buckets(self) -> tuple[int, ...]:
        """The KV-length bucket grid (DESIGN.md §9), ascending, topped by
        ``max_len``; ``kv_bucketing=False`` pins the single max_len bucket."""
        from repro.serving.scheduler import default_kv_buckets
        if not self.kv_bucketing:
            return (self.max_len,)
        if self.kv_buckets is None:
            return default_kv_buckets(self.max_len)
        grid = tuple(sorted({min(b, self.max_len) for b in self.kv_buckets}))
        return grid if grid[-1] == self.max_len else grid + (self.max_len,)

    # ---- construction helpers -----------------------------------------------
    @classmethod
    def from_env(cls, **overrides) -> "EngineConfig":
        """A config with the attention-toggle env fallbacks pinned into
        explicit field values (the single env read of the process's
        configuration path)."""
        base = cls(**overrides)
        return dataclasses.replace(
            base,
            attn_fast=base.resolved_attn_fast(),
            attn_stream=base.resolved_attn_stream())

    @classmethod
    def add_args(cls, ap: argparse.ArgumentParser) -> None:
        """Register the shared engine CLI surface (defined once, consumed by
        ``launch/serve.py`` and ``benchmarks/offline_throughput.py``)."""
        ap.add_argument("--slots", type=int, default=cls.max_slots,
                        help="slot count (concurrent active requests)")
        ap.add_argument("--max-len", type=int, default=256,
                        help="per-slot cache capacity (tokens)")
        ap.add_argument("--step-mode", default="packed",
                        choices=["packed", "legacy"],
                        help="packed = one fused dispatch/iteration "
                             "(DESIGN.md §8)")
        ap.add_argument("--async-depth", type=int, default=None,
                        help="iterations kept in flight before syncing their "
                             "sampled tokens (DESIGN.md §10); 0 = eager "
                             "lock-step; default: 1 packed / 0 legacy")
        ap.add_argument("--tp", type=int, default=1,
                        help="tensor-parallel degree (DESIGN.md §11): the "
                             "packed step runs as one shard_map program over "
                             "a 1-D model mesh; on CPU the devices come from "
                             "--xla_force_host_platform_device_count")
        ap.add_argument("--no-kv-bucketing", action="store_true",
                        help="sweep max_len every iteration instead of the "
                             "KV-length bucket (DESIGN.md §9; A/B baseline)")
        ap.add_argument("--prefix-caching",
                        action=argparse.BooleanOptionalAction, default=False,
                        help="cross-request prefix caching over the "
                             "block-table KV (DESIGN.md §12): identical "
                             "prompt prefixes are prefilled once and shared "
                             "(copy-on-write on divergence)")
        ap.add_argument("--kv-block-size", type=int, default=cls.kv_block_size,
                        help="KV block size (tokens per block-table block; "
                             "must divide --max-len when --prefix-caching)")
        ap.add_argument("--kv-dtype", default=cls.kv_dtype,
                        choices=["bf16", "int8"],
                        help="KV-cache storage dtype (DESIGN.md §15): int8 "
                             "stores quantized values + per-(token, kv-head) "
                             "f32 scales — ~2x the admitted requests at a "
                             "fixed --kv-budget, dequant-on-load in the "
                             "packed-attention kernel")
        ap.add_argument("--spec-k", type=int, default=cls.spec_k,
                        help="speculative decoding (DESIGN.md §13): draft "
                             "tokens verified per decoding slot per packed "
                             "iteration; 0 = off")
        ap.add_argument("--drafter", default=None,
                        choices=["ngram"],
                        help="draft proposer for --spec-k > 0 (default: "
                             "ngram prompt-lookup/self-history matching)")
        ap.add_argument("--temperature", type=float, default=cls.temperature,
                        help="sampling temperature (0 = greedy, the default "
                             "and the spec-decode exactness baseline)")
        ap.add_argument("--top-k", type=int, default=None,
                        help="top-k sampling cutoff (needs --temperature > 0)")
        ap.add_argument("--attn-fast", action=argparse.BooleanOptionalAction,
                        default=None,
                        help="no-upcast attention refs (§Perf HC3); default: "
                             "REPRO_ATTN_FAST env")
        ap.add_argument("--attn-stream", action=argparse.BooleanOptionalAction,
                        default=None,
                        help="streamed long-seq flash ref; default: "
                             "REPRO_ATTN_STREAM env")

    @classmethod
    def from_args(cls, ns: argparse.Namespace, **overrides) -> "EngineConfig":
        """Build a config from an ``add_args`` namespace; ``overrides`` win
        over flags (benchmark mode matrices pass their per-mode kwargs)."""
        kw = dict(
            max_slots=ns.slots,
            max_len=ns.max_len,
            step_mode=ns.step_mode,
            async_depth=ns.async_depth,
            tp=ns.tp,
            kv_bucketing=not ns.no_kv_bucketing,
            prefix_caching=ns.prefix_caching,
            kv_block_size=ns.kv_block_size,
            kv_dtype=ns.kv_dtype,
            spec_k=ns.spec_k,
            drafter=ns.drafter,
            temperature=ns.temperature,
            top_k=ns.top_k,
            attn_fast=ns.attn_fast,
            attn_stream=ns.attn_stream,
        )
        kw.update(overrides)
        return cls(**kw)


@dataclasses.dataclass(frozen=True)
class PoolConfig:
    """Multi-replica pool + SLO admission knobs (DESIGN.md §14).

    Same rules as ``EngineConfig``: fields store what the caller said,
    validation in ``__post_init__``, flags defined once in ``add_args``
    and rebuilt by ``from_args``.  All SLO fields are optional — ``None``
    disables that admission/violation check, so a bare single-replica pool
    behaves exactly like the engine it wraps."""

    replicas: int = 1
    # TTFT admission SLO: predicted time-to-first-token (cheapest replica's
    # backlog / measured service rate) above this -> shed with reason
    slo_ttft_ms: Optional[float] = None
    # TPOT SLO: per-output-token latency; checked at completion (a
    # violation is recorded, not retroactively shed)
    slo_tpot_ms: Optional[float] = None
    # hard backlog cap per replica in tokens: the deterministic shed
    # trigger (virtual-clock tests can't rely on wall-time predictions)
    shed_backlog_tokens: Optional[int] = None
    # admission headroom: predicted TTFT is compared against
    # slo_ttft_ms * slo_safety (under-admit rather than violate)
    slo_safety: float = 1.0
    # queue-timeout for a request stuck WAITING on one replica; after
    # ``retry_limit`` re-routes it is shed with reason "retry_limit"
    request_timeout_s: Optional[float] = None
    retry_limit: int = 3
    backoff_base_s: float = 0.01
    # chaos: FaultPlan spec string ("kill@40:r1,...") or None
    fault_plan: Optional[str] = None
    # session affinity (multi-turn requests pinned to their prefix cache)
    affinity: bool = True

    def __post_init__(self):
        if self.replicas < 1:
            raise ValueError("replicas must be >= 1")
        if self.retry_limit < 0:
            raise ValueError("retry_limit must be >= 0")
        for f in ("slo_ttft_ms", "slo_tpot_ms", "request_timeout_s"):
            v = getattr(self, f)
            if v is not None and v <= 0:
                raise ValueError(f"{f} must be positive when set")

    @classmethod
    def add_args(cls, ap: argparse.ArgumentParser) -> None:
        """Pool CLI surface shared by launch/serve.py and the online
        latency benchmark."""
        ap.add_argument("--replicas", type=int, default=cls.replicas,
                        help="engine replicas behind the router")
        ap.add_argument("--slo-ttft-ms", type=float, default=None,
                        help="TTFT SLO; admission sheds requests whose "
                             "predicted TTFT exceeds it")
        ap.add_argument("--slo-tpot-ms", type=float, default=None,
                        help="per-output-token SLO; violations counted "
                             "at completion")
        ap.add_argument("--shed-backlog-tokens", type=int, default=None,
                        help="hard per-replica backlog cap (tokens) "
                             "before shedding")
        ap.add_argument("--request-timeout-s", type=float, default=None,
                        help="queue timeout before retry-with-backoff")
        ap.add_argument("--retry-limit", type=int, default=cls.retry_limit,
                        help="re-dispatch attempts before a request is "
                             "shed")
        ap.add_argument("--fault-plan", default=None,
                        help="chaos spec, e.g. 'kill@40:r1,stall@10:r0:20'"
                             " (tick-indexed, deterministic)")

    @classmethod
    def from_args(cls, ns: argparse.Namespace, **overrides) -> "PoolConfig":
        kw = dict(
            replicas=ns.replicas,
            slo_ttft_ms=ns.slo_ttft_ms,
            slo_tpot_ms=ns.slo_tpot_ms,
            shed_backlog_tokens=ns.shed_backlog_tokens,
            request_timeout_s=ns.request_timeout_s,
            retry_limit=ns.retry_limit,
            fault_plan=ns.fault_plan,
        )
        kw.update(overrides)
        return cls(**kw)
