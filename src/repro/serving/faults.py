"""Deterministic fault-injection (chaos) harness for the replica pool.

A ``FaultPlan`` is a list of events indexed by the pool's *tick counter*
(one tick = one sweep where every live replica steps once), not wall time —
so a seeded plan perturbs the exact same iteration every run and the chaos
exactness tests in ``tests/test_fault_tolerance.py`` can compare a killed
pool against an unperturbed one token-for-token.

Event kinds:

  * ``kill``    — replica dies abruptly: in-flight (uncommitted) work is
                  lost, committed tokens are checkpointed and re-dispatched.
  * ``stall``   — replica freezes for ``arg`` ticks (network partition /
                  preemption): it holds its work but steps nothing; the
                  router marks it suspect and the pool's per-request
                  timeouts fire if the stall outlives them.
  * ``degrade`` — replica only steps every ``arg``-th tick (thermal
                  throttle / noisy neighbor): straggler EMA sheds load.
  * ``join``    — a fresh replica is added (elastic scale-up).
  * ``leave``   — graceful drain-and-evacuate departure (scale-down).

Spec strings (``--fault-plan``) are comma-separated ``kind@tick[:rN][:arg]``:

    kill@40:r1  stall@10:r0:20  degrade@5:r1:3  join@60  leave@80:r0

``FaultPlan.seeded`` draws a reproducible random plan from a seed for
soak-style chaos runs.
"""
from __future__ import annotations

import dataclasses
import random

KINDS = ("kill", "stall", "degrade", "join", "leave")


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    tick: int
    kind: str          # one of KINDS
    replica: int = 0   # target replica index (ignored for join)
    arg: int = 0       # stall: duration ticks; degrade: step-every-N

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}")
        if self.tick < 0:
            raise ValueError("fault tick must be >= 0")

    def describe(self) -> str:
        base = f"{self.kind}@{self.tick}:r{self.replica}"
        return f"{base}:{self.arg}" if self.arg else base


class FaultPlan:
    def __init__(self, events: list[FaultEvent] = ()):  # type: ignore[assignment]
        self.events = sorted(events, key=lambda e: (e.tick, e.kind))
        self._fired: set[int] = set()

    def __bool__(self) -> bool:
        return bool(self.events)

    def __len__(self) -> int:
        return len(self.events)

    def due(self, tick: int) -> list[FaultEvent]:
        """Events that fire at or before ``tick``, each delivered once."""
        out = []
        for i, ev in enumerate(self.events):
            if ev.tick <= tick and i not in self._fired:
                self._fired.add(i)
                out.append(ev)
        return out

    def reset(self) -> None:
        self._fired.clear()

    @classmethod
    def parse(cls, spec: str) -> "FaultPlan":
        """Parse ``kind@tick[:rN][:arg]`` comma-separated event specs."""
        events = []
        for part in spec.split(","):
            part = part.strip()
            if not part:
                continue
            head, _, rest = part.partition("@")
            kind = head.strip()
            fields = rest.split(":")
            if not fields[0]:
                raise ValueError(f"fault event {part!r} missing @tick")
            tick = int(fields[0])
            replica, arg = 0, 0
            for f in fields[1:]:
                f = f.strip()
                if f.startswith("r"):
                    replica = int(f[1:])
                else:
                    arg = int(f)
            if kind == "stall" and arg <= 0:
                arg = 10
            if kind == "degrade" and arg <= 1:
                arg = 2
            events.append(FaultEvent(tick=tick, kind=kind,
                                     replica=replica, arg=arg))
        return cls(events)

    @classmethod
    def seeded(cls, seed: int, n_events: int, horizon: int,
               n_replicas: int, kinds: tuple[str, ...] = KINDS) \
            -> "FaultPlan":
        """Reproducible random plan: same (seed, args) -> same events."""
        rng = random.Random(seed)
        events = []
        for _ in range(n_events):
            kind = rng.choice(kinds)
            events.append(FaultEvent(
                tick=rng.randrange(1, max(horizon, 2)), kind=kind,
                replica=rng.randrange(max(n_replicas, 1)),
                arg=rng.randrange(2, 8)))
        return cls(events)

    def describe(self) -> str:
        return ",".join(e.describe() for e in self.events)
