"""Multi-replica online serving pool (DESIGN.md §14).

``ReplicaPool`` is the paper's §4.1 deployment box made concrete: N live
``ServeEngine`` replicas (each internally tp=K with async depth, prefix
caching and spec decoding composing unchanged) behind the load-aware
``Router``, driven by a tick loop — one tick steps every live replica once,
fires due fault events, releases backed-off retries, flushes parked work,
and enforces queue timeouts.  Guarantees:

  * **never hang**: every submitted request terminates in exactly one of
    ``results`` (completed) or ``shed`` (explicit ``State.REJECTED`` with a
    ``reject_reason``).  Admission control sheds up front; ``run_ticked``
    sheds leftovers at its deadline; retries are bounded by
    ``retry_limit``.
  * **no silent loss**: a replica kill evacuates its queued AND in-flight
    requests — committed tokens are checkpointed into the prompt as a
    forced replay prefix (token-exact resume, see ``Request.
    checkpoint_redispatch``) and every re-dispatch/retry/shed increments a
    ``PoolStats`` counter surfaced by ``snapshot()``.
  * **determinism for tests**: with ``virtual_dt`` set the pool runs on a
    virtual clock advanced per tick, and ``FaultPlan`` events are indexed
    by tick — a seeded chaos run perturbs the same iteration every time.

SLO admission: predicted TTFT for a new request is the cheapest live
replica's backlog (queued + launched-but-uncommitted tokens, §10) plus its
own prompt, divided by the pool's measured service rate (EMA of committed
tokens/s).  Above ``slo_ttft_ms * slo_safety`` -> shed with reason
``"ttft_slo"``; a ``shed_backlog_tokens`` cap gives virtual-time tests a
deterministic trigger that needs no rate history.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Optional

from repro.distributed.elastic import ClusterState, ElasticManager
from repro.serving.config import PoolConfig
from repro.serving.faults import FaultPlan
from repro.serving.request import Request, State
from repro.serving.router import NoLiveReplicas, ReplicaHandle, Router


@dataclasses.dataclass
class PoolStats:
    submitted: int = 0
    completed: int = 0
    shed_requests: int = 0          # explicit rejections (admission/timeout)
    retries: int = 0                # timeout/backoff re-routes
    redispatched_requests: int = 0  # failure/leave evacuations re-entered
    redispatched_tokens: int = 0    # committed tokens replayed as prefix
    slo_violations: int = 0         # completed requests beyond TTFT/TPOT SLO
    timeouts: int = 0
    faults_injected: int = 0
    joins: int = 0
    leaves: int = 0
    ticks: int = 0

    def snapshot(self) -> dict:
        return {f.name: getattr(self, f.name)
                for f in dataclasses.fields(self)}


class ReplicaPool:
    def __init__(self, engines: list, cfg: PoolConfig = PoolConfig(), *,
                 fault_plan: Optional[FaultPlan] = None,
                 engine_factory: Optional[Callable[[], object]] = None,
                 virtual_dt: Optional[float] = None,
                 elastic: Optional[ElasticManager] = None,
                 rate_alpha: float = 0.3):
        assert engines, "pool needs at least one engine"
        self.cfg = cfg
        if fault_plan is None and cfg.fault_plan:
            fault_plan = FaultPlan.parse(cfg.fault_plan)
        self.faults = fault_plan or FaultPlan([])
        self.engine_factory = engine_factory
        self.elastic = elastic or ElasticManager(
            ClusterState(data=len(engines), model=1), min_data=1)
        # virtual clock: tests advance time by virtual_dt per tick so
        # arrival/TTFT stamps and timeouts are deterministic; engines are
        # re-pointed at the pool clock so their commit stamps agree with it
        self.virtual_dt = virtual_dt
        self._vnow = 0.0
        handles = []
        for i, eng in enumerate(engines):
            eng._clock = self.clock
            handles.append(ReplicaHandle(i, eng))
        self.router = Router(handles, affinity=cfg.affinity)
        self.stats = PoolStats()
        self.results: dict[int, Request] = {}
        self.shed: list[Request] = []
        self.tick_count = 0
        self.halted = False
        self._rate: Optional[float] = None          # committed tokens/s EMA
        self._rate_alpha = rate_alpha
        self._prev_tokens = [0] * len(engines)
        self._last_step_s = [1e-3] * len(engines)
        self._dispatched_at: dict[int, float] = {}
        self._backoff: list[tuple[float, Request]] = []

    # ---- clock -------------------------------------------------------------
    def clock(self) -> float:
        if self.virtual_dt is not None:
            return self._vnow
        return time.perf_counter()

    # ---- admission ---------------------------------------------------------
    def _shed(self, req: Request, reason: str) -> None:
        req.state = State.REJECTED
        req.reject_reason = reason
        self.stats.shed_requests += 1
        self.shed.append(req)

    def _servable(self, req: Request) -> bool:
        """Can some live engine fit this prompt and still generate at
        least one token?  (The engine clamps ``max_new_tokens`` to the
        slot extent; a prompt at/over ``max_len`` would clamp to zero and
        sit in the scheduler forever.)"""
        for h in self.router.replicas:
            if not h.alive or h.engine is None:
                return True      # engine-less handle: no length limit known
            eng = h.engine
            if req.prompt_len + 1 + eng.spec_k <= eng.max_len:
                return True
        return False

    def _best_backlog(self) -> Optional[int]:
        best = None
        for h in self.router.replicas:
            if not h.alive:
                continue
            b = h.stats().backlog_tokens
            if best is None or b < best:
                best = b
        return best

    def predicted_ttft_s(self, prompt_len: int) -> Optional[float]:
        """Admission estimate: cheapest backlog + own prompt, over the
        measured service rate.  ``None`` until a rate has been observed
        (optimistic: the empty pool admits everything)."""
        if self._rate is None or self._rate <= 0:
            return None
        best = self._best_backlog()
        if best is None:
            return None
        return (best + prompt_len) / self._rate

    def submit(self, req: Request) -> bool:
        """Admit-or-shed, never hang: returns False with the request in
        ``self.shed`` (explicit ``REJECTED`` + reason) when admission
        declines it."""
        self.stats.submitted += 1
        if not req.arrival:
            req.arrival = self.clock()
        if self.halted:
            self._shed(req, "pool_halted")
            return False
        if self.router.n_alive == 0:
            self._shed(req, "no_live_replicas")
            return False
        if not self._servable(req):
            # a prompt no live engine can fit would head-of-line block its
            # scheduler forever — reject it up front instead
            self._shed(req, "too_long")
            return False
        cap = self.cfg.shed_backlog_tokens
        best = self._best_backlog()
        if cap is not None and best is not None \
                and best + req.prompt_len > cap:
            self._shed(req, "backlog")
            return False
        if self.cfg.slo_ttft_ms is not None:
            pred = self.predicted_ttft_s(req.prompt_len)
            if pred is not None and pred * 1e3 > \
                    self.cfg.slo_ttft_ms * self.cfg.slo_safety:
                self._shed(req, "ttft_slo")
                return False
        try:
            self.router.submit(req)
        except NoLiveReplicas:
            self._shed(req, "no_live_replicas")
            return False
        self._dispatched_at[req.rid] = self.clock()
        return True

    # ---- completion --------------------------------------------------------
    def _complete(self, req: Request) -> None:
        if req.rid in self.results:
            return
        self.results[req.rid] = req
        self.stats.completed += 1
        self._dispatched_at.pop(req.rid, None)
        if req.replica is not None \
                and req.replica < len(self.router.replicas):
            self.router.replicas[req.replica].assigned.pop(req.rid, None)
        slo_t, slo_p = self.cfg.slo_ttft_ms, self.cfg.slo_tpot_ms
        if slo_t is not None and req.first_token_at is not None \
                and (req.first_token_at - req.arrival) * 1e3 > slo_t:
            self.stats.slo_violations += 1
        elif slo_p is not None and req.finished_at is not None \
                and req.first_token_at is not None and len(req.output) > 1:
            tpot = (req.finished_at - req.first_token_at) \
                / (len(req.output) - 1)
            if tpot * 1e3 > slo_p:
                self.stats.slo_violations += 1

    # ---- faults / membership ----------------------------------------------
    def _count_evacuation(self, handle: ReplicaHandle,
                          fn: Callable[[], tuple]) -> list[Request]:
        """Run an evacuation, folding its engine-side token counts into the
        pool counters and completing checkpoint-finished requests."""
        eng = handle.engine
        before = eng.stats.evacuated_tokens if eng is not None else 0
        finished, moved = fn()
        if eng is not None:
            self.stats.redispatched_tokens += \
                eng.stats.evacuated_tokens - before
        self.stats.redispatched_requests += len(moved)
        for r in finished:
            self._complete(r)
        for r in moved:
            self._dispatched_at[r.rid] = self.clock()
        return moved

    def fail_replica(self, idx: int) -> list[Request]:
        """Abrupt kill: in-flight (uncommitted) tokens are lost; committed
        work is checkpointed and re-dispatched.  Returns moved requests."""
        if idx >= len(self.router.replicas) \
                or not self.router.replicas[idx].alive:
            return []
        handle = self.router.replicas[idx]
        moved = self._count_evacuation(
            handle, lambda: self.router.retire_replica(idx, drain=False))
        decision = self.elastic.on_failure("data", 1)
        if decision.action == "halt":
            self.halted = True
            # nothing can run: everything evacuated-but-unplaced is shed
            # explicitly rather than parked forever
            for r in list(self.router.pending):
                self._shed(r, "pool_halted")
            self.router.pending.clear()
        return moved

    def leave_replica(self, idx: int) -> list[Request]:
        """Graceful scale-down: drain the pipeline first (its in-flight
        tokens commit), then evacuate what remains."""
        if idx >= len(self.router.replicas) \
                or not self.router.replicas[idx].alive:
            return []
        if self.router.n_alive <= 1:
            return []           # refuse to drain the last replica
        handle = self.router.replicas[idx]
        moved = self._count_evacuation(
            handle, lambda: self.router.retire_replica(idx, drain=True))
        self.elastic.on_leave(1)     # planned, not failed
        self.stats.leaves += 1
        return moved

    def join_replica(self, engine=None) -> Optional[int]:
        """Elastic scale-up; pulls parked work onto the new replica."""
        if engine is None:
            if self.engine_factory is None:
                return None
            engine = self.engine_factory()
        engine._clock = self.clock
        idx = len(self.router.replicas)
        self.router.add_replica(ReplicaHandle(idx, engine))
        self._prev_tokens.append(self._engine_tokens(engine))
        self._last_step_s.append(1e-3)
        self.elastic.on_capacity(1)
        self.stats.joins += 1
        self.halted = False
        return idx

    def _apply_fault(self, ev) -> None:
        self.stats.faults_injected += 1
        h = (self.router.replicas[ev.replica]
             if ev.replica < len(self.router.replicas) else None)
        if ev.kind == "kill":
            self.fail_replica(ev.replica)
        elif ev.kind == "stall" and h is not None and h.alive:
            h.stall_until = max(h.stall_until, self.tick_count + ev.arg)
            h.suspect = True
        elif ev.kind == "degrade" and h is not None and h.alive:
            h.degrade = max(ev.arg, 2)
            h.suspect = True
        elif ev.kind == "join":
            self.join_replica()
        elif ev.kind == "leave":
            self.leave_replica(ev.replica)

    # ---- timeouts / retries ------------------------------------------------
    def _check_timeouts(self, now: float) -> None:
        limit = self.cfg.request_timeout_s
        if limit is None:
            return
        for h in self.router.replicas:
            if not h.alive or h.engine is None:
                continue
            sched = h.engine.scheduler
            for r in [r for r in sched.waiting
                      if now - self._dispatched_at.get(r.rid, now) > limit]:
                sched.waiting.remove(r)
                h.assigned.pop(r.rid, None)
                self.stats.timeouts += 1
                r.retries += 1
                if r.retries > self.cfg.retry_limit:
                    self._dispatched_at.pop(r.rid, None)
                    self._shed(r, "retry_limit")
                    continue
                self.stats.retries += 1
                delay = self.cfg.backoff_base_s * 2 ** (r.retries - 1)
                self._backoff.append((now + delay, r))

    def _release_backoff(self, now: float) -> None:
        due = [r for t, r in self._backoff if t <= now]
        self._backoff = [(t, r) for t, r in self._backoff if t > now]
        for r in due:
            try:
                self.router.submit(r)
                self._dispatched_at[r.rid] = now
            except NoLiveReplicas:
                self._shed(r, "no_live_replicas")

    # ---- the event loop ----------------------------------------------------
    def _engine_tokens(self, eng) -> int:
        return eng.stats.prefill_tokens + eng.stats.decode_tokens

    def _observe_rate(self, dt: float, committed: int) -> None:
        if dt <= 0 or committed <= 0:
            return
        inst = committed / dt
        self._rate = inst if self._rate is None else (
            self._rate_alpha * inst + (1 - self._rate_alpha) * self._rate)

    def tick(self) -> list[Request]:
        """One pool iteration: advance the clock, fire due faults, release
        retries, flush parked work, step every live (non-stalled) replica
        once, observe service rate, enforce queue timeouts."""
        if self.virtual_dt is not None:
            self._vnow += self.virtual_dt
        now = self.clock()
        self.stats.ticks += 1
        for ev in self.faults.due(self.tick_count):
            self._apply_fault(ev)
        self._release_backoff(now)
        for r in self.router.flush_pending():
            self._dispatched_at[r.rid] = now
        finished: list[Request] = []
        committed = 0
        reps = self.router.replicas
        for i, h in enumerate(reps):
            if not h.alive or h.engine is None:
                continue
            if self.tick_count < h.stall_until:
                continue
            if h.degrade > 1 and self.tick_count % h.degrade:
                continue
            if h.suspect and self.tick_count >= h.stall_until \
                    and h.degrade <= 1:
                h.suspect = False        # stall expired: healthy again
            eng = h.engine
            t0 = time.perf_counter()
            plan = eng.scheduler.plan()
            if plan is None:
                done = eng.drain(max_retire=1) if eng.in_flight else []
            else:
                done = eng.step(plan)
            if plan is not None or done:
                self._last_step_s[i] = max(time.perf_counter() - t0, 1e-9)
            tot = self._engine_tokens(eng)
            committed += tot - self._prev_tokens[i]
            self._prev_tokens[i] = tot
            finished += done
        self.router.observe_step_times(list(self._last_step_s))
        dt = self.virtual_dt if self.virtual_dt is not None \
            else sum(self._last_step_s) / max(len(self._last_step_s), 1)
        self._observe_rate(dt, committed)
        self._check_timeouts(now)
        for r in finished:
            self._complete(r)
        self.tick_count += 1
        return finished

    def outstanding(self) -> int:
        """Requests admitted but not yet completed or shed."""
        n = len(self.router.pending) + len(self._backoff)
        for h in self.router.replicas:
            if not h.alive or h.engine is None:
                continue
            sched = h.engine.scheduler
            n += sched.n_waiting
            n += sum(1 for r in sched.active
                     if r.state not in (State.FINISHED, State.DISCARDED,
                                        State.REJECTED))
        return n

    def drain(self) -> list[Request]:
        """Flush every live replica's pipeline (no new work planned)."""
        done: list[Request] = []
        for h in self.router.replicas:
            if h.alive and h.engine is not None:
                done += h.engine.drain()
        for r in done:
            self._complete(r)
        return done

    def run_ticked(self, arrivals: list[tuple[int, Request]],
                   max_ticks: int = 10_000) -> dict[int, Request]:
        """Deterministic driver: submit each request at its arrival tick,
        tick until everything has completed or been shed, bounded by
        ``max_ticks`` (leftovers are shed with reason ``"deadline"`` — the
        pool never hangs).  Returns ``self.results``."""
        arrivals = sorted(arrivals, key=lambda a: a[0])
        i = 0
        while True:
            while i < len(arrivals) and arrivals[i][0] <= self.tick_count:
                self.submit(arrivals[i][1])
                i += 1
            if i >= len(arrivals) and self.outstanding() == 0:
                break
            if self.tick_count >= max_ticks:
                for h in self.router.replicas:
                    if not h.alive or h.engine is None:
                        continue
                    # abandon in-flight work UNfetched: a later drain()
                    # must not commit tokens into requests shed below
                    # (they would land in both results and shed)
                    h.engine._ring.clear()
                    sched = h.engine.scheduler
                    stuck = list(sched.waiting) + [
                        r for r in sched.active
                        if r.state not in (State.FINISHED, State.DISCARDED)]
                    sched.waiting.clear()
                    sched.active = []
                    for r in stuck:
                        self._shed(r, "deadline")
                for r in list(self.router.pending) + \
                        [r for _, r in self._backoff]:
                    self._shed(r, "deadline")
                self.router.pending.clear()
                self._backoff = []
                break
            self.tick()
        self.drain()
        return self.results

    def run_online(self, reqs: list[Request], offsets: list[float],
                   duration: Optional[float] = None) -> dict[int, Request]:
        """Wall-clock driver for benchmarks/serve: submit request ``k`` at
        ``t0 + offsets[k]``, tick when there is work, sleep (never
        busy-wait) when idle before the next arrival."""
        assert len(reqs) == len(offsets)
        t0 = self.clock()
        i = 0
        while True:
            now = self.clock() - t0
            while i < len(reqs) and offsets[i] <= now:
                reqs[i].arrival = self.clock()
                self.submit(reqs[i])
                i += 1
            if i >= len(reqs) and self.outstanding() == 0:
                break
            if duration is not None and now > duration:
                break
            if self.outstanding() == 0 and i < len(reqs):
                # idle until the next arrival: sleep, don't spin
                time.sleep(min(max(offsets[i] - now, 0.0), 0.002)
                           or 0.0005)
                continue
            self.tick()
        self.drain()
        return self.results

    # ---- observability -----------------------------------------------------
    def snapshot(self) -> dict:
        snap = self.stats.snapshot()
        snap["service_rate_tok_s"] = self._rate
        snap["pending"] = len(self.router.pending)
        snap["backoff"] = len(self._backoff)
        snap["dispatched"] = self.router.dispatched
        snap["router_redispatched"] = self.router.redispatched
        per = []
        for h in self.router.replicas:
            st = h.stats()
            per.append({
                "replica": h.rid, "alive": h.alive, "suspect": h.suspect,
                "queued_tokens": st.queued_tokens,
                "inflight_tokens": st.inflight_tokens,
                "queue_depth": st.active_requests,
                "kv_used_frac": round(st.kv_used_frac, 4),
            })
        snap["replicas"] = per
        return snap
