"""Global batch scheduler (paper §4.2): continuous batching + chunked
prefill + discrete batching, with asynchronous control-flow scheduling
(§5.3 / DESIGN.md §10).

Every iteration the scheduler emits a ``BatchPlan``:
  * all active decode requests contribute one token each;
  * head-of-line prefill requests contribute chunks sized to top the dense
    batch up to the chosen *discrete* size (paper: GEMM efficiency cliffs —
    launch 2048, never 2049);
  * new requests are admitted eagerly while the KV peak-memory estimate fits.

Plans are formed **speculatively** from launch-side state
(``prefill_launched`` / ``inflight``), not committed results: every
in-flight decode is assumed to continue, so the engine can form and launch
iteration i+1 before iteration i's sampled tokens ever reach the host (the
§5.3 mechanism generalized from lag-1 EOS to a lag-(1+depth) pipeline).
``commit`` reconciles late — it applies sampled tokens as they arrive,
flags EOS (acted on at the next planning opportunity, paper's <1%
overhead), finishes requests, and *drops* speculative tokens that raced
past a finish (``dropped_tokens``).  With an eager engine
(``async_depth=0``) launch state never leads committed state and the
schedule is bit-identical to the pre-§10 lock-step one.

With speculative decoding (``spec_k > 0``, DESIGN.md §13) each decoding
request contributes a ``spec_k + 1``-token *verify segment* instead of a
single decode token: the device-fed last accepted token plus ``spec_k``
drafter proposals.  All launch-side accounting (``inflight``, KV extents,
token budgets) uses the worst case — every verify launch is charged the
full ``spec_k + 1`` samples — and ``commit`` reconciles with the actual
accepted prefix, so admission/planning stay conservative while the device
rolls ``cache_len`` back for rejected positions on its own.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

from repro.core.nanobatch import (NanoBatchPlan, nano_batch_sizes_for,
                                  packed_segment_order)
from repro.serving.kvcache import PagedKVManager
from repro.serving.request import Request, State


@dataclasses.dataclass
class PrefillChunk:
    req: Request
    offset: int          # token offset within the prompt
    length: int


@dataclasses.dataclass
class BatchPlan:
    decode: list[Request]
    prefill: list[PrefillChunk]
    dense_batch: int     # the discrete dense size this plan fills
    # tokens per decode entry: 1, or spec_k + 1 when each decoding slot
    # launches a verify segment (DESIGN.md §13)
    decode_width: int = 1

    @property
    def dense_tokens(self) -> int:
        return (len(self.decode) * self.decode_width
                + sum(c.length for c in self.prefill))


@dataclasses.dataclass
class PackedSegment:
    """One contiguous token run of the packed stream (DESIGN.md §8):
    a single decode token, one prefill chunk, or — with speculative
    decoding (§13) — one ``spec_k + 1``-token verify segment whose first
    token is device-fed and whose tail holds the drafter's proposals."""
    req: Request
    offset: int          # position of the segment's first token (prefill);
    #                      decode positions come from the engine's slot state
    length: int
    is_decode: bool
    draft: tuple[int, ...] = ()   # spec_k proposals (verify segments only)


@dataclasses.dataclass
class PackedPlan:
    """Token-packed launch layout for one iteration: segments in nano-batch
    interleave order, plus the bucketed launch length (the *actual* compiled
    shape — the paper's discrete-batching insight applied end-to-end) and
    the iteration's KV-length bucket (DESIGN.md §9)."""
    segments: list[PackedSegment]
    tokens: int                     # real tokens (== BatchPlan.dense_tokens)
    launch_tokens: int              # bucketed T the program is compiled for
    dense_batch: int                # the discrete size the plan targeted
    nano: NanoBatchPlan             # nano-batch split of the launched stream
    segment_nano: tuple[int, ...]   # nano-batch id per segment
    kv_bucket: Optional[int] = None  # quantized max KV extent this iteration
    kv_needed: int = 0              # exact max KV extent (diagnostics)

    @property
    def padding(self) -> int:
        return self.launch_tokens - self.tokens


def default_kv_buckets(max_len: int, floor: int = 64) -> tuple[int, ...]:
    """Power-of-two KV-length grid up to ``max_len`` (DESIGN.md §9):
    ``(64, 128, 256, ..., max_len)``.  Coarse enough that the packed-step
    compile cache stays small (|T buckets| × |kv buckets| programs), fine
    enough that short-context iterations never sweep the whole cache."""
    b = min(floor, max_len)
    out = []
    while b < max_len:
        out.append(b)
        b *= 2
    out.append(max_len)
    return tuple(out)


class GlobalBatchScheduler:
    def __init__(self, kv: PagedKVManager, *,
                 discrete_sizes: tuple[int, ...] = (2048, 1024, 512, 256, 128,
                                                    64, 32, 16, 8),
                 max_active: int = 256,
                 prefill_chunk_min: int = 8,
                 kv_buckets: Optional[tuple[int, ...]] = None,
                 max_request_len: Optional[int] = None,
                 spec_k: int = 0, drafter=None):
        self.kv = kv
        self.sizes = tuple(sorted(discrete_sizes, reverse=True))
        self.max_active = max_active
        # speculative decoding (DESIGN.md §13): every decode entry plans,
        # launches, and is charged ``spec_k + 1`` tokens (worst case); the
        # drafter fills the segment's proposal tail at pack() time
        self.spec_k = int(spec_k)
        self.drafter = drafter
        # per-slot position extent (the engine's max_len): a prompt longer
        # than a slot can hold is never admitted — it stays in the waiting
        # queue (long-standing documented behavior), instead of prefilling
        # past the cache and tripping the kv-bucket bound mid-run
        self.max_request_len = max_request_len
        # KV-length grid (DESIGN.md §9), ascending; None disables bucketing
        # (PackedPlan.kv_bucket stays None -> the engine sweeps max_len)
        self.kv_buckets = (tuple(sorted(set(kv_buckets)))
                          if kv_buckets else None)
        # chunk lengths are quantized to the discrete sizes; raising the
        # floor to the smallest size means the only unbucketed lengths are
        # terminal remainders < chunk_min, keeping the engine's jit compile
        # cache bounded by len(sizes) + chunk_min - 1 programs
        self.chunk_min = max(prefill_chunk_min, self.sizes[-1])
        self.waiting: deque[Request] = deque()
        self.active: list[Request] = []
        # padding accounting for the packed step (tokens launched but unused)
        self.padding_tokens = 0
        self.launched_tokens = 0
        # speculative decode tokens launched for requests that finished
        # before their commit arrived (async pipeline overshoot, §10)
        self.dropped_tokens = 0
        # prompt tokens served from shared blocks at admission (§12)
        self.prefix_hit_tokens = 0

    # ---- admission ---------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.waiting.append(req)

    def _admit(self) -> None:
        """Eager admission under the peak-memory estimate (§4.4).  With a
        prefix-caching allocator (DESIGN.md §12) the prompt's token ids are
        handed to ``allocate`` for content-hash matching, and prefill starts
        at the cached boundary: the matched prefix's KV already sits in
        shared blocks, so only the uncached suffix is ever planned."""
        prefix = getattr(self.kv, "prefix_caching", False)
        while self.waiting and len(self.active) < self.max_active:
            cand = self.waiting[0]
            if (self.max_request_len is not None
                    and cand.prompt_len > self.max_request_len):
                break
            if not self.kv.can_admit(cand, self.active):
                break
            if not self.kv.allocate(cand.rid, max(cand.prompt_len, 1),
                                    token_ids=cand.prompt if prefix else None):
                break
            self.waiting.popleft()
            cand.state = State.PREFILL
            if prefix:
                cached = self.kv.cached_tokens(cand.rid)
                cand.prefill_done = cand.prefill_launched = cached
                self.prefix_hit_tokens += cached
            self.active.append(cand)

    # ---- discrete batching (§4.2) -------------------------------------------
    def _pick_dense(self, available: int) -> int:
        for s in self.sizes:
            if s <= available:
                return s
        return self.sizes[-1]

    def _quantize_chunk(self, want: int) -> int:
        """Round a prefill chunk length down to a discrete size.

        The engine's jitted prefill step compiles one program per chunk
        length; quantizing to the discrete set bounds the XLA compile cache
        (the paper's discrete-batching insight applied to prefill).  The
        only lengths that fall through are terminal remainders below the
        smallest discrete size (``chunk_min`` is floored at that size in
        ``__init__``), so the cache stays bounded by
        ``len(sizes) + chunk_min - 1`` entries."""
        for s in self.sizes:
            if s <= want:
                return s
        return want

    # ---- per-iteration plan --------------------------------------------------
    def _decodable(self, r: Request) -> bool:
        """Speculative decode eligibility (§10): plan from *launched* state.

        A request decodes once its whole prompt has been launched (the
        first decode token is the prefill-final sample, which may still be
        in flight — the engine's device-resident ``last_token`` buffer
        feeds it forward without a host round-trip).  Generation is capped
        by launched samples (``len(output) + inflight``), so speculation
        never runs past ``max_new_tokens``; once an EOS has been *committed*
        (``pending_eos``) the request stops planning as soon as one
        post-EOS token is in flight — the §5.3 single extra token,
        regardless of pipeline depth."""
        return (r.state != State.FINISHED
                and r.prefill_launched >= r.prompt_len
                and len(r.output) + r.inflight < r.max_new_tokens
                and not (r.pending_eos and r.inflight > 0))

    def plan(self) -> Optional[BatchPlan]:
        self._admit()
        decode = [r for r in self.active if self._decodable(r)]
        prefilling = [r for r in self.active if r.prefill_unlaunched > 0]

        width = self.spec_k + 1
        available = len(decode) * width + sum(r.prefill_unlaunched
                                              for r in prefilling)
        if available == 0:
            return None
        dense = self._pick_dense(available)

        budget = max(dense - len(decode) * width, 0)
        chunks: list[PrefillChunk] = []
        for r in prefilling:
            if budget < min(self.chunk_min, r.prefill_unlaunched):
                break
            take = self._quantize_chunk(min(budget, r.prefill_unlaunched))
            chunks.append(PrefillChunk(req=r, offset=r.prefill_launched,
                                       length=take))
            budget -= take
        return BatchPlan(decode=decode, prefill=chunks, dense_batch=dense,
                         decode_width=width)

    def mark_launched(self, plan: BatchPlan) -> None:
        """Advance launch-side state when the engine dispatches ``plan``
        (after ``pack()`` — packing reads the pre-launch in-flight counts).
        Each decode entry puts ``decode_width`` sampled tokens in flight
        (the worst case of a verify segment, §13 — ``commit`` reconciles
        with the accepted count) and each prefill-*final* chunk puts one;
        ``commit`` retires them as results arrive."""
        for r in plan.decode:
            r.inflight += plan.decode_width
        for c in plan.prefill:
            c.req.prefill_launched += c.length
            if c.req.prefill_launched >= c.req.prompt_len:
                c.req.inflight += 1

    # ---- packed launch layout (single-dispatch step, DESIGN.md §8) ----------
    def bucket_tokens(self, tokens: int) -> int:
        """Launch length for ``tokens`` packed tokens: the smallest discrete
        dense size that fits (compile-cache bounded by ``len(sizes)``), or —
        defensively, if an iteration ever exceeds the largest size — the
        next multiple of it.  When ``max_active`` sits below the smallest
        discrete size, it joins the grid as a floor bucket: a decode-only
        iteration can never exceed ``max_active`` tokens, and padding it up
        to a size no real batch reaches would be pure waste (one extra
        compiled program, used by every decode-only iteration).  With
        speculative decoding a decode-only iteration reaches
        ``max_active × (spec_k + 1)`` tokens, so that floor joins the grid
        instead — still exactly one extra bucket (the "static spec_k grid"
        of DESIGN.md §13's compile-cache accounting)."""
        grid = tuple(reversed(self.sizes))   # ascending
        floor = self.max_active * (self.spec_k + 1)
        if floor < grid[0]:
            grid = (floor,) + grid
        for s in grid:
            if tokens <= s:
                return s
        return -(-tokens // self.sizes[0]) * self.sizes[0]

    def bucket_kv(self, needed: int) -> int:
        """Quantize an iteration's max KV extent up to the kv-bucket grid
        (DESIGN.md §9) — the smallest bucket that covers it, saturating at
        the top of the grid (== the engine's ``max_len``)."""
        assert self.kv_buckets, "scheduler constructed without kv_buckets"
        for s in self.kv_buckets:
            if needed <= s:
                return s
        return self.kv_buckets[-1]

    def _kv_needed(self, segs: list[PackedSegment]) -> int:
        """Exact max KV extent this iteration's attention touches: a decode
        segment's first token writes at position ``total_tokens + inflight
        - 1`` (prompt + committed outputs + launched-but-uncommitted
        samples, which all occupy cache rows below it) and its last draft
        position sits ``spec_k`` rows further (§13 verify segments; the
        worst case — the device may roll back to less); each position
        attends one more row than its index.  A prefill chunk attends
        ``offset + length`` rows.  With an eager non-speculative engine
        ``inflight`` and ``spec_k`` are zero at pack time and this reduces
        to the pre-§10 ``total_tokens``."""
        needed = 1
        for s in segs:
            needed = max(needed,
                         s.req.total_tokens + s.req.inflight + self.spec_k
                         if s.is_decode else s.offset + s.length)
        return needed

    def _draft(self, r: Request) -> tuple[int, ...]:
        """Exactly ``spec_k`` draft tokens for a verify segment (§13).  The
        drafter sees the *committed* history only (under the async pipeline
        that lags the device by up to ``async_depth`` verifies — stale
        drafts lower acceptance, never correctness); short or empty
        proposals are padded with the last history token so every verify
        segment has the uniform static width the accounting assumes."""
        if self.spec_k == 0:
            return ()
        prop = list(self.drafter.propose(r, self.spec_k))[:self.spec_k] \
            if self.drafter is not None else []
        if len(prop) < self.spec_k:
            hist = r.prompt + r.output
            pad = prop[-1] if prop else (hist[-1] if hist else 0)
            prop += [pad] * (self.spec_k - len(prop))
        return tuple(int(t) for t in prop)

    def pack(self, plan: BatchPlan, *, nano: int = 2) -> PackedPlan:
        """Lay one iteration's decode tokens + prefill chunks out as a
        token-packed stream: segments ordered by the nano-batch interleave
        (core/nanobatch.packed_segment_order — memory-bound decode first,
        compute-bound chunks in descending length), launch length bucketed
        to the discrete dense sizes, the max KV extent quantized to the
        kv-bucket grid, padding accounted."""
        width = plan.decode_width
        segs = [PackedSegment(req=r, offset=-1, length=width, is_decode=True,
                              draft=self._draft(r))
                for r in plan.decode]
        segs += [PackedSegment(req=c.req, offset=c.offset, length=c.length,
                               is_decode=False) for c in plan.prefill]
        order = packed_segment_order(
            [("verify" if s.length > 1 else "decode") if s.is_decode
             else "prefill" for s in segs],
            [s.length for s in segs])
        segs = [segs[i] for i in order]
        tokens = plan.dense_tokens
        launch = self.bucket_tokens(tokens)
        nano_plan = nano_batch_sizes_for(launch, nano)
        self.padding_tokens += launch - tokens
        self.launched_tokens += launch
        kv_needed = self._kv_needed(segs)
        return PackedPlan(segments=segs, tokens=tokens, launch_tokens=launch,
                          dense_batch=plan.dense_batch, nano=nano_plan,
                          segment_nano=nano_plan.assign_segments(
                              [s.length for s in segs]),
                          kv_bucket=(self.bucket_kv(kv_needed)
                                     if self.kv_buckets else None),
                          kv_needed=kv_needed)

    # ---- post-iteration bookkeeping -------------------------------------------
    def commit(self, plan: BatchPlan, sampled, now: float) -> list[Request]:
        """Apply iteration results.  ``sampled``: rid -> next token id, or
        — for a §13 verify segment — the *accepted* token list (1 to
        ``decode_width`` tokens: the target-model sample at the segment
        base plus every accepted draft continuation).

        EOS is *not* acted on this iteration (async top-level scheduling,
        §5.3): the request is flagged and removed at the next planning
        opportunity, generating one extra token (one extra *verify
        segment* under speculation — everything after the post-EOS token
        is dropped here) — paper's <1% overhead.  Under a pipelined engine
        (§10) commits arrive up to ``async_depth`` iterations after their
        plan was formed; tokens sampled for a request that has since
        FINISHED (its later iterations were launched before the
        EOS-bearing commit landed) are *dropped* here — the request was
        already finalized and returned, so a late append would mutate a
        result the caller holds.  ``max_new_tokens`` truncation works the
        same way: accepted tokens past the cap are dropped, so speculation
        never overshoots the request's contract."""
        finished = []
        prefix = getattr(self.kv, "prefix_caching", False)
        for c in plan.prefill:
            c.req.prefill_done += c.length
            # lock-step drivers call plan()/commit() without the engine's
            # mark_launched(): keep launch state from falling *behind*
            # committed state, so the next plan's chunks still advance
            # (under a pipelined engine launched already leads done and
            # this is a no-op)
            c.req.prefill_launched = max(c.req.prefill_launched,
                                         c.req.prefill_done)
            # committed-and-written rows are exactly the prefilled prompt
            # prefix: full blocks below it promote into the hash table (§12)
            self.kv.extend(c.req.rid, max(c.req.total_tokens, 1),
                           token_ids=(c.req.prompt[:c.req.prefill_done]
                                      if prefix else None))
            if c.req.prefill_remaining == 0:
                c.req.state = State.DECODE
        decode_rids = {r.rid for r in plan.decode}
        for r in list(plan.decode) + [c.req for c in plan.prefill
                                      if c.req.state == State.DECODE]:
            tok = sampled.get(r.rid)
            if tok is None:
                continue
            toks = list(tok) if isinstance(tok, (list, tuple)) else [tok]
            # retire the *launched* worst case (decode_width per verify
            # segment, 1 per prefill-final), not the accepted count —
            # launch-side accounting charged the worst case too
            launched = plan.decode_width if r.rid in decode_rids else 1
            r.inflight = max(r.inflight - launched, 0)
            if r.state in (State.FINISHED, State.DISCARDED):
                self.dropped_tokens += len(toks)  # late speculative (§10)
                continue
            if r.first_token_at is None:
                r.first_token_at = now
            for t in toks:
                if r.state == State.FINISHED:
                    self.dropped_tokens += 1   # accepted past finish (§13)
                    continue
                r.output.append(t)
                # extend may fail only if the §4.4 peak estimate
                # under-predicted (requests decoding far past
                # avg_decode_len) — the launch-aware sweep
                # (kvcache.peak_pages) removes the pipeline-lag cause, the
                # rest is inherent to the heuristic; failures are counted
                # (KVStats.extend_failures), the paper's answer is rare
                # reclaim (State.DISCARDED), not a hard error on the
                # serving loop.  Committed-and-written rows at this point
                # are the prompt plus every output but the newest (its KV
                # lands next launch): only blocks fully below that promote
                # into the hash table (§12)
                self.kv.extend(r.rid, r.total_tokens + 1,
                               token_ids=(r.prompt + r.output[:-1]
                                          if prefix else None))
                hit_eos = (r.eos_id is not None and t == r.eos_id)
                if r.pending_eos or len(r.output) >= r.max_new_tokens:
                    r.state = State.FINISHED
                    r.finished_at = now
                    finished.append(r)
                elif hit_eos:
                    r.pending_eos = True   # detected next iteration
        self.active = [r for r in self.active if r.state != State.FINISHED]
        return finished

    @property
    def n_active(self) -> int:
        return len(self.active)

    @property
    def n_waiting(self) -> int:
        return len(self.waiting)
