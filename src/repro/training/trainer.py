"""Training substrate: jitted train step (single source of truth — the
dry-run lowers exactly this function), grad accumulation, remat, and the
fault-tolerant training driver (checkpoint/restart, failure injection,
straggler-aware dispatch).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable, Iterator, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed.checkpoint import CheckpointManager
from repro.distributed.elastic import StragglerMitigator
from repro.models import model as model_lib
from repro.training import optimizer as opt_lib


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    remat: str = "none"              # none | full | dots | dots_no_batch
    grad_accum: int = 1
    aux_weight: float = 0.01
    opt: opt_lib.AdamWConfig = dataclasses.field(
        default_factory=opt_lib.AdamWConfig)


def make_train_step(cfg: ModelConfig, tc: TrainConfig) -> Callable:
    """(params, opt_state, batch) -> (params, opt_state, metrics).

    Pure function; callers jit it with their own shardings/donation — the
    multi-pod dry-run lowers this very function for every train_4k cell.
    """
    def loss_fn(params, batch):
        return model_lib.loss_fn(cfg, params, batch, remat=tc.remat,
                                 aux_weight=tc.aux_weight)

    def grads_of(params, batch):
        if tc.grad_accum <= 1:
            (loss, metrics), grads = jax.value_and_grad(
                loss_fn, has_aux=True)(params, batch)
            return loss, metrics, grads

        a = tc.grad_accum
        micro = jax.tree.map(
            lambda x: x.reshape((a, x.shape[0] // a) + x.shape[1:]), batch)

        def body(carry, mb):
            acc, loss_acc = carry
            (loss, _m), g = jax.value_and_grad(loss_fn, has_aux=True)(params, mb)
            acc = jax.tree.map(lambda ag, gg: ag + gg.astype(jnp.float32),
                               acc, g)
            return (acc, loss_acc + loss), None

        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (gsum, loss_sum), _ = jax.lax.scan(body, (zeros, 0.0), micro)
        grads = jax.tree.map(lambda g: (g / a), gsum)
        return loss_sum / a, {"xent": loss_sum / a,
                              "aux": jnp.zeros((), jnp.float32)}, grads

    def train_step(params, opt_state, batch):
        loss, metrics, grads = grads_of(params, batch)
        params, opt_state, opt_metrics = opt_lib.adamw_update(
            tc.opt, params, grads, opt_state)
        metrics = dict(metrics, loss=loss, **opt_metrics)
        return params, opt_state, metrics

    return train_step


def train_state_shapes(cfg: ModelConfig, tp: int = 1, mesh=None, rules=None):
    """(params, opt_state) ShapeDtypeStructs with shardings — dry-run input."""
    pshapes = model_lib.shapes(cfg, tp, mesh, rules)

    def opt_like(sds):
        sharding = getattr(sds, "sharding", None)
        return jax.ShapeDtypeStruct(sds.shape, jnp.float32, sharding=sharding)

    opt_state = {
        "mu": jax.tree.map(opt_like, pshapes),
        "nu": jax.tree.map(opt_like, pshapes),
        "step": jax.ShapeDtypeStruct((), jnp.int32),
    }
    return pshapes, opt_state


# ---------------------------------------------------------------------------
# fault-tolerant driver
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class DriverConfig:
    steps: int = 100
    ckpt_dir: str = "/tmp/repro_ckpt"
    ckpt_every: int = 20
    keep: int = 2
    log_every: int = 10
    inject_failure_at: Optional[int] = None     # simulate a crash at step N
    n_sim_hosts: int = 4                        # straggler simulation


class Trainer:
    """Checkpoint/restart training loop.

    Failure model: ``inject_failure_at`` raises mid-run; calling ``fit``
    again restores from the last committed checkpoint and continues —
    identical to a cluster restart (tests assert bit-equal final params vs
    an uninterrupted run with the same data order)."""

    def __init__(self, cfg: ModelConfig, tc: TrainConfig, dc: DriverConfig,
                 params=None, seed: int = 0):
        self.cfg, self.tc, self.dc = cfg, tc, dc
        self.step_fn = jax.jit(make_train_step(cfg, tc), donate_argnums=(0, 1))
        self.ckpt = CheckpointManager(dc.ckpt_dir, every=dc.ckpt_every,
                                      keep=dc.keep)
        self.params = params if params is not None \
            else model_lib.init(cfg, jax.random.PRNGKey(seed))
        self.opt_state = opt_lib.adamw_init(self.params)
        self.start_step = 0
        self.straggler = StragglerMitigator(dc.n_sim_hosts)
        restored = self.ckpt.restore_or_none(
            {"params": self.params, "opt": self.opt_state})
        if restored is not None:
            tree, step = restored
            self.params, self.opt_state = tree["params"], tree["opt"]
            self.start_step = step
        self._failed = False

    def fit(self, stream: Iterator[dict],
            step_time_cb: Optional[Callable] = None) -> dict:
        history = []
        step = self.start_step
        while step < self.dc.steps:
            batch = next(stream)
            t0 = time.perf_counter()
            if self.dc.inject_failure_at is not None \
                    and step == self.dc.inject_failure_at and not self._failed:
                self._failed = True
                raise RuntimeError(f"injected failure at step {step}")
            self.params, self.opt_state, metrics = self.step_fn(
                self.params, self.opt_state,
                jax.tree.map(jnp.asarray, batch))
            dt = time.perf_counter() - t0
            step += 1
            self.ckpt.maybe_save({"params": self.params, "opt": self.opt_state},
                                 step)
            if step_time_cb is not None:
                self.straggler.observe(step_time_cb(dt))
            if step % self.dc.log_every == 0 or step == self.dc.steps:
                history.append({"step": step,
                                "loss": float(metrics["loss"]),
                                "grad_norm": float(metrics["grad_norm"]),
                                "sec": dt})
        self.ckpt.maybe_save({"params": self.params, "opt": self.opt_state},
                             step, force=True)
        self.start_step = step
        return {"history": history, "final_step": step}
