"""Data pipeline: synthetic LM stream + memmap-backed tokenized corpus.

Both emit {tokens (B, S) int32, labels (B, S)} with next-token labels; the
memmap path supports per-host sharding (host h of H reads disjoint strided
windows) — the 1000-node ingest pattern without a central loader.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Optional

import numpy as np


@dataclasses.dataclass
class DataConfig:
    batch: int
    seq_len: int
    vocab_size: int
    seed: int = 0
    path: Optional[str] = None       # memmap .bin of uint16/uint32 tokens
    dtype: str = "uint16"
    host_id: int = 0
    n_hosts: int = 1


def synthetic_stream(cfg: DataConfig) -> Iterator[dict]:
    """Zipf-ish synthetic tokens — cheap, deterministic, vocab-covering."""
    rng = np.random.default_rng(cfg.seed + cfg.host_id)
    ranks = np.arange(1, cfg.vocab_size + 1, dtype=np.float64)
    probs = 1.0 / ranks
    probs /= probs.sum()
    while True:
        toks = rng.choice(cfg.vocab_size, size=(cfg.batch, cfg.seq_len + 1),
                          p=probs).astype(np.int32)
        yield {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


def memmap_stream(cfg: DataConfig) -> Iterator[dict]:
    """Strided window reads from a flat token file, host-sharded."""
    assert cfg.path is not None
    data = np.memmap(cfg.path, dtype=np.dtype(cfg.dtype), mode="r")
    n_tokens = len(data)
    window = cfg.seq_len + 1
    n_windows = n_tokens // window
    rng = np.random.default_rng(cfg.seed + cfg.host_id)
    # host h owns windows where idx % n_hosts == host_id
    owned = np.arange(cfg.host_id, n_windows, cfg.n_hosts)
    while True:
        idx = rng.choice(owned, size=cfg.batch, replace=n_windows < cfg.batch)
        batch = np.stack([data[i * window:(i + 1) * window] for i in idx])
        batch = batch.astype(np.int32)
        yield {"tokens": batch[:, :-1], "labels": batch[:, 1:]}


def make_stream(cfg: DataConfig) -> Iterator[dict]:
    return memmap_stream(cfg) if cfg.path else synthetic_stream(cfg)


def write_corpus(path: str, tokens: np.ndarray, dtype: str = "uint16") -> None:
    tokens.astype(np.dtype(dtype)).tofile(path)
