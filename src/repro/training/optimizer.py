"""Optimizers in pure JAX (no external deps): AdamW + SGD, global-norm
clipping, warmup-cosine schedule."""
from __future__ import annotations

import dataclasses
import math
from typing import Any

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def warmup_cosine(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = cfg.lr * step / max(cfg.warmup_steps, 1)
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    cos = cfg.min_lr_ratio * cfg.lr + (1 - cfg.min_lr_ratio) * cfg.lr \
        * 0.5 * (1 + jnp.cos(math.pi * prog))
    return jnp.where(step < cfg.warmup_steps, warm, cos)


def adamw_init(params: Any) -> dict:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "mu": jax.tree.map(zeros, params),
        "nu": jax.tree.map(zeros, params),
        "step": jnp.zeros((), jnp.int32),
    }


def global_norm(tree: Any) -> jax.Array:
    sq = jax.tree.map(lambda g: jnp.sum(jnp.square(g.astype(jnp.float32))), tree)
    return jnp.sqrt(jax.tree.reduce(jnp.add, sq))


def clip_by_global_norm(grads: Any, max_norm: float) -> tuple[Any, jax.Array]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def adamw_update(cfg: AdamWConfig, params: Any, grads: Any, state: dict
                 ) -> tuple[Any, dict, dict]:
    """Returns (new_params, new_state, metrics)."""
    grads, gnorm = clip_by_global_norm(grads, cfg.clip_norm)
    step = state["step"] + 1
    lr = warmup_cosine(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(p, g, mu, nu):
        g32 = g.astype(jnp.float32)
        mu = cfg.b1 * mu + (1 - cfg.b1) * g32
        nu = cfg.b2 * nu + (1 - cfg.b2) * jnp.square(g32)
        mhat = mu / b1c
        nhat = nu / b2c
        delta = mhat / (jnp.sqrt(nhat) + cfg.eps)
        if cfg.weight_decay and p.ndim >= 2:      # decay matrices only
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), mu, nu

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = tdef.flatten_up_to(grads)
    flat_mu = tdef.flatten_up_to(state["mu"])
    flat_nu = tdef.flatten_up_to(state["nu"])
    new_p, new_mu, new_nu = [], [], []
    for p, g, mu, nu in zip(flat_p, flat_g, flat_mu, flat_nu):
        a, b, c = upd(p, g, mu, nu)
        new_p.append(a); new_mu.append(b); new_nu.append(c)
    return (tdef.unflatten(new_p),
            {"mu": tdef.unflatten(new_mu), "nu": tdef.unflatten(new_nu),
             "step": step},
            {"lr": lr, "grad_norm": gnorm})


def sgd_update(params: Any, grads: Any, lr: float) -> Any:
    return jax.tree.map(
        lambda p, g: (p.astype(jnp.float32) - lr * g.astype(jnp.float32)
                      ).astype(p.dtype), params, grads)
