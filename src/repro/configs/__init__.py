from repro.configs.base import (  # noqa: F401
    ATTN, MAMBA, MLSTM, SLSTM,
    FFN_DENSE, FFN_MOE, FFN_MOE_DENSE, FFN_NONE,
    LayerSpec, MoEConfig, MLAConfig, MambaConfig, XLSTMConfig,
    ModelConfig, ShapeConfig, SHAPES, applicable_shapes,
    register, get_config, list_configs, scale_down,
)
