"""Jamba-1.5-Large (398B) — hybrid Mamba+attention 1:7 interleave, MoE 16e top-2.

[arXiv:2403.19887; hf]. 72L d_model=8192 64H (GQA kv=8) d_ff=24576 vocab=65536.
Pattern: 9 groups of 8 layers; attention at in-group index 4 (Jamba places one
attention layer per 8-layer block); MoE on every second layer (odd in-group
index), dense FFN otherwise.
"""
from repro.configs.base import (
    ATTN, MAMBA, FFN_DENSE, FFN_MOE, LayerSpec, MambaConfig, MoEConfig,
    ModelConfig, register,
)

_pattern = tuple(
    LayerSpec(
        mixer=ATTN if i == 4 else MAMBA,
        ffn=FFN_MOE if i % 2 == 1 else FFN_DENSE,
    )
    for i in range(8)
)

CONFIG = register(ModelConfig(
    name="jamba-1.5-large-398b",
    family="hybrid",
    n_layers=72,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=24576,
    vocab_size=65536,
    block_pattern=_pattern,
    moe=MoEConfig(num_experts=16, top_k=2, expert_d_ff=24576),
    mamba=MambaConfig(d_state=16, d_conv=4, expand=2),
    citation="arXiv:2403.19887",
))
