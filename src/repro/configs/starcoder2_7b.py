"""StarCoder2-7B — GQA, RoPE [arXiv:2402.19173; hf].

32L d_model=4608 36H (GQA kv=4) d_ff=18432 vocab=49152.
"""
from repro.configs.base import LayerSpec, ModelConfig, register

CONFIG = register(ModelConfig(
    name="starcoder2-7b",
    family="dense",
    n_layers=32,
    d_model=4608,
    n_heads=36,
    n_kv_heads=4,
    d_ff=18432,
    vocab_size=49152,
    block_pattern=(LayerSpec(),),
    ffn_gated=False,          # StarCoder2 uses a plain GELU MLP
    rope_theta=1_000_000.0,
    citation="arXiv:2402.19173",
))
