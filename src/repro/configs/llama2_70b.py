"""LLaMA-2-70B — the paper's own evaluation model [arXiv:2307.09288].

80L d_model=8192 64H (GQA kv=8) d_ff=28672 vocab=32000.  Used to reproduce
the paper's cost-model case study (Table 2) and optimal-throughput numbers.
"""
from repro.configs.base import LayerSpec, ModelConfig, register

CONFIG = register(ModelConfig(
    name="llama2-70b",
    family="dense",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab_size=32000,
    block_pattern=(LayerSpec(),),
    citation="arXiv:2307.09288",
))
