"""xLSTM-1.3B — sLSTM + mLSTM blocks [arXiv:2405.04517; unverified].

48L d_model=2048 4H d_ff=0 (xLSTM blocks embed their own up/down projections;
no separate FFN). 7:1 mLSTM:sLSTM interleave (sLSTM at in-group index 7).
"""
from repro.configs.base import (
    MLSTM, SLSTM, FFN_NONE, LayerSpec, XLSTMConfig, ModelConfig, register,
)

_pattern = tuple(
    LayerSpec(mixer=SLSTM if i == 7 else MLSTM, ffn=FFN_NONE)
    for i in range(8)
)

CONFIG = register(ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab_size=50304,
    block_pattern=_pattern,
    xlstm=XLSTMConfig(proj_factor=2.0, conv_kernel=4),
    citation="arXiv:2405.04517",
))
