"""Tiny configs for CPU examples / end-to-end drivers (~100M-class and below)."""
from repro.configs.base import LayerSpec, ModelConfig, register

# ~100M dense model for examples/train_small.py
CONFIG_100M = register(ModelConfig(
    name="tiny-100m",
    family="dense",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=4,
    d_ff=2048,
    vocab_size=32000,
    block_pattern=(LayerSpec(),),
    citation="n/a (example)",
))

# even smaller model for fast engine/benchmark runs on 1 CPU core
CONFIG_TOY = register(ModelConfig(
    name="tiny-toy",
    family="dense",
    n_layers=4,
    d_model=256,
    n_heads=4,
    n_kv_heads=2,
    d_ff=512,
    vocab_size=512,
    block_pattern=(LayerSpec(),),
    citation="n/a (example)",
))
