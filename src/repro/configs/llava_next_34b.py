"""LLaVA-NeXT-34B — VLM, anyres tiling [hf:llava-hf/llava-v1.6; unverified].

60L d_model=7168 56H (GQA kv=8) d_ff=20480 vocab=64000.
Backbone-only per assignment: the vision tower is a STUB — ``input_specs()``
provides precomputed patch embeddings (anyres tiling → 1024 patch tokens for
the 32k shapes, scaled for smaller sequences) which the model projects with a
single learned matrix and prepends to the text-token embeddings.
"""
from repro.configs.base import LayerSpec, ModelConfig, register

CONFIG = register(ModelConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab_size=64000,
    block_pattern=(LayerSpec(),),
    frontend="vision",
    num_patch_tokens=1024,
    citation="hf:llava-hf/llava-v1.6-mistral-7b-hf",
))
