"""MusicGen-medium — decoder-only over EnCodec tokens [arXiv:2306.05284; hf].

48L d_model=1536 24H (MHA kv=24) d_ff=6144 vocab=2048.
Audio frontend is a STUB: inputs are 4 parallel EnCodec codebook token streams
(delay pattern applied upstream); embeddings of the 4 codebooks are summed and
the model emits 4 parallel LM heads of vocab 2048 each.
"""
from repro.configs.base import LayerSpec, ModelConfig, register

CONFIG = register(ModelConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    block_pattern=(LayerSpec(),),
    ffn_gated=False,          # MusicGen uses a plain GELU MLP
    frontend="audio",
    num_codebooks=4,
    citation="arXiv:2306.05284",
))
