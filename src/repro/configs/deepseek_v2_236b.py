"""DeepSeek-V2 (236B) — MLA kv_lora=512, 2 shared + 160 routed top-6
[arXiv:2405.04434; hf].

60L d_model=5120 128H (MLA; assignment writes "GQA kv=128" = per-head KV
up-projected from the 512-d latent) d_ff=1536 (routed expert dim)
vocab=102400.  First layer uses a dense FFN (d_ff 12288 per the paper); the
remaining 59 layers are MoE with 2 shared experts (1536 each → shared_d_ff
3072 fused) + 160 routed, top-6.
"""
from repro.configs.base import (
    ATTN, FFN_DENSE, FFN_MOE, LayerSpec, MLAConfig, MoEConfig, ModelConfig,
    register,
)

CONFIG = register(ModelConfig(
    name="deepseek-v2-236b",
    family="moe",
    n_layers=60,
    d_model=5120,
    n_heads=128,
    n_kv_heads=128,
    d_ff=12288,                     # dense layer-0 FFN dim
    vocab_size=102400,
    first_layers=(LayerSpec(mixer=ATTN, ffn=FFN_DENSE),),  # layer 0 dense
    block_pattern=(LayerSpec(mixer=ATTN, ffn=FFN_MOE),),   # layers 1..59 MoE

    moe=MoEConfig(num_experts=160, top_k=6, expert_d_ff=1536,
                  num_shared_experts=2, shared_d_ff=3072),
    mla=MLAConfig(kv_lora_rank=512, q_lora_rank=1536,
                  qk_nope_dim=128, qk_rope_dim=64, v_head_dim=128),
    citation="arXiv:2405.04434",
))
