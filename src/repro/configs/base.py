"""Model/shape configuration system.

Every architecture in the assigned pool is expressed as a ``ModelConfig``; the
unified model in ``repro.models.model`` consumes only this dataclass, so adding
an architecture is a single config file.

Block types
-----------
The per-layer structure is a repeating ``block_pattern`` of ``LayerSpec``s
(attention / mamba / mlstm / slstm) each paired with an FFN kind
(dense / moe / moe+dense-residual / none).  ``layer_groups()`` expands the
pattern to ``n_layers`` and groups identical patterns so the model can
``jax.lax.scan`` over stacked parameter pytrees (1 CPU core in this container
=> HLO size matters; scan keeps compile time flat in depth).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

# ---------------------------------------------------------------------------
# Layer / FFN kinds
# ---------------------------------------------------------------------------
ATTN = "attn"          # softmax attention (GQA or MLA)
MAMBA = "mamba"        # Mamba-1 selective SSM
MLSTM = "mlstm"        # xLSTM matrix-LSTM
SLSTM = "slstm"        # xLSTM scalar-LSTM

FFN_DENSE = "dense"
FFN_MOE = "moe"
FFN_MOE_DENSE = "moe+dense"   # Arctic-style parallel dense residual + MoE
FFN_NONE = "none"


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    """One decoder layer: a sequence-mixing op plus an FFN kind."""
    mixer: str = ATTN
    ffn: str = FFN_DENSE


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int = 0
    top_k: int = 2
    expert_d_ff: int = 0
    num_shared_experts: int = 0      # DeepSeek-style always-on experts
    shared_d_ff: int = 0
    capacity_factor: float = 1.25
    # group size (tokens per dispatch group) for the GShard einsum dispatch;
    # smaller groups shrink the (G, S, E, C) dispatch tensor working set.
    dispatch_group: int = 512
    router_jitter: float = 0.0


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    """DeepSeek-V2 multi-head latent attention."""
    kv_lora_rank: int = 512
    q_lora_rank: int = 1536
    qk_nope_dim: int = 128
    qk_rope_dim: int = 64
    v_head_dim: int = 128


@dataclasses.dataclass(frozen=True)
class MambaConfig:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0          # 0 => ceil(d_model/16)


@dataclasses.dataclass(frozen=True)
class XLSTMConfig:
    proj_factor: float = 2.0   # mLSTM up-projection factor
    conv_kernel: int = 4
    slstm_conv_kernel: int = 4


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0                # 0 => d_model // n_heads
    block_pattern: tuple[LayerSpec, ...] = (LayerSpec(),)
    # explicit specs for the first k layers (e.g. DeepSeek-V2 layer-0 dense
    # FFN); the repeating block_pattern fills the remaining layers.
    first_layers: tuple[LayerSpec, ...] = ()
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    mamba: Optional[MambaConfig] = None
    xlstm: Optional[XLSTMConfig] = None
    qk_norm: bool = False
    ffn_gated: bool = True           # SwiGLU (3 mats) vs plain MLP (2 mats)
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    # modality frontends (stub): number of non-text embedding positions the
    # input_specs() provide, and (audio) codebook count.
    frontend: str = "none"           # none | vision | audio
    num_patch_tokens: int = 0        # vision stub
    num_codebooks: int = 1           # audio stub (MusicGen)
    # long-context: archs with any full-attention layer cannot run long_500k
    dtype: str = "bfloat16"
    citation: str = ""

    # ---- derived -----------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def gqa_group(self) -> int:
        return self.n_heads // max(self.n_kv_heads, 1)

    def layer_specs(self) -> list[LayerSpec]:
        """Expand first_layers + block_pattern to n_layers LayerSpecs."""
        rest = self.n_layers - len(self.first_layers)
        assert rest >= 0, "first_layers longer than n_layers"
        pat = self.block_pattern
        reps = math.ceil(rest / len(pat))
        return list(self.first_layers) + (list(pat) * reps)[:rest]

    def layer_groups(self) -> list[tuple[tuple[LayerSpec, ...], int]]:
        """Group layers into (pattern, repeat_count) for scan-over-groups.

        The model ``jax.lax.scan``s ``repeat_count`` times over a body of
        ``len(pattern)`` sub-layers with stacked params — keeps HLO size flat
        in depth.  first_layers become (spec,)×1 leading groups.
        """
        groups: list[tuple[tuple[LayerSpec, ...], int]] = []
        for spec in self.first_layers:
            groups.append(((spec,), 1))
        rest = self.n_layers - len(self.first_layers)
        pat = self.block_pattern
        full, rem = divmod(rest, len(pat))
        if full:
            groups.append((tuple(pat), full))
        if rem:
            groups.append((tuple(pat[:rem]), 1))
        return groups

    @property
    def has_full_attention(self) -> bool:
        return any(s.mixer == ATTN for s in self.block_pattern)

    @property
    def supports_long_context(self) -> bool:
        """Sub-quadratic state growth?  Hybrids qualify (attention KV is
        sequence-shardable; Mamba/xLSTM state is O(1))."""
        return self.family in ("ssm", "hybrid")

    # NOTE: parameter counts are computed from the actual param tree (single
    # source of truth) — see ``repro.models.model.num_params`` /
    # ``active_params``, which sum ``param_shapes(cfg)`` leaves (tagging
    # expert weights by path for the MoE active count).


# ---------------------------------------------------------------------------
# Shapes (assigned): seq_len x global_batch, and which step they lower
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    step: str                 # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeConfig] = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def applicable_shapes(cfg: ModelConfig) -> list[ShapeConfig]:
    out = []
    for s in SHAPES.values():
        if s.name == "long_500k" and not cfg.supports_long_context:
            continue  # skip for pure full-attention archs (see DESIGN.md §4)
        out.append(s)
    return out


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
_REGISTRY: dict[str, "ModelConfig"] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    if not _REGISTRY:
        _load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> list[str]:
    if not _REGISTRY:
        _load_all()
    return sorted(_REGISTRY)


def _load_all() -> None:
    # import each config module for its register() side effect
    from repro.configs import (  # noqa: F401
        jamba_1_5_large_398b,
        xlstm_1_3b,
        qwen3_4b,
        minitron_4b,
        qwen3_8b,
        starcoder2_7b,
        llava_next_34b,
        musicgen_medium,
        arctic_480b,
        deepseek_v2_236b,
        llama2_70b,
        tiny,
    )


def scale_down(cfg: ModelConfig, *, n_layers: int = 0, d_model: int = 128,
               n_heads: int = 4, vocab: int = 512) -> ModelConfig:
    """Reduced same-family config for CPU smoke tests.

    Keeps the block pattern / MoE / MLA / SSM structure, shrinks all widths.
    """
    pat_len = min(len(cfg.block_pattern), 8)
    layers = n_layers or (len(cfg.first_layers) + pat_len)
    kv = max(1, min(cfg.n_kv_heads, n_heads))
    hd = max(8, d_model // n_heads)
    moe = None
    if cfg.moe is not None:
        moe = dataclasses.replace(
            cfg.moe, num_experts=min(cfg.moe.num_experts, 4),
            top_k=min(cfg.moe.top_k, 2), expert_d_ff=d_model * 2,
            shared_d_ff=d_model * 2 if cfg.moe.num_shared_experts else 0,
            dispatch_group=64)
    mla = None
    if cfg.mla is not None:
        mla = MLAConfig(kv_lora_rank=32, q_lora_rank=48, qk_nope_dim=hd,
                        qk_rope_dim=8, v_head_dim=hd)
    return dataclasses.replace(
        cfg, name=cfg.name + "-smoke", n_layers=layers, d_model=d_model,
        n_heads=n_heads, n_kv_heads=kv, head_dim=hd,
        d_ff=0 if cfg.d_ff == 0 else d_model * 3,
        vocab_size=vocab, moe=moe, mla=mla,
        num_patch_tokens=min(cfg.num_patch_tokens, 8) if cfg.num_patch_tokens else 0,
    )
