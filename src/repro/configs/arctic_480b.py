"""Snowflake Arctic (480B) — 128 experts top-2 + dense residual
[hf:Snowflake/snowflake-arctic-base; hf].

35L d_model=7168 56H (GQA kv=8) d_ff=4864 vocab=32000, MoE 128e top-2.
Arctic is a dense-MoE *hybrid residual*: each layer runs a dense FFN in
parallel with the routed MoE and sums the outputs (FFN_MOE_DENSE).
"""
from repro.configs.base import (
    FFN_MOE_DENSE, LayerSpec, MoEConfig, ModelConfig, register,
)

CONFIG = register(ModelConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,
    vocab_size=32000,
    block_pattern=(LayerSpec(ffn=FFN_MOE_DENSE),),
    moe=MoEConfig(num_experts=128, top_k=2, expert_d_ff=4864),
    citation="hf:Snowflake/snowflake-arctic-base",
))
