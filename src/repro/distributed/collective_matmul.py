"""Decomposed (overlapped) collective matmuls — the beyond-paper TPU analogue
of NanoFlow's network/compute overlap (DESIGN.md §2).

XLA *can* overlap async collectives, but an un-decomposed AllGather→GEMM
chain leaves the full gather on the critical path.  Decomposing into
``chunks`` ring steps (chunk count = the nano-batch count chosen by
core/autosearch) hides all but one chunk's ICI latency behind the MXU:

  allgather_matmul:       Y_loc = concat_p(x_p) @ W_loc  (W column-parallel)
  matmul_reduce_scatter:  Y_p   = Σ_p' (x @ W)_p'        (W row-parallel)

Both are written for use inside ``jax.shard_map`` over one mesh axis and are
bit-compatible with the naive collective + matmul (tested on host devices).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

# ``lax.pvary`` (varying-manual-axes tagging for shard_map's vma checks)
# only exists on newer jax; on older releases there is no vma tracking and
# the tag is a no-op.
_pvary = getattr(jax.lax, "pvary", lambda x, axes: x)


def allgather_matmul(x: jax.Array, w: jax.Array, axis_name: str) -> jax.Array:
    """x: (m, k_local) — feature-sharded on `axis_name`;
    w: (k_total, n_local) — each device holds ALL rows for its column shard.
    Returns x_full @ w (m, n_local) without materializing x_full: each ring
    step multiplies the chunk in hand while the next chunk is in flight.
    """
    p = jax.lax.psum(1, axis_name)
    idx = jax.lax.axis_index(axis_name)
    m, k_local = x.shape
    assert w.shape[0] == k_local * p, (x.shape, w.shape)

    def rows(i):
        # rows of w corresponding to the chunk that originated at device i
        return jax.lax.dynamic_slice_in_dim(w, i * k_local, k_local, axis=0)

    def body(step, carry):
        acc, chunk, src = carry
        acc = acc + jnp.dot(chunk, rows(src),
                            preferred_element_type=jnp.float32)
        # pass our chunk around the ring; after step s we hold (idx+s+1)'s
        nxt = jax.lax.ppermute(
            chunk, axis_name, [(j, (j - 1) % p) for j in range(p)])
        return acc, nxt, (src + 1) % p

    acc = _pvary(jnp.zeros((m, w.shape[1]), jnp.float32), (axis_name,))
    acc, chunk, src = jax.lax.fori_loop(0, p - 1, body, (acc, x, idx))
    acc = acc + jnp.dot(chunk, rows(src), preferred_element_type=jnp.float32)
    return acc.astype(x.dtype)


def matmul_reduce_scatter(x: jax.Array, w: jax.Array, axis_name: str,
                          scatter_dim: int = 1) -> jax.Array:
    """x: (m, k_local); w: (k_local, n) row-parallel shard.  Computes the
    full partial product then reduce-scatters columns across `axis_name`,
    chunk-by-chunk so each ring transfer overlaps the next chunk's GEMM.

    Returns (m, n/p): the column shard of the summed product.
    """
    p = jax.lax.psum(1, axis_name)
    idx = jax.lax.axis_index(axis_name)
    m, k_local = x.shape
    n = w.shape[1]
    assert n % p == 0, (n, p)
    nc = n // p

    def cols(i):
        return jax.lax.dynamic_slice_in_dim(w, i * nc, nc, axis=1)

    # ring reduce-scatter: the packet for column chunk c starts at device
    # c+1 and flows toward increasing ids, so device j adds its contribution
    # for chunk (j-1-s) at step s; after p-1 hops it holds its own chunk.
    def body(step, carry):
        acc, dst = carry
        acc = acc + jnp.dot(x, cols(dst), preferred_element_type=jnp.float32)
        nxt = jax.lax.ppermute(
            acc, axis_name, [(j, (j + 1) % p) for j in range(p)])
        return nxt, (dst - 1) % p

    start = (idx - 1) % p
    acc = _pvary(jnp.zeros((m, nc), jnp.float32), (axis_name,))
    acc, dst = jax.lax.fori_loop(0, p - 1, body, (acc, start))
    # dst == idx now: add our own contribution last
    acc = acc + jnp.dot(x, cols(dst), preferred_element_type=jnp.float32)
    return acc.astype(x.dtype)


def matmul_allreduce(x: jax.Array, w: jax.Array, axis_name: str) -> jax.Array:
    """Row-parallel matmul + AR = reduce-scatter matmul + all-gather (the
    all-gather chunks also overlap).  Drop-in for `psum(x @ w)`."""
    part = matmul_reduce_scatter(x, w, axis_name)
    return jax.lax.all_gather(part, axis_name, axis=1, tiled=True)
