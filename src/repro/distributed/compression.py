"""Gradient compression for the cross-pod (DCN) all-reduce.

int8 uniform quantization with *error feedback* (residual carried to the next
step), applied only to the slow ``pod`` axis — the intra-pod ICI all-reduce
stays exact.  Error feedback makes the compressed SGD trajectory converge to
the uncompressed one (Karimireddy et al. 2019); tested in
tests/test_distributed.py (compression error shrinks vs no-feedback).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp


def compress_state_init(grads: Any) -> Any:
    """Zero residuals, congruent with the grad pytree."""
    return jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)


def _quantize(x: jax.Array) -> tuple[jax.Array, jax.Array]:
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compressed_psum(grads: Any, residuals: Any, axis_name: str
                    ) -> tuple[Any, Any]:
    """int8 psum over ``axis_name`` with error feedback.

    Returns (mean-reduced grads, new residuals).  Wire cost on hardware:
    1 byte/element + one f32 scale per leaf (vs 4 bytes uncompressed).  The
    XLA emulation below psums the *dequantized* values (numerically identical
    to an int8-payload collective with per-device scales); a production DCN
    backend would ship the int8 payload itself."""
    n = jax.lax.psum(1, axis_name)

    def leaf(g, r):
        x = g.astype(jnp.float32) + r
        q, scale = _quantize(x)
        deq = q.astype(jnp.float32) * scale
        new_r = x - deq                        # error feedback
        # int8 wire: sum int32 of int8 payloads; scales are per-device, so
        # psum the dequantized contribution (scale ⊗ int8) — payload stays
        # 1 B/elem on the wire, scales are O(1).
        summed = jax.lax.psum(deq, axis_name)
        return (summed / n).astype(g.dtype), new_r

    pairs = jax.tree.map(leaf, grads, residuals)
    reduced = jax.tree.map(lambda t: t[0], pairs,
                           is_leaf=lambda t: isinstance(t, tuple))
    new_res = jax.tree.map(lambda t: t[1], pairs,
                           is_leaf=lambda t: isinstance(t, tuple))
    return reduced, new_res


def plain_psum(grads: Any, axis_name: str) -> Any:
    n = jax.lax.psum(1, axis_name)
    return jax.tree.map(lambda g: jax.lax.psum(g, axis_name) / n, grads)
