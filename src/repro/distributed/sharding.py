"""Logical-axis sharding rules (MaxText-style) + activation constraints.

Model code annotates tensors with *logical* axis names; a rule table maps the
logical names to mesh axes.  ``use_mesh(mesh, rules)`` activates constraints;
outside the context (e.g. single-device CPU smoke tests) ``shard()`` is a
no-op, so the same model code runs everywhere.

Rule sets
---------
``RULES_TP_DP``      — production default: batch→data(+pod), TP width→model.
``RULES_LONG_CTX``   — long_500k decode: batch=1, so the *KV sequence* dim is
                       sharded over data (flash-decoding-style distributed
                       softmax; see models/attention.py lse-combine path).
``RULES_SINGLE``     — everything replicated (debug).
"""
from __future__ import annotations

import contextlib
import threading
from typing import Optional, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

MeshAxes = Union[str, tuple[str, ...], None]

# logical axis -> mesh axis (None = replicated). "data+" expands to
# ("pod", "data") when the mesh has a pod axis, else "data".
RULES_TP_DP: dict[str, MeshAxes] = {
    # activations
    "batch": "data+",
    "act_seq": None,
    "kv_seq": None,
    "embed": None,
    "act_heads": "model",
    "act_kv_heads": "model",
    "act_ff": "model",
    "act_vocab": "model",
    "act_experts": "model",
    "act_inner": "model",       # mamba/xlstm expanded inner dim
    "act_dv": "model",          # mLSTM value dim
    # weights
    "w_embed": None,
    "heads": "model",
    "kv_heads": "model",
    "ff": "model",
    "vocab": "model",
    "experts": "model",
    "inner": "model",
    "head_dim": None,
    "state": None,
    "lora": None,
    "dv": "model",
}

RULES_LONG_CTX: dict[str, MeshAxes] = dict(
    RULES_TP_DP,
    batch=None,                  # global_batch=1: nothing to shard
    kv_seq="data",               # shard the 524k-token KV cache over data
)

RULES_SINGLE: dict[str, MeshAxes] = {k: None for k in RULES_TP_DP}


class _Ctx(threading.local):
    mesh: Optional[Mesh] = None
    rules: dict[str, MeshAxes] = {}


_CTX = _Ctx()


@contextlib.contextmanager
def use_mesh(mesh: Optional[Mesh], rules: Optional[dict[str, MeshAxes]] = None):
    """Activate sharding constraints for model code within this context."""
    prev = (_CTX.mesh, _CTX.rules)
    _CTX.mesh = mesh
    _CTX.rules = dict(rules if rules is not None else RULES_TP_DP)
    try:
        if mesh is not None:
            with mesh:
                yield
        else:
            yield
    finally:
        _CTX.mesh, _CTX.rules = prev


def current_mesh() -> Optional[Mesh]:
    return _CTX.mesh


def _resolve(axis: Optional[str], mesh: Mesh) -> MeshAxes:
    if axis is None:
        return None
    rule = _CTX.rules.get(axis, None)
    if rule == "data+":
        return ("pod", "data") if "pod" in mesh.axis_names else "data"
    if rule == "all":
        return tuple(mesh.axis_names)
    return rule


def logical_to_pspec(axes: tuple[Optional[str], ...], mesh: Mesh,
                     rules: Optional[dict[str, MeshAxes]] = None) -> P:
    """Map a tuple of logical axis names to a PartitionSpec under `rules`."""
    if rules is None:
        rules = _CTX.rules or RULES_TP_DP
    out = []
    used: set[str] = set()
    for a in axes:
        r = rules.get(a, None) if a is not None else None
        if r == "data+":
            r = ("pod", "data") if "pod" in mesh.axis_names else "data"
        elif r == "all":
            r = tuple(mesh.axis_names)
        # a mesh axis may appear only once in a PartitionSpec
        if r is not None:
            flat = (r,) if isinstance(r, str) else tuple(r)
            if any(f in used for f in flat):
                r = None
            else:
                used.update(flat)
        out.append(r)
    return P(*out)


def shard(x: jax.Array, *axes: Optional[str]) -> jax.Array:
    """Apply with_sharding_constraint according to the active rule table.

    No-op outside a ``use_mesh`` context or when the mesh is trivial.
    """
    mesh = _CTX.mesh
    if mesh is None or mesh.size == 1:
        return x
    if len(axes) != x.ndim:
        raise ValueError(f"shard(): {len(axes)} axes for rank-{x.ndim} tensor")
    spec = logical_to_pspec(tuple(axes), mesh)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def named_sharding(mesh: Mesh, *axes: Optional[str],
                   rules: Optional[dict[str, MeshAxes]] = None) -> NamedSharding:
    return NamedSharding(mesh, logical_to_pspec(tuple(axes), mesh, rules))


def shard_map_compat(f, mesh, in_specs, out_specs, check: bool = True):
    """Version-adaptive ``shard_map``: newer jax exposes ``jax.shard_map``
    (replication checking via ``check_vma``); older releases have
    ``jax.experimental.shard_map.shard_map`` with ``check_rep``.  The
    engine's TP packed step (DESIGN.md §11) disables the check — its body
    mixes manually-replicated values with psum'd partials, which the
    static replication tracker cannot prove."""
    import inspect
    try:
        from jax.experimental.shard_map import shard_map as smap
    except ImportError:
        smap = jax.shard_map
    kw = {}
    if not check:
        # fail loudly if a future jax renames the kwarg again (check_rep ->
        # check_vma already happened once): with the check silently left on,
        # the TP body would die in an opaque replication-check trace error
        kw = {next(k for k in ("check_rep", "check_vma")
                   if k in inspect.signature(smap).parameters): False}
    return smap(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, **kw)
