"""Elastic scaling + fault tolerance orchestration (driver-level).

On a real cluster these callbacks wrap the JAX distributed runtime; in this
repo the same state machine drives the train/serve drivers with *injected*
failures (tests/test_distributed.py, examples/train_small.py --inject-failure).

Policy (DESIGN.md §5):
  * a failed host removes one ``data``-axis row -> new mesh (data-1, model);
    model-axis failures are fatal for the affected pod (its TP shards are
    incomplete) -> the pod drops out and the request stream is re-balanced.
  * params are restored from the latest checkpoint with the *new* mesh's
    shardings (checkpoint.restore handles cross-mesh placement).
  * the global batch is kept constant: per-replica micro-batch grows.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax


@dataclasses.dataclass
class ClusterState:
    data: int
    model: int
    pods: int = 1
    failed_hosts: int = 0

    @property
    def n_devices(self) -> int:
        return self.pods * self.data * self.model


@dataclasses.dataclass
class ElasticDecision:
    action: str              # "continue" | "rescale" | "halt"
    new_state: ClusterState
    reason: str = ""


class ElasticManager:
    """Decides mesh reconfiguration on failure / capacity-change events."""

    def __init__(self, state: ClusterState, min_data: int = 1):
        self.state = state
        self.min_data = min_data

    def on_failure(self, axis: str = "data", count: int = 1) -> ElasticDecision:
        s = self.state
        if axis == "model":
            if s.pods > 1:
                new = ClusterState(s.data, s.model, s.pods - 1,
                                   s.failed_hosts + count)
                self.state = new
                return ElasticDecision("rescale", new,
                                       "model-axis failure: drop pod")
            return ElasticDecision("halt", s, "TP shard lost, single pod")
        new_data = s.data - count
        if new_data < self.min_data:
            return ElasticDecision("halt", s, "below minimum data parallelism")
        new = ClusterState(new_data, s.model, s.pods, s.failed_hosts + count)
        self.state = new
        return ElasticDecision("rescale", new, f"data axis {s.data}->{new_data}")

    def on_leave(self, count: int = 1) -> ElasticDecision:
        """Voluntary scale-down (drained replica retiring): same data-axis
        arithmetic and ``min_data`` floor as a failure, but the host is not
        *failed* — ``failed_hosts`` stays put so failure-rate dashboards
        aren't polluted by planned rescales."""
        s = self.state
        new_data = s.data - count
        if new_data < self.min_data:
            return ElasticDecision("halt", s, "below minimum data parallelism")
        new = ClusterState(new_data, s.model, s.pods, s.failed_hosts)
        self.state = new
        return ElasticDecision("rescale", new,
                               f"graceful leave {s.data}->{new_data}")

    def on_capacity(self, added_rows: int) -> ElasticDecision:
        s = self.state
        new = ClusterState(s.data + added_rows, s.model, s.pods)
        self.state = new
        return ElasticDecision("rescale", new, f"scale up +{added_rows} rows")


def make_mesh_for(state: ClusterState, devices=None):
    shape = ((state.pods, state.data, state.model) if state.pods > 1
             else (state.data, state.model))
    axes = (("pod", "data", "model") if state.pods > 1 else ("data", "model"))
    if devices is not None:
        n = math.prod(shape)
        import numpy as np
        return jax.sharding.Mesh(
            np.asarray(devices[:n]).reshape(shape), axes)
    return jax.make_mesh(shape, axes,
                         axis_types=(jax.sharding.AxisType.Auto,) * len(axes))


def per_replica_batch(global_batch: int, state: ClusterState) -> int:
    """Keep the global batch constant across rescales (grad-noise scale)."""
    replicas = state.pods * state.data
    return -(-global_batch // replicas)


class StragglerMitigator:
    """EMA of per-host step times -> rebalanced per-host batch shares.

    The paper's batch scheduler assigns work uniformly; at 1000+ nodes,
    stragglers (thermal throttling, flaky HBM) stretch every synchronous
    step.  We shift batch share away from slow hosts, bounded to ±25% so the
    dense-batch efficiency (discrete batching) is preserved.
    """

    def __init__(self, n_hosts: int, alpha: float = 0.2, max_skew: float = 0.25):
        self.n = n_hosts
        self.alpha = alpha
        self.max_skew = max_skew
        self.ema: Optional[list[float]] = None

    def observe(self, step_times: list[float]) -> None:
        assert len(step_times) == self.n
        if self.ema is None:
            self.ema = list(step_times)
        else:
            self.ema = [(1 - self.alpha) * e + self.alpha * t
                        for e, t in zip(self.ema, step_times)]

    def shares(self) -> list[float]:
        """Batch share per host, normalized to sum 1 (speed-proportional)."""
        if self.ema is None:
            return [1.0 / self.n] * self.n
        speed = [1.0 / max(t, 1e-9) for t in self.ema]
        mean = sum(speed) / self.n
        lo, hi = (1 - self.max_skew) * mean, (1 + self.max_skew) * mean
        speed = [min(max(s, lo), hi) for s in speed]
        total = sum(speed)
        return [s / total for s in speed]

    def split_batch(self, global_batch: int, multiple_of: int = 8) -> list[int]:
        """Integer batch split honoring discrete-batching multiples."""
        shares = self.shares()
        raw = [global_batch * s for s in shares]
        out = [max(multiple_of, int(r // multiple_of) * multiple_of)
               for r in raw]
        # fix rounding drift onto the fastest host
        drift = global_batch - sum(out)
        fastest = max(range(self.n), key=lambda i: shares[i])
        out[fastest] = max(multiple_of, out[fastest] + drift)
        return out
