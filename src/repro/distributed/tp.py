"""Tensor-parallel runtime for the packed serving step (DESIGN.md §11).

The engine wraps its jitted packed iteration in ``shard_map`` over a 1-D
``("model",)`` mesh.  Inside the body every array is a *local shard* and the
model code must say where cross-shard reductions happen.  Rather than fork a
second copy of every mixer family, the packed-path code calls the helpers
here at its (few) reduction points; outside a TP context every helper
degrades to the exact single-device computation, so ``tp=1`` remains the
unsharded code path.

Layout (one mesh axis, ``"model"``; table in DESIGN.md §11):

  * GQA      — q/k/v/o projections and the K/V slot cache sharded along
               (kv-)heads; attention is per-head local; the output
               projection is row-parallel (all-reduce).
  * MLA      — the latent path (``c_kv``/``k_rope`` cache and their
               projections) is *replicated*; the absorbed per-head
               projections (``wuq``/``wuk``/``wuv``/``wo``) are sharded
               along heads; output projection row-parallel.
  * Mamba    — the expanded inner dim ``d_in`` is sharded (contiguous
               channel blocks); dt/B/C come from a row-parallel projection
               (psum inside the token scan); ``w_out`` row-parallel.
  * mLSTM    — sharded along *heads* (= contiguous ``d_in`` channel
               blocks); the (C, n, m) matrix memory is head-sharded; the
               i/f gates are row-parallel (psum) then sliced to the local
               heads; the out-norm reduces over the full width via psum;
               ``w_down`` row-parallel.
  * sLSTM    — the tiny scalar recurrence runs replicated (DESIGN.md §4);
               only the post-recurrence GLU FFN is column/row-parallel.
  * MoE      — experts sharded over the mesh axis; routing computed
               replicated, each shard combines its local experts' outputs
               and the combine is psum'd.  Shared/dense-residual FFNs are
               column/row-parallel.
  * embed / head / norms / ``last_token`` / sampled tokens — replicated:
    greedy sampling needs the full vocab row, and a replicated
    ``last_token`` buffer means the §10 feedback loop closes with no
    collective.

Fused projections whose columns are later ``split`` in half (mamba/mLSTM
``x‖z`` up-projections, the sLSTM GLU ``u‖g``) are **re-interleaved** at
placement time (``shard_params_tp``) so each shard's contiguous column
block holds the *matching* halves — the math is unchanged, only the
storage layout of the fused axis moves.

Row-parallel matmuls route through the ring-decomposed collective matmul
(``distributed/collective_matmul.matmul_allreduce``), launched **per
nano-batch group** of the packed stream: group i's all-reduce has no data
dependence on group i+1's GEMM, so the paper's §4.3 network/compute
overlap is expressed as real dependency freedom in the launched program —
the ``NanoBatchPlan`` split governs launched collectives, not just the
cost model.
"""
from __future__ import annotations

import contextlib
import dataclasses
import threading
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import (ATTN, FFN_DENSE, FFN_MOE, FFN_MOE_DENSE,
                                MAMBA, MLSTM, SLSTM, ModelConfig)
from repro.distributed.collective_matmul import matmul_allreduce

# ---------------------------------------------------------------------------
# context
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TPContext:
    axis: str                       # mesh axis name ("model")
    size: int                       # shard count
    nano: tuple[int, ...] = ()      # nano-batch split of the packed T axis


class _State(threading.local):
    ctx: Optional[TPContext] = None


_STATE = _State()


@contextlib.contextmanager
def tp_ctx(axis: str, size: int, nano: tuple[int, ...] = ()):
    """Activate the TP helpers for a shard_map trace body.  ``size <= 1``
    deactivates (helpers become the single-device computation)."""
    prev = _STATE.ctx
    _STATE.ctx = TPContext(axis, int(size), tuple(nano)) if size > 1 else None
    try:
        yield
    finally:
        _STATE.ctx = prev


def active() -> Optional[TPContext]:
    return _STATE.ctx


def world() -> int:
    return _STATE.ctx.size if _STATE.ctx is not None else 1


# ---------------------------------------------------------------------------
# collective helpers (identity outside a TP context)
# ---------------------------------------------------------------------------
def psum(x: jax.Array) -> jax.Array:
    ctx = _STATE.ctx
    return jax.lax.psum(x, ctx.axis) if ctx is not None else x


def shard_block(x: jax.Array, axis: int = -1) -> jax.Array:
    """Slice this shard's contiguous block of a replicated full tensor
    (e.g. the psum'd mLSTM gates back down to the local heads)."""
    ctx = _STATE.ctx
    if ctx is None:
        return x
    blk = x.shape[axis] // ctx.size
    start = jax.lax.axis_index(ctx.axis) * blk
    return jax.lax.dynamic_slice_in_dim(x, start, blk, axis=axis)


def row_parallel(x: jax.Array, w: jax.Array) -> jax.Array:
    """``x (..., k_local) @ w (k_local, n)`` summed over the TP axis.

    Under TP the sum is launched through the ring-decomposed collective
    matmul once **per nano-batch group** of the leading (token) axis, so
    group i's collective is dependency-free of group i+1's GEMM (paper
    §4.3 / DESIGN.md §11).  Outside a TP context this is the plain einsum.
    """
    ctx = _STATE.ctx
    if ctx is None:
        return jnp.einsum("...k,kn->...n", x, w)
    if w.shape[-1] % ctx.size:
        # ring reduce-scatter needs n % p == 0; fall back to a plain psum
        return jax.lax.psum(jnp.einsum("...k,kn->...n", x, w), ctx.axis)
    lead = x.shape[:-1]
    xf = x.reshape(-1, x.shape[-1])
    m = xf.shape[0]
    sizes = ctx.nano if (len(ctx.nano) > 1 and sum(ctx.nano) == m) else (m,)
    outs, start = [], 0
    for s in sizes:
        outs.append(matmul_allreduce(
            jax.lax.slice_in_dim(xf, start, start + s, axis=0), w, ctx.axis))
        start += s
    y = outs[0] if len(outs) == 1 else jnp.concatenate(outs, axis=0)
    return y.reshape(lead + (w.shape[-1],))


def out_project(out: jax.Array, wo: jax.Array) -> jax.Array:
    """Attention output projection ``(t,h,k),(h,k,d)->(t,d)`` — row-parallel
    over the (head-sharded) contraction under TP."""
    if _STATE.ctx is None:
        return jnp.einsum("thk,hkd->td", out, wo)
    return row_parallel(out.reshape(out.shape[0], -1),
                        wo.reshape(-1, wo.shape[-1]))


def rmsnorm_sharded(x: jax.Array, weight: jax.Array, eps: float) -> jax.Array:
    """RMSNorm over a last axis that is TP-sharded: the mean-square reduces
    over the *full* width via psum; ``weight`` is the local shard.  Outside
    a TP context this is exactly ``models.layers.rmsnorm``."""
    ctx = _STATE.ctx
    x32 = x.astype(jnp.float32)
    ss = jnp.sum(jnp.square(x32), axis=-1, keepdims=True)
    width = x.shape[-1] * (ctx.size if ctx is not None else 1)
    if ctx is not None:
        ss = jax.lax.psum(ss, ctx.axis)
    var = ss / width
    return (x32 * jax.lax.rsqrt(var + eps)).astype(x.dtype) * weight


# ---------------------------------------------------------------------------
# layout: logical param axes -> mesh axes for the manual (shard_map) layout
# ---------------------------------------------------------------------------
# Only these logical axes map to the mesh; vocab/embed/lora/head_dim/state
# stay replicated (greedy sampling wants full-vocab logits; the MLA latent
# is replicated by design).  At most one axis of a leaf is sharded.
_MANUAL_AXES = {"heads": "model", "kv_heads": "model", "ff": "model",
                "inner": "model", "experts": "model"}


def _param_spec(path: tuple[str, ...], d) -> P:
    name = path[-1]
    if name == "router":
        return P()                   # routing is computed replicated
    # mLSTM overrides, scoped to the mixer subtree so an unrelated leaf
    # that happens to share a name can never inherit the head layout:
    if "mixer" in path and name in ("w_q", "w_k", "w_v"):
        # per-head block-diagonal projections: shard the head axis (axis 1
        # after layer stacking) — the logical tags say replicated/dv but
        # the manual layout shards whole heads (DESIGN.md §11)
        return P(None, "model")
    if "mixer" in path and name in ("w_i", "w_f"):
        # gate inputs (d_in, h): rows local, full-h output psum'd
        return P(None, "model")
    spec, used = [], False
    for ax in d.axes:
        m = _MANUAL_AXES.get(ax) if ax is not None else None
        if m is not None and not used:
            spec.append(m)
            used = True
        else:
            spec.append(None)
    return P(*spec)


def _needs_half_interleave(path: tuple[str, ...]) -> bool:
    """Fused projections whose output columns are later split in half:
    mamba ``w_in`` / mLSTM ``w_up`` (x‖z) and sLSTM ``w_ffn_up`` (u‖g).
    A contiguous column shard of the fused axis would put *all* of x on
    shard 0 and all of z on shard 1; re-interleaving gives every shard the
    matching halves.  (The FFN ``w_up`` is not fused — no interleave.)"""
    return (path[-1] == "w_ffn_up"
            or (path[-1] in ("w_in", "w_up") and "mixer" in path))


def _interleave_halves(w: np.ndarray, p: int) -> np.ndarray:
    c = w.shape[-1] // 2
    blk = c // p
    a, b = w[..., :c], w[..., c:]
    parts = []
    for j in range(p):
        parts.append(a[..., j * blk:(j + 1) * blk])
        parts.append(b[..., j * blk:(j + 1) * blk])
    return np.concatenate(parts, axis=-1)


def param_pspecs_tp(cfg: ModelConfig) -> dict:
    """PartitionSpec tree matching ``model.init``'s param tree under the
    manual TP layout (shard_map in_specs / NamedSharding placement)."""
    from repro.models.model import model_defs
    from repro.models.param import map_defs
    return map_defs(_param_spec, model_defs(cfg, tp=1))


def shard_params_tp(cfg: ModelConfig, params: dict, mesh) -> dict:
    """Place a (replicated-layout) param tree on the TP mesh, applying the
    fused-column re-interleave where the layout requires it."""
    from repro.models.model import model_defs
    defs = model_defs(cfg, tp=1)
    p = int(mesh.shape["model"])

    def walk(prm, dfs, path):
        out = {}
        for k, v in prm.items():
            if isinstance(v, dict):
                out[k] = walk(v, dfs[k], path + (k,))
            else:
                leaf_path = path + (k,)
                # only the fused-projection leaves round-trip through the
                # host (their columns must be re-interleaved); everything
                # else reshards device-side
                a = _interleave_halves(np.asarray(v), p) \
                    if _needs_half_interleave(leaf_path) else v
                spec = _param_spec(leaf_path, dfs[k])
                out[k] = jax.device_put(a, NamedSharding(mesh, spec))
        return out

    return walk(params, defs, ())


def _block_cache_specs(cfg: ModelConfig, spec,
                       kv_dtype: str | None = None) -> dict:
    """PartitionSpecs per cache leaf (leading layer-stack dim included).
    ``kv_dtype="int8"`` (DESIGN.md §15) adds the scale leaves: GQA scales
    (L,N,S,KV) shard on the same kv-head axis as the values; MLA latent
    scales are replicated like the latent itself."""
    if spec.mixer == ATTN:
        if cfg.mla is not None:
            out = {"c_kv": P(), "k_rope": P()}       # latent replicated
            if kv_dtype == "int8":
                out["c_kv_s"] = P()
                out["k_rope_s"] = P()
            return out
        out = {"k": P(None, None, None, "model"),    # (L,N,S,KV,hd): kv heads
               "v": P(None, None, None, "model")}
        if kv_dtype == "int8":
            out["k_s"] = P(None, None, None, "model")   # (L,N,S,KV)
            out["v_s"] = P(None, None, None, "model")
        return out
    if spec.mixer == MAMBA:
        return {"conv": P(None, None, None, "model"),   # (L,N,K-1,d_in)
                "ssm": P(None, None, "model")}          # (L,N,d_in,n)
    if spec.mixer == MLSTM:
        return {"conv": P(None, None, None, "model"),   # (L,N,K-1,d_in)
                "c": P(None, None, "model"),            # (L,N,h,dqk,dv)
                "n": P(None, None, "model"),            # (L,N,h,dqk)
                "m": P(None, None, "model")}            # (L,N,h)
    if spec.mixer == SLSTM:
        return {k: P() for k in ("conv", "c", "n", "h", "m")}  # replicated
    raise ValueError(spec.mixer)


def cache_pspecs_tp(cfg: ModelConfig, kv_dtype: str | None = None) -> list:
    """PartitionSpec tree matching ``model.init_cache``'s structure (pass
    the engine's kv_dtype so the int8 scale leaves get their specs — the
    tree is used as shard_map in_specs and must match the cache exactly)."""
    out = []
    for pattern, reps in cfg.layer_groups():
        out.append({f"sub{i}": _block_cache_specs(cfg, spec, kv_dtype)
                    for i, spec in enumerate(pattern)})
    return out


def shard_cache_tp(cfg: ModelConfig, cache: list, mesh,
                   kv_dtype: str | None = None) -> list:
    specs = cache_pspecs_tp(cfg, kv_dtype)
    out = []
    for gi, group in enumerate(cache):
        g = {}
        for sub, leaves in group.items():
            g[sub] = {name: jax.device_put(
                leaf, NamedSharding(mesh, specs[gi][sub][name]))
                for name, leaf in leaves.items()}
        out.append(g)
    return out


# ---------------------------------------------------------------------------
# validation
# ---------------------------------------------------------------------------
def validate_tp(cfg: ModelConfig, tp: int) -> None:
    """The manual layout shards whole heads / channel blocks / experts —
    every sharded width must divide by ``tp`` (the dry-run effective-layout
    machinery of DESIGN.md §4 pads/replicates instead; the real engine
    keeps the exact math and demands divisibility)."""
    errs = []

    def div(n, v, what):
        if v % n:
            errs.append(f"{what}={v} not divisible by tp={n}")

    div(tp, cfg.d_model, "d_model")
    for spec in set(cfg.layer_specs()):
        if spec.mixer == ATTN:
            div(tp, cfg.n_heads, "n_heads")
            if cfg.mla is None:
                div(tp, cfg.n_kv_heads, "n_kv_heads")
        elif spec.mixer == MAMBA:
            from repro.models.ssm import _dims
            d_in, _, _ = _dims(cfg)
            div(tp, d_in, "mamba d_in")
        elif spec.mixer == MLSTM:
            from repro.models.xlstm import _mlstm_dims
            d_in, h, _ = _mlstm_dims(cfg)
            div(tp, h, "mlstm heads")
            div(tp, d_in, "mlstm d_in")
        elif spec.mixer == SLSTM:
            div(tp, cfg.d_model, "slstm d_model")
        if spec.ffn == FFN_DENSE:
            div(tp, cfg.d_ff, "d_ff")
        elif spec.ffn in (FFN_MOE, FFN_MOE_DENSE):
            m = cfg.moe
            div(tp, m.num_experts, "num_experts")
            if m.num_shared_experts:
                div(tp, m.shared_d_ff, "shared_d_ff")
            if spec.ffn == FFN_MOE_DENSE:
                div(tp, cfg.d_ff, "d_ff (dense residual)")
    if errs:
        raise ValueError(f"config {cfg.name!r} cannot shard at tp={tp}: "
                         + "; ".join(errs))


# ---------------------------------------------------------------------------
# collective-traffic model (benchmark observability)
# ---------------------------------------------------------------------------
def collective_bytes_per_iter(cfg: ModelConfig, t: int, tp: int,
                              itemsize: int) -> int:
    """Rough wire-byte model of one packed iteration's TP collectives: each
    row-parallel all-reduce moves ~``2(p-1)/p × payload`` per shard (ring).
    Counts the per-layer output projections, the MoE combine psum, and the
    mamba dt/B/C + mLSTM gate/norm psums inside the token scans.  A model,
    not a measurement — reported per iteration by the benchmarks."""
    if tp <= 1:
        return 0
    d = cfg.d_model
    payload = 0
    for spec in cfg.layer_specs():
        if spec.mixer == ATTN:
            payload += t * d                       # wo all-reduce
        elif spec.mixer == MAMBA:
            from repro.models.ssm import _dims
            _, dt_rank, n = _dims(cfg)
            payload += t * d + t * (dt_rank + 2 * n)   # w_out AR + dt/B/C
        elif spec.mixer == MLSTM:
            payload += t * d + t * (2 * cfg.n_heads + 1)  # w_down + gates+norm
        elif spec.mixer == SLSTM:
            payload += t * d                       # w_ffn_down all-reduce
        if spec.ffn == FFN_DENSE:
            payload += t * d
        elif spec.ffn in (FFN_MOE, FFN_MOE_DENSE):
            payload += t * d                       # combine psum
            if cfg.moe.num_shared_experts:
                payload += t * d
            if spec.ffn == FFN_MOE_DENSE:
                payload += t * d
    return int(2 * (tp - 1) / tp * payload * itemsize)
