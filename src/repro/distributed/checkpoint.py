"""Sharded checkpointing with atomic commit, retention, and elastic restore.

Layout (one directory per step):
    <dir>/step_000420/
        METADATA.json        — tree structure, shapes, dtypes, step
        <leaf-path>.npy      — one file per pytree leaf

Writes go to ``step_XXXX.tmp`` and are renamed on completion, so a crash
mid-save never corrupts the latest checkpoint (restart-safe).  ``restore``
accepts a target mesh/shardings different from the one that saved — the
elastic-rescale path (DESIGN.md §5): leaves are device_put with the *new*
sharding.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

SEP = "__"


def _flatten(tree: Any, path: tuple[str, ...] = ()) -> dict[str, Any]:
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], path + (str(k),)))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, path + (f"[{i}]",)))
    else:
        out[SEP.join(path)] = tree
    return out


def _unflatten(flat: dict[str, Any], template: Any, path: tuple[str, ...] = ()):
    if isinstance(template, dict):
        return {k: _unflatten(flat, template[k], path + (str(k),))
                for k in template}
    if isinstance(template, (list, tuple)):
        seq = [_unflatten(flat, v, path + (f"[{i}]",))
               for i, v in enumerate(template)]
        return type(template)(seq) if isinstance(template, tuple) else seq
    return flat[SEP.join(path)]


def save(tree: Any, directory: str, step: int) -> str:
    """Atomic checkpoint write.  Returns the committed path."""
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)
    flat = _flatten(tree)
    meta = {"step": step, "leaves": {}}
    for name, leaf in flat.items():
        arr = np.asarray(jax.device_get(leaf))
        dtype = str(arr.dtype)
        if dtype == "bfloat16":            # np.save has no native bf16
            arr = arr.view(np.uint16)
        np.save(os.path.join(tmp, name + ".npy"), arr)
        meta["leaves"][name] = {"shape": list(arr.shape), "dtype": dtype}
    with open(os.path.join(tmp, "METADATA.json"), "w") as f:
        json.dump(meta, f)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(directory)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(directory: str, template: Any, step: Optional[int] = None,
            shardings: Optional[Any] = None) -> tuple[Any, int]:
    """Load a checkpoint into the structure of ``template``.

    ``shardings``: optional pytree (congruent with template) of Shardings for
    elastic restore onto a different mesh.  Returns (tree, step)."""
    if step is None:
        step = latest_step(directory)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {directory}")
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "METADATA.json")) as f:
        meta = json.load(f)
    flat_shard = _flatten(shardings) if shardings is not None else None
    flat = {}
    for name, info in meta["leaves"].items():
        arr = np.load(os.path.join(path, name + ".npy"))
        if info["dtype"] == "bfloat16":
            import ml_dtypes
            arr = arr.view(ml_dtypes.bfloat16)
        if flat_shard is not None and name in flat_shard \
                and flat_shard[name] is not None:
            flat[name] = jax.device_put(arr, flat_shard[name])
        else:
            flat[name] = jnp.asarray(arr)
    return _unflatten(flat, template), step


def retain(directory: str, keep: int = 3) -> None:
    """Delete all but the newest ``keep`` checkpoints."""
    if not os.path.isdir(directory):
        return
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(directory)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(directory, f"step_{s:08d}"),
                      ignore_errors=True)


class CheckpointManager:
    """save-every-N + retention + restore-or-init, used by the train driver."""

    def __init__(self, directory: str, every: int = 100, keep: int = 3):
        self.directory = directory
        self.every = every
        self.keep = keep

    def maybe_save(self, tree: Any, step: int, force: bool = False) -> bool:
        if not force and (step == 0 or step % self.every):
            return False
        save(tree, self.directory, step)
        retain(self.directory, self.keep)
        return True

    def restore_or_none(self, template: Any, shardings=None):
        try:
            return restore(self.directory, template, shardings=shardings)
        except FileNotFoundError:
            return None
