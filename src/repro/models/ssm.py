"""Mamba-1 selective-SSM block (Jamba's sequence mixer).

TP: the inner dim d_in = expand·d_model is sharded over the model axis; the
SSM scan is elementwise across channels so it shards cleanly.  dt/B/C are
small (rank + 2N per token) and replicated.  out_proj is row-parallel (AR).
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import MambaConfig, ModelConfig
from repro.distributed import tp
from repro.kernels import ops
from repro.kernels.ref import ssm_step_ref
from repro.models.layers import (causal_conv1d, causal_conv1d_step, conv_tail,
                                 shard, silu, softplus)
from repro.models.param import ParamDef


def _dims(cfg: ModelConfig) -> tuple[int, int, int]:
    mc = cfg.mamba or MambaConfig()
    d_in = mc.expand * cfg.d_model
    dt_rank = mc.dt_rank or -(-cfg.d_model // 16)
    return d_in, dt_rank, mc.d_state


def mamba_defs(cfg: ModelConfig, tp: int) -> dict:
    mc = cfg.mamba or MambaConfig()
    d, dt = cfg.d_model, cfg.dtype
    d_in, dt_rank, n = _dims(cfg)
    return {
        "w_in": ParamDef((d, 2 * d_in), ("w_embed", "inner"), dtype=dt),
        "conv_w": ParamDef((d_in, mc.d_conv), ("inner", None), dtype=dt),
        "conv_b": ParamDef((d_in,), ("inner",), init="zeros", dtype=dt),
        "w_x": ParamDef((d_in, dt_rank + 2 * n), ("inner", None), dtype=dt),
        "w_dt": ParamDef((dt_rank, d_in), (None, "inner"), dtype=dt),
        "dt_bias": ParamDef((d_in,), ("inner",), init="dt_bias", dtype="float32"),
        "a_log": ParamDef((d_in, n), ("inner", "state"), init="a_log", dtype="float32"),
        "d_skip": ParamDef((d_in,), ("inner",), init="ones", dtype="float32"),
        "w_out": ParamDef((d_in, d), ("inner", "w_embed"), dtype=dt),
    }


def _pre(cfg: ModelConfig, p: dict, x: jax.Array):
    """Shared projections.  x: (B, S, D) -> (xz split, conv'd xs, dt, b, c)."""
    d_in, dt_rank, n = _dims(cfg)
    xz = jnp.einsum("bsd,dk->bsk", x, p["w_in"])
    xz = shard(xz, "batch", "act_seq", "act_inner")
    xs, z = jnp.split(xz, 2, axis=-1)
    return xs, z


def _ssm_params(cfg: ModelConfig, p: dict, xc: jax.Array):
    d_in, dt_rank, n = _dims(cfg)
    # dt/B/C are computed from the *full* inner width; under TP the rows of
    # w_x are channel-sharded, so the contraction is a row-parallel partial
    # sum — psum'd to the replicated (dt_rank + 2N) projection (no-op at tp=1)
    proj = tp.psum(jnp.einsum("bsk,kr->bsr", xc, p["w_x"]))
    dt_low, b, c = jnp.split(proj, [dt_rank, dt_rank + n], axis=-1)
    dt = softplus(jnp.einsum("bsr,rk->bsk", dt_low, p["w_dt"]).astype(jnp.float32)
                  + p["dt_bias"])
    a = -jnp.exp(p["a_log"])
    return dt, a, b, c


def mamba_full(cfg: ModelConfig, p: dict, x: jax.Array,
               initial: Optional[dict] = None, return_state: bool = False):
    """Train/prefill.  x: (B, S, D)."""
    mc = cfg.mamba or MambaConfig()
    xs, z = _pre(cfg, p, x)
    if initial is not None:
        # chunked prefill: prepend conv history
        hist = initial["conv"]                        # (B, K-1, d_in)
        xs_ext = jnp.concatenate([hist, xs], axis=1)
        xc = causal_conv1d(xs_ext, p["conv_w"], p["conv_b"])[:, hist.shape[1]:]
        h0 = initial["ssm"]
    else:
        xs_ext = xs
        xc = causal_conv1d(xs, p["conv_w"], p["conv_b"])
        h0 = None
    xc = silu(xc)
    dt, a, b, c = _ssm_params(cfg, p, xc)
    y, h_final = ops.ssm_scan(xc, dt, a, b, c, p["d_skip"], h0)
    y = y * silu(z)
    y = shard(y, "batch", "act_seq", "act_inner")
    out = jnp.einsum("bsk,kd->bsd", y, p["w_out"])
    out = shard(out, "batch", "act_seq", "embed")
    if return_state:
        # conv history for the next chunk spans the chunk boundary: take the
        # tail of (prev history ++ chunk), not of the chunk alone — a chunk
        # shorter than d_conv-1 must keep earlier history, not zero-pad it.
        return out, {"conv": conv_tail(xs_ext, mc.d_conv - 1),
                     "ssm": h_final}
    return out


def mamba_decode(cfg: ModelConfig, p: dict, x: jax.Array, cache: dict):
    """x: (B, 1, D); cache = {conv (B,K-1,d_in), ssm (B,d_in,N)}."""
    xs, z = _pre(cfg, p, x)
    xc, conv_state = causal_conv1d_step(xs[:, 0], cache["conv"], p["conv_w"],
                                        p["conv_b"])
    xc = silu(xc)[:, None, :]
    dt, a, b, c = _ssm_params(cfg, p, xc)
    y, h = ssm_step_ref(xc[:, 0], dt[:, 0], a, b[:, 0], c[:, 0], p["d_skip"],
                        cache["ssm"])
    y = y * silu(z[:, 0])
    y = shard(y, "batch", "act_inner")
    out = jnp.einsum("bk,kd->bd", y, p["w_out"])[:, None, :]
    return shard(out, "batch", "act_seq", "embed"), {"conv": conv_state, "ssm": h}


def mamba_packed(cfg: ModelConfig, p: dict, x: jax.Array, cache: dict,
                 token_slot: jax.Array, token_active: jax.Array):
    """Token-packed dense-batch step (DESIGN.md §8).  x: (1, T, D) mixed
    decode + prefill-chunk tokens; cache: {conv (N, K-1, d_in),
    ssm (N, d_in, S)} — the whole slot-state array.

    The input/gate projections run dense over the packed stream (MXU-shaped,
    one GEMM for the whole iteration); the inherently sequential part — conv
    history shift + selective scan — runs as one ``lax.scan`` over the T
    tokens that gathers each token's *slot* state, advances it one step, and
    scatters it back.  Tokens of the same segment therefore chain through
    their slot's state exactly as the chunked path does, while tokens of
    different slots merely pass each other's state through untouched.
    Inactive (padding) tokens are masked out of the state commit."""
    xs, z = _pre(cfg, p, x)                              # (1, T, d_in)

    def step(carry, inp):
        conv_c, ssm_c = carry
        xs_t, s_i, act = inp                             # (d_in,), i32, bool
        hist = jax.lax.dynamic_index_in_dim(conv_c, s_i, 0)     # (1,K-1,d_in)
        h0 = jax.lax.dynamic_index_in_dim(ssm_c, s_i, 0)        # (1,d_in,N)
        xc_t, new_hist = causal_conv1d_step(xs_t[None], hist, p["conv_w"],
                                            p["conv_b"])
        xc_t = silu(xc_t)                                # (1, d_in)
        dt, a, b, c = _ssm_params(cfg, p, xc_t[:, None, :])
        y_t, h1 = ssm_step_ref(xc_t, dt[:, 0], a, b[:, 0], c[:, 0],
                               p["d_skip"], h0)
        conv_c = jax.lax.dynamic_update_index_in_dim(
            conv_c, jnp.where(act, new_hist, hist).astype(conv_c.dtype),
            s_i, 0)
        ssm_c = jax.lax.dynamic_update_index_in_dim(
            ssm_c, jnp.where(act, h1, h0), s_i, 0)
        return (conv_c, ssm_c), y_t[0]

    (conv_f, ssm_f), ys = jax.lax.scan(
        step, (cache["conv"], cache["ssm"]),
        (xs[0], token_slot, token_active))
    y = ys[None] * silu(z)
    y = shard(y, "batch", "act_seq", "act_inner")
    # row-parallel under TP (w_out rows are the local channel block); the
    # all-reduce is ring-decomposed per nano-batch group (DESIGN.md §11)
    out = tp.row_parallel(y, p["w_out"])
    out = shard(out, "batch", "act_seq", "embed")
    return out, {"conv": conv_f, "ssm": ssm_f}


def mamba_init_cache(cfg: ModelConfig, tp: int, batch: int) -> dict:
    mc = cfg.mamba or MambaConfig()
    d_in, _, n = _dims(cfg)
    dt = jnp.dtype(cfg.dtype)
    return {"conv": jnp.zeros((batch, mc.d_conv - 1, d_in), dt),
            "ssm": jnp.zeros((batch, d_in, n), jnp.float32)}


def mamba_cache_axes() -> dict:
    return {"conv": ("batch", None, "act_inner"),
            "ssm": ("batch", "act_inner", None)}
