"""Unified LM: embedding/frontend -> scanned decoder groups -> head.

Three execution paths (all pure functions of (cfg, params, ...)):
  * ``forward_full``  — train / prefill over a whole token chunk
  * ``forward_decode``— one-token decode against a carried cache
  * ``loss_fn``       — token-level xent (+ MoE aux) on top of forward_full

Layers are grouped by ``cfg.layer_groups()`` and executed with
``jax.lax.scan`` over stacked parameter pytrees, so HLO size (and compile
time on this 1-core container) stays flat in depth.
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import blocks
from repro.models.layers import cross_entropy, rmsnorm, shard
from repro.models.param import (ParamDef, count_params, init_params, map_defs,
                                param_shapes, stack_defs)

# ---------------------------------------------------------------------------
# definitions
# ---------------------------------------------------------------------------


def model_defs(cfg: ModelConfig, tp: int = 1) -> dict:
    d, v, dt = cfg.d_model, cfg.vocab_size, cfg.dtype
    defs: dict = {}
    if cfg.frontend == "audio":
        k = cfg.num_codebooks
        defs["embed"] = ParamDef((k, v, d), (None, "vocab", "w_embed"),
                                 dtype=dt, fan_in_axes=(1,))
        defs["head"] = ParamDef((d, k, v), ("w_embed", None, "vocab"), dtype=dt)
    else:
        defs["embed"] = ParamDef((v, d), ("vocab", "w_embed"), dtype=dt)
        if not cfg.tie_embeddings:
            defs["head"] = ParamDef((d, v), ("w_embed", "vocab"), dtype=dt)
    if cfg.frontend == "vision":
        defs["patch_proj"] = ParamDef((d, d), ("w_embed", None), dtype=dt)
    for gi, (pattern, reps) in enumerate(cfg.layer_groups()):
        group = {f"sub{i}": blocks.block_defs(cfg, spec, tp)
                 for i, spec in enumerate(pattern)}
        defs[f"group{gi}"] = stack_defs(group, reps)
    defs["final_norm"] = ParamDef((d,), ("w_embed",), init="ones", dtype=dt)
    return defs


def init(cfg: ModelConfig, key: jax.Array, tp: int = 1) -> dict:
    return init_params(model_defs(cfg, tp), key)


def shapes(cfg: ModelConfig, tp: int = 1, mesh=None, rules=None) -> dict:
    return param_shapes(model_defs(cfg, tp), mesh, rules)


def num_params(cfg: ModelConfig) -> int:
    return count_params(model_defs(cfg, tp=1))


def active_params(cfg: ModelConfig) -> int:
    """6·N_active·D — total minus the inactive routed-expert fraction."""
    tree = model_defs(cfg, tp=1)
    total = count_params(tree)
    if cfg.moe is None:
        return total
    expert_total = 0

    def leaf(path, d: ParamDef):
        nonlocal expert_total
        if "experts" in d.axes and path[-1] in ("w_gate", "w_up", "w_down"):
            import numpy as np
            expert_total += int(np.prod(d.shape))
        return None

    map_defs(leaf, tree)
    frac = cfg.moe.top_k / cfg.moe.num_experts
    return total - expert_total + int(expert_total * frac)


# ---------------------------------------------------------------------------
# embedding / head
# ---------------------------------------------------------------------------
def _embed(cfg: ModelConfig, params: dict, tokens: jax.Array,
           patches: Optional[jax.Array] = None) -> jax.Array:
    if cfg.frontend == "audio":
        # tokens: (B, S, K) — sum codebook embeddings
        k = cfg.num_codebooks
        parts = [jnp.take(params["embed"][i], tokens[..., i], axis=0)
                 for i in range(k)]
        x = functools.reduce(jnp.add, parts)
    else:
        x = jnp.take(params["embed"], tokens, axis=0)
    if cfg.frontend == "vision" and patches is not None:
        pe = jnp.einsum("bsd,dk->bsk", patches.astype(x.dtype),
                        params["patch_proj"])
        x = jnp.concatenate([pe, x], axis=1)
    return shard(x, "batch", "act_seq", "embed")


def _head(cfg: ModelConfig, params: dict, x: jax.Array) -> jax.Array:
    x = rmsnorm(x, params["final_norm"], cfg.norm_eps)
    if cfg.frontend == "audio":
        logits = jnp.einsum("bsd,dkv->bskv", x, params["head"])
        return shard(logits, "batch", "act_seq", None, "act_vocab")
    w = params["embed"].T if cfg.tie_embeddings else params["head"]
    logits = jnp.einsum("bsd,dv->bsv", x, w)
    return shard(logits, "batch", "act_seq", "act_vocab")


# ---------------------------------------------------------------------------
# forward: full chunk (train / prefill)
# ---------------------------------------------------------------------------
def forward_full(cfg: ModelConfig, params: dict, tokens: jax.Array, *,
                 patches: Optional[jax.Array] = None,
                 positions: Optional[jax.Array] = None,
                 q_offset: int | jax.Array = 0,
                 initial_states: Optional[list] = None,
                 return_states: bool = False,
                 remat: str = "none"):
    """Returns (logits, aux_loss[, states]).

    ``states``: per-group stacked mixer states (KV for attention, recurrent
    state for SSM/LSTM) for handing off to the decode path.

    ``initial_states``: carry state from an earlier chunk, in the exact
    structure this function returns via ``return_states`` — threading it
    (plus ``q_offset`` / ``positions`` set to the prefix length) continues a
    chunked prefill without recomputing the prefix (DESIGN.md §7).  For
    attention the state holds the prefix KV (latents), which is concatenated
    before the causal attention; recurrent mixers resume exactly.
    """
    x = _embed(cfg, params, tokens, patches)
    b, s = x.shape[0], x.shape[1]
    if positions is None:
        # q_offset may be a scalar or per-row (B,) — reshape to a column so
        # it broadcasts over the sequence axis (matching causal_qmask)
        qo = jnp.asarray(q_offset, jnp.int32).reshape(-1, 1)
        positions = jnp.arange(s, dtype=jnp.int32)[None, :] + jnp.zeros(
            (b, 1), jnp.int32) + qo
    aux = jnp.zeros((), jnp.float32)
    states: list[Any] = []
    for gi, (pattern, reps) in enumerate(cfg.layer_groups()):
        stacked = params[f"group{gi}"]
        init_g = initial_states[gi] if initial_states is not None else None

        def body(carry, xs, _pattern=pattern, _has_init=init_g is not None):
            x, aux = carry
            layer_p, layer_init = xs if _has_init else (xs, None)
            sts = {}
            for i, spec in enumerate(_pattern):
                init_i = None
                if layer_init is not None:
                    init_i = _state_to_initial(spec, layer_init[f"sub{i}"])
                if return_states:
                    x, a, st = blocks.block_full(
                        cfg, spec, layer_p[f"sub{i}"], x, positions,
                        q_offset=q_offset, initial=init_i, return_state=True)
                    sts[f"sub{i}"] = st
                else:
                    x, a = blocks.block_full(cfg, spec, layer_p[f"sub{i}"], x,
                                             positions, q_offset=q_offset,
                                             initial=init_i)
                aux = aux + a
            return (x, aux), (sts if return_states else None)

        if remat != "none":
            body = _remat(body, remat)
        xs = (stacked, init_g) if init_g is not None else stacked
        (x, aux), sts = jax.lax.scan(body, (x, aux), xs)
        states.append(sts)
    logits = _head(cfg, params, x)
    if return_states:
        return logits, aux, states
    return logits, aux


def _state_to_initial(spec, state: dict) -> dict:
    """Returned-state structure -> ``block_full(initial=...)`` structure.
    Attention states hold the prefix KV pair under "kv"; block_full expects
    it as ``kv_prefix`` (the prefix length is implied by the array shape).
    Recurrent states pass through unchanged (state format == cache format)."""
    from repro.configs.base import ATTN
    if spec.mixer == ATTN:
        pk, pv = state["kv"]
        return {"kv_prefix": (pk, pv, pk.shape[1])}
    return state


def _remat(body, policy: str):
    policies = {
        "full": None,
        "dots": jax.checkpoint_policies.checkpoint_dots,
        "dots_no_batch": jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
    }
    return jax.checkpoint(body, policy=policies[policy], prevent_cse=False)


def loss_fn(cfg: ModelConfig, params: dict, batch: dict, *,
            remat: str = "none", aux_weight: float = 0.01):
    """batch: {tokens, labels[, patches]} — labels ignore index < 0."""
    logits, aux = forward_full(cfg, params, batch["tokens"],
                               patches=batch.get("patches"), remat=remat)
    labels = batch["labels"]
    if cfg.frontend == "vision" and batch.get("patches") is not None:
        # loss only on text positions (after the patch prefix)
        n_patch = batch["patches"].shape[1]
        logits = logits[:, n_patch:]
    mask = (labels >= 0).astype(jnp.float32)
    xent = cross_entropy(logits, jnp.maximum(labels, 0), mask)
    return xent + aux_weight * aux, {"xent": xent, "aux": aux}


# ---------------------------------------------------------------------------
# cache: per-group stacked block caches
# ---------------------------------------------------------------------------
def init_cache(cfg: ModelConfig, tp: int, batch: int, max_len: int,
               kv_dtype: str | None = None) -> list:
    out = []
    for pattern, reps in cfg.layer_groups():
        group = {}
        for i, spec in enumerate(pattern):
            one = blocks.block_init_cache(cfg, spec, tp, batch, max_len,
                                          kv_dtype)
            group[f"sub{i}"] = jax.tree.map(
                lambda a: jnp.tile(a[None], (reps,) + (1,) * a.ndim), one)
        out.append(group)
    return out


def cache_shapes(cfg: ModelConfig, tp: int, batch: int, max_len: int,
                 mesh=None, rules=None) -> list:
    """ShapeDtypeStructs for the cache (dry-run; no allocation)."""
    cache = jax.eval_shape(lambda: init_cache(cfg, tp, batch, max_len))
    if mesh is None:
        return cache
    axes = cache_axes(cfg)
    from repro.distributed.sharding import logical_to_pspec
    from jax.sharding import NamedSharding

    def attach(sds, ax):
        spec = logical_to_pspec((None,) + tuple(ax), mesh, rules)
        return jax.ShapeDtypeStruct(sds.shape, sds.dtype,
                                    sharding=NamedSharding(mesh, spec))
    return jax.tree.map(attach, cache, axes,
                        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct))


def cache_axes(cfg: ModelConfig, kv_dtype: str | None = None) -> list:
    """Logical axes per cache leaf (without the leading layer-stack dim)."""
    out = []
    for pattern, reps in cfg.layer_groups():
        group = {f"sub{i}": blocks.block_cache_axes(cfg, spec, kv_dtype)
                 for i, spec in enumerate(pattern)}
        out.append(group)
    return out


def cache_pspecs(cfg: ModelConfig, mesh, rules=None) -> list:
    from repro.distributed.sharding import logical_to_pspec
    axes = cache_axes(cfg)
    return jax.tree.map(
        lambda ax: logical_to_pspec((None,) + tuple(ax), mesh, rules),
        axes, is_leaf=lambda x: isinstance(x, tuple) and all(
            isinstance(a, (str, type(None))) for a in x))


# ---------------------------------------------------------------------------
# forward: decode step
# ---------------------------------------------------------------------------
def forward_decode(cfg: ModelConfig, params: dict, tokens: jax.Array,
                   cache: list, cache_len: jax.Array):
    """tokens: (B, 1[, K]); cache_len: (B,) valid positions before this token.

    Returns (logits (B, vocab[, K]), new_cache).
    """
    x = _embed(cfg, params, tokens)
    positions = cache_len[:, None]
    new_cache: list = []
    for gi, (pattern, reps) in enumerate(cfg.layer_groups()):
        stacked_p = params[f"group{gi}"]
        stacked_c = cache[gi]

        def body(x, pc, _pattern=pattern):
            layer_p, layer_c = pc
            new_c = {}
            for i, spec in enumerate(_pattern):
                x, c = blocks.block_decode(cfg, spec, layer_p[f"sub{i}"], x,
                                           positions, layer_c[f"sub{i}"],
                                           cache_len)
                new_c[f"sub{i}"] = c
            return x, new_c

        x, nc = jax.lax.scan(body, x, (stacked_p, stacked_c))
        new_cache.append(nc)
    logits = _head(cfg, params, x)
    return logits[:, 0], new_cache


# ---------------------------------------------------------------------------
# forward: incremental prefill chunk against a carried cache
# ---------------------------------------------------------------------------
def forward_chunk(cfg: ModelConfig, params: dict, tokens: jax.Array,
                  cache: list, cache_len: jax.Array):
    """Incremental chunked prefill (DESIGN.md §7): run ``tokens``
    (B, S_chunk[, K]) as the next S_chunk prompt positions after the
    ``cache_len`` (B,) tokens already in ``cache``.

    The multi-token generalization of ``forward_decode``: attention writes
    the chunk's K/V (latents) into the cache at the prefix offset and
    attends causally over prefix + chunk; recurrent mixers resume from the
    cached state.  Each prompt token passes through the model exactly once
    across chunks — O(p) model FLOPs for a p-token prompt, vs O(p²/chunk)
    for prefix recomputation.  All shapes are static given the chunk length,
    so ``jax.jit`` compiles one program per (bucketed) chunk size.

    Per-row ``cache_len`` offsets are supported on the XLA/ref kernel path;
    the engine calls this one slot at a time (B = 1).

    Returns (logits (B, S_chunk, vocab[, K]), new_cache).
    """
    x = _embed(cfg, params, tokens)
    b, s = x.shape[0], x.shape[1]
    positions = cache_len[:, None] + jnp.arange(s, dtype=jnp.int32)[None, :]
    new_cache: list = []
    for gi, (pattern, reps) in enumerate(cfg.layer_groups()):
        stacked_p = params[f"group{gi}"]
        stacked_c = cache[gi]

        def body(x, pc, _pattern=pattern):
            layer_p, layer_c = pc
            new_c = {}
            for i, spec in enumerate(_pattern):
                x, c = blocks.block_chunk(cfg, spec, layer_p[f"sub{i}"], x,
                                          positions, layer_c[f"sub{i}"],
                                          cache_len)
                new_c[f"sub{i}"] = c
            return x, new_c

        x, nc = jax.lax.scan(body, x, (stacked_p, stacked_c))
        new_cache.append(nc)
    logits = _head(cfg, params, x)
    return logits, new_cache


# ---------------------------------------------------------------------------
# forward: token-packed dense-batch step (decode + all prefill chunks fused)
# ---------------------------------------------------------------------------
def forward_packed(cfg: ModelConfig, params: dict, tokens: jax.Array,
                   cache: list, token_slot: jax.Array, token_pos: jax.Array,
                   token_wpos: jax.Array, token_active: jax.Array,
                   kv_bucket: Optional[int] = None, token_dst=None,
                   block_tables=None):
    """One iteration's *entire* model work as a single program (DESIGN.md
    §8): the decode tokens (one per decoding slot) and every scheduled
    prefill chunk are packed into one ``(1, T)`` token stream with per-token
    metadata, generalizing ``forward_chunk`` from one contiguous segment to
    many.

    tokens: (1, T[, K]) packed stream; token_slot: (T,) slot id per token;
    token_pos: (T,) absolute position of the token within its request;
    token_wpos: (T,) cache write position — ``token_pos`` for real tokens,
    ``max_len`` (out of bounds → scatter-dropped) for padding; token_active:
    (T,) False for padding tokens, which then neither write K/V nor commit
    recurrent state.  Under the engine's async pipeline (DESIGN.md §10) the
    stream's decode positions arrive as *device-substituted* values: the
    host writes placeholders and ``sampling.substitute_last`` gathers the
    real tokens from the device-resident ``last_token`` buffer before this
    function runs — the semantics here are unchanged, the values just never
    round-tripped through the host.

    Attention writes each token's K/V (MLA latents) at ``(slot, pos)`` and
    applies a segment-aware mask — a token attends rows ``[0, pos]`` of its
    own slot only, so segments never attend across each other; recurrent
    mixers advance per-slot state through a token scan with active-masking.
    ``kv_bucket`` (static, DESIGN.md §9): upper bound on this iteration's
    ``max(token_pos) + 1`` — attention reads only that many cache rows per
    slot, so its cost scales with actual context.  ``T`` and ``kv_bucket``
    are the only shape parameters, so the engine's jit compile cache is
    bounded by |discrete dense sizes| × |kv buckets|.

    ``token_dst`` ((T,) int32 flat physical rows) and ``block_tables``
    ((N_slots, max_len/block_size) int32) switch attention layers to
    block-table mode (DESIGN.md §12): K/V scatter by physical row, gather
    through per-slot tables — requests then share immutable prefix blocks.
    Both are traced operands of static shape, so the compile-cache bound
    above is unchanged.

    Speculative verify segments (DESIGN.md §13) need no support here at
    all: a slot's k+1 verify positions are just a k+1-token segment, and
    ``token_pos`` / ``token_wpos`` / ``token_dst`` are already traced
    operands — the engine rewrites them on device (true positions from the
    rolled-back ``cache_len`` chain) before calling this function, and the
    segment-causal mask above *is* the draft/verify factorization.

    Returns (logits (1, T, vocab[, K]), new_cache).
    """
    x = _embed(cfg, params, tokens)
    positions = token_pos[None]
    new_cache: list = []
    for gi, (pattern, reps) in enumerate(cfg.layer_groups()):
        stacked_p = params[f"group{gi}"]
        stacked_c = cache[gi]

        def body(x, pc, _pattern=pattern):
            layer_p, layer_c = pc
            new_c = {}
            for i, spec in enumerate(_pattern):
                x, c = blocks.block_packed(cfg, spec, layer_p[f"sub{i}"], x,
                                           positions, layer_c[f"sub{i}"],
                                           token_slot, token_wpos,
                                           token_active, kv_bucket=kv_bucket,
                                           token_dst=token_dst,
                                           block_tables=block_tables)
                new_c[f"sub{i}"] = c
            return x, new_c

        x, nc = jax.lax.scan(body, x, (stacked_p, stacked_c))
        new_cache.append(nc)
    logits = _head(cfg, params, x)
    return logits, new_cache


# ---------------------------------------------------------------------------
# prefill -> cache handoff (dry-run prefill step & engine prefill)
# ---------------------------------------------------------------------------
def prefill(cfg: ModelConfig, params: dict, tokens: jax.Array, *,
            patches: Optional[jax.Array] = None, tp: int = 1,
            max_len: Optional[int] = None):
    """Run the full-chunk path, then scatter per-layer states into a decode
    cache of capacity ``max_len`` (defaults to the prompt length).

    Returns (last_logits (B, ...), cache, cache_len (B,)).
    """
    logits, _aux, states = forward_full(cfg, params, tokens, patches=patches,
                                        return_states=True)
    b = tokens.shape[0]
    s_total = logits.shape[1]
    cap = max_len or s_total
    cache = init_cache(cfg, tp, b, cap)
    new_cache = []
    for gi, (pattern, reps) in enumerate(cfg.layer_groups()):
        group_c = cache[gi]
        group_s = states[gi]
        out_group = {}
        for i, spec in enumerate(pattern):
            out_group[f"sub{i}"] = _state_to_cache(
                cfg, spec, group_c[f"sub{i}"], group_s[f"sub{i}"])
        new_cache.append(out_group)
    cache_len = jnp.full((b,), s_total, jnp.int32)
    return logits[:, -1], new_cache, cache_len


def _state_to_cache(cfg, spec, cache_z, state):
    from repro.configs.base import ATTN
    if spec.mixer == ATTN:
        if cfg.mla is not None:
            c_kv, k_rope = state["kv"]           # (L, B, S, rank/rope)
            ck = _place(cache_z["c_kv"], c_kv)
            kr = _place(cache_z["k_rope"], k_rope)
            return {"c_kv": ck, "k_rope": kr}
        k, v = state["kv"]                        # (L, B, S, KV, hd)
        return {"k": _place(cache_z["k"], k), "v": _place(cache_z["v"], v)}
    return state                                  # recurrent: state IS cache


def _place(zeros: jax.Array, filled: jax.Array) -> jax.Array:
    """Write prompt-length tensors into the zero cache prefix (seq offset 0)."""
    return jax.lax.dynamic_update_slice(
        zeros, filled.astype(zeros.dtype), (0,) * zeros.ndim)


# ---------------------------------------------------------------------------
# input specs (dry-run stand-ins; ShapeDtypeStruct only)
# ---------------------------------------------------------------------------
def input_specs(cfg: ModelConfig, shape: ShapeConfig, *, mesh=None, rules=None,
                tp: int = 1) -> dict:
    """ShapeDtypeStructs for every model input of the given shape cell."""
    from jax.sharding import NamedSharding
    from repro.distributed.sharding import logical_to_pspec

    b, s = shape.global_batch, shape.seq_len

    def sds(shp, axes, dtype=jnp.int32):
        sharding = None
        if mesh is not None:
            sharding = NamedSharding(mesh, logical_to_pspec(axes, mesh, rules))
        return jax.ShapeDtypeStruct(shp, dtype, sharding=sharding)

    if shape.step in ("train", "prefill"):
        if cfg.frontend == "vision":
            n_patch = min(cfg.num_patch_tokens, s // 4)
            s_text = s - n_patch
            specs = {
                "tokens": sds((b, s_text), ("batch", "act_seq")),
                "patches": sds((b, n_patch, cfg.d_model),
                               ("batch", "act_seq", "embed"),
                               jnp.dtype(cfg.dtype)),
            }
            if shape.step == "train":
                specs["labels"] = sds((b, s_text), ("batch", "act_seq"))
            return specs
        if cfg.frontend == "audio":
            specs = {"tokens": sds((b, s, cfg.num_codebooks),
                                   ("batch", "act_seq", None))}
            if shape.step == "train":
                specs["labels"] = sds((b, s, cfg.num_codebooks),
                                      ("batch", "act_seq", None))
            return specs
        specs = {"tokens": sds((b, s), ("batch", "act_seq"))}
        if shape.step == "train":
            specs["labels"] = sds((b, s), ("batch", "act_seq"))
        return specs

    # decode: one new token against a seq_len cache
    tok_shape = (b, 1, cfg.num_codebooks) if cfg.frontend == "audio" else (b, 1)
    tok_axes = ("batch", "act_seq", None) if cfg.frontend == "audio" \
        else ("batch", "act_seq")
    return {
        "tokens": sds(tok_shape, tok_axes),
        "cache": cache_shapes(cfg, tp, b, s, mesh, rules),
        "cache_len": sds((b,), ("batch",)),
    }
