"""Shared layer primitives: RMSNorm, RoPE, activation, TP-aware projections."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.distributed.sharding import shard


def rmsnorm(x: jax.Array, weight: jax.Array, eps: float = 1e-5) -> jax.Array:
    dt = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    return (x32 * jax.lax.rsqrt(var + eps)).astype(dt) * weight


def silu(x: jax.Array) -> jax.Array:
    return x * jax.nn.sigmoid(x)


def softplus(x: jax.Array) -> jax.Array:
    return jax.nn.softplus(x)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """(head_dim/2,) inverse frequencies, f32."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def _rope_rotate(x: jax.Array, angles: jax.Array) -> jax.Array:
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1 = x[..., 0::2].astype(jnp.float32)
    x2 = x[..., 1::2].astype(jnp.float32)
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    out = jnp.stack([r1, r2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


def _angles(positions: jax.Array, seq: int, hd: int, theta: float) -> jax.Array:
    """positions: (S,) or (B, S) -> angles (S, hd/2) or (B, S, hd/2)."""
    freqs = rope_freqs(hd, theta)
    return positions.astype(jnp.float32)[..., None] * freqs


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, D) — explicit head axis.  positions: (S,) or (B, S)."""
    assert x.ndim == 4, x.shape
    ang = _angles(positions, x.shape[1], x.shape[-1], theta)
    ang = ang[..., None, :]                 # broadcast over heads
    if ang.ndim == 3:                       # positions were (S,)
        ang = ang[None]
    return _rope_rotate(x, ang)


def apply_rope_nohead(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, D) — no head axis (MLA decoupled key)."""
    assert x.ndim == 3, x.shape
    ang = _angles(positions, x.shape[1], x.shape[-1], theta)
    if ang.ndim == 2:
        ang = ang[None]
    return _rope_rotate(x, ang)


# ---------------------------------------------------------------------------
# causal depthwise conv (mamba / xlstm) — supports streaming decode
# ---------------------------------------------------------------------------
def causal_conv1d(x: jax.Array, w: jax.Array, b: Optional[jax.Array]) -> jax.Array:
    """x: (B, S, C); w: (C, K) depthwise taps; left-pads with zeros."""
    k = w.shape[-1]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    # sum_k w[:, k] * x[t - (K-1) + k]
    out = jnp.zeros_like(x)
    for i in range(k):
        out = out + pad[:, i : i + x.shape[1], :] * w[:, i]
    if b is not None:
        out = out + b
    return out


def causal_conv1d_step(x_t: jax.Array, conv_state: jax.Array, w: jax.Array,
                       b: Optional[jax.Array]) -> tuple[jax.Array, jax.Array]:
    """One decode step.  x_t: (B, C); conv_state: (B, K-1, C) past inputs."""
    window = jnp.concatenate([conv_state, x_t[:, None, :]], axis=1)  # (B,K,C)
    out = jnp.einsum("bkc,ck->bc", window, w)
    if b is not None:
        out = out + b
    return out, window[:, 1:, :]


def conv_tail(xs: jax.Array, kk: int) -> jax.Array:
    """Conv history carry for chunked prefill: last ``kk`` rows of (B, S, C),
    left-zero-padded when S < kk.  Callers pass (prev history ++ chunk) so
    chunks shorter than the kernel keep earlier history."""
    if xs.shape[1] >= kk:
        return xs[:, xs.shape[1] - kk:, :]
    return jnp.pad(xs, ((0, 0), (kk - xs.shape[1], 0), (0, 0)))


# ---------------------------------------------------------------------------
# losses / heads
# ---------------------------------------------------------------------------
def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: Optional[jax.Array] = None) -> jax.Array:
    """Mean token-level xent.  logits (..., V) f32-upcast; labels (...) int."""
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


__all__ = [
    "rmsnorm", "silu", "softplus", "rope_freqs", "apply_rope",
    "apply_rope_nohead", "causal_conv1d", "causal_conv1d_step",
    "cross_entropy", "shard",
]
