"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory).

[arXiv:2405.04517].  TP strategy (DESIGN.md §4): the mLSTM value dim is
sharded over the model axis (its matrix memory C is (dqk × dv) per head —
sharding dv shards both the state and the einsums); q/k are computed
replicated.  sLSTM state is tiny (vectors of d_model) — its recurrence runs
replicated and only the post-FFN projections are TP-sharded.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, XLSTMConfig
from repro.distributed import tp
from repro.kernels.ref import mlstm_chunk_ref, mlstm_step_ref
from repro.models.layers import (causal_conv1d, causal_conv1d_step, conv_tail,
                                 rmsnorm, shard, silu)
from repro.models.param import ParamDef


def _xc(cfg: ModelConfig) -> XLSTMConfig:
    return cfg.xlstm or XLSTMConfig()


def _mlstm_dims(cfg: ModelConfig) -> tuple[int, int, int]:
    d_in = int(_xc(cfg).proj_factor * cfg.d_model)
    h = cfg.n_heads
    return d_in, h, d_in // h


# ---------------------------------------------------------------------------
# mLSTM
# ---------------------------------------------------------------------------
def mlstm_defs(cfg: ModelConfig, tp: int) -> dict:
    d, dt = cfg.d_model, cfg.dtype
    xc = _xc(cfg)
    d_in, h, dh = _mlstm_dims(cfg)
    return {
        "w_up": ParamDef((d, 2 * d_in), ("w_embed", "inner"), dtype=dt),
        "conv_w": ParamDef((d_in, xc.conv_kernel), ("inner", None), dtype=dt),
        "conv_b": ParamDef((d_in,), ("inner",), init="zeros", dtype=dt),
        # per-head block-diagonal q/k/v (xLSTM paper; 1/H the dense params)
        "w_q": ParamDef((h, dh, dh), (None, None, None), dtype=dt,
                        fan_in_axes=(1,)),
        "w_k": ParamDef((h, dh, dh), (None, None, None), dtype=dt,
                        fan_in_axes=(1,)),
        "w_v": ParamDef((h, dh, dh), (None, None, "dv"), dtype=dt,
                        fan_in_axes=(1,)),
        "w_i": ParamDef((d_in, h), (None, None), init="small", dtype="float32"),
        "w_f": ParamDef((d_in, h), (None, None), init="small", dtype="float32"),
        "b_i": ParamDef((h,), (None,), init="zeros", dtype="float32"),
        "b_f": ParamDef((h,), (None,), init="ones", dtype="float32"),
        "out_norm": ParamDef((d_in,), ("inner",), init="ones", dtype=dt),
        "w_down": ParamDef((d_in, d), ("inner", "w_embed"), dtype=dt),
    }


def _mlstm_pre(cfg: ModelConfig, p: dict, x: jax.Array,
               conv_hist: Optional[jax.Array] = None):
    """x: (B,S,D) -> q,k,v (B,S,H,dh), gates (B,S,H), z, conv-input stream
    (history ++ chunk — the source for the next chunk's conv tail)."""
    d_in, h, dh = _mlstm_dims(cfg)
    xz = jnp.einsum("bsd,dk->bsk", x, p["w_up"])
    xs, z = jnp.split(xz, 2, axis=-1)
    if conv_hist is not None:
        ext = jnp.concatenate([conv_hist, xs], axis=1)
        xc = causal_conv1d(ext, p["conv_w"], p["conv_b"])[:, conv_hist.shape[1]:]
    else:
        ext = xs
        xc = causal_conv1d(xs, p["conv_w"], p["conv_b"])
    xc = silu(xc)
    b, s, _ = x.shape
    xch = xc.reshape(b, s, h, dh)
    xsh = xs.reshape(b, s, h, dh)
    q = jnp.einsum("bshk,hkj->bshj", xch, p["w_q"])
    k = jnp.einsum("bshk,hkj->bshj", xch, p["w_k"])
    v = jnp.einsum("bshk,hkj->bshj", xsh, p["w_v"])
    v = shard(v, "batch", "act_seq", None, "act_dv")
    ig = jnp.einsum("bsk,kh->bsh", xc.astype(jnp.float32), p["w_i"]) + p["b_i"]
    fg = jax.nn.log_sigmoid(
        jnp.einsum("bsk,kh->bsh", xc.astype(jnp.float32), p["w_f"]) + p["b_f"])
    return q, k, v, ig, fg, z, ext


def mlstm_chunkwise(q: jax.Array, k: jax.Array, v: jax.Array,
                    i_gate: jax.Array, f_gate: jax.Array, *,
                    chunk: int = 64, initial: Optional[tuple] = None):
    """Chunkwise-parallel stabilized mLSTM — bit-compatible with the
    sequential reference (kernels/ref.py:mlstm_chunk_ref; tested to 1e-4).

    Why: the per-step scan carries the (dqk × dv) matrix memory through HBM
    every token and saves every carry for backward (the xlstm train_4k cell
    showed 1 TB/device temp).  The chunkwise form computes intra-chunk
    interactions as causal (L×L) matmuls (MXU-shaped) and carries state only
    per chunk: state traffic and saved residuals drop by the chunk length.

    Derivation (per head; b_j = Σ_{k≤j} f_k inclusive, m_0/C_0/n_0 carried):
      m_j   = b_j + G_j,          G_j = max(m_0, cummax_k≤j(i_k - b_k))
      num_j = Σ_{k≤j} e^{i_k-b_k-G_j}(q_j·k_k)v_k + e^{m_0-G_j}(q_j·C_0)
      n_j   = Σ_{k≤j} e^{i_k-b_k-G_j}k_k        + e^{m_0-G_j}n_0
      y_j   = num_j / max(|q_j·n_j|, e^{-m_j})
      C_L   = Σ_k e^{i_k-b_k-G_L}k_k v_kᵀ + e^{m_0-G_L}C_0   (chunk end)
    All exponents are ≤ 0, so everything is overflow-safe.
    """
    bsz, s, h, dqk = q.shape
    dv = v.shape[-1]
    scale = dqk ** -0.5
    if initial is None:
        c0 = jnp.zeros((bsz, h, dqk, dv), jnp.float32)
        n0 = jnp.zeros((bsz, h, dqk), jnp.float32)
        m0 = jnp.full((bsz, h), -1e30, jnp.float32)
    else:
        c0, n0, m0 = initial
        m0 = jnp.maximum(m0, -1e30)          # ref uses -inf; keep finite

    l = min(chunk, s)
    pad = (-s) % l
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        i_gate = jnp.pad(i_gate, ((0, 0), (0, pad), (0, 0)),
                         constant_values=-1e30)     # no input on padding
        f_gate = jnp.pad(f_gate, ((0, 0), (0, pad), (0, 0)))
    nc = (s + pad) // l

    def chk(x_, d):
        return x_.reshape(bsz, nc, l, h, d).transpose(1, 0, 3, 2, 4)

    qs = chk(q.astype(jnp.float32) * scale, dqk)     # (nc,B,H,L,dqk)
    ks = chk(k.astype(jnp.float32), dqk)
    vs = chk(v.astype(jnp.float32), dv)
    ig = i_gate.astype(jnp.float32).reshape(bsz, nc, l, h).transpose(1, 0, 3, 2)
    fg = f_gate.astype(jnp.float32).reshape(bsz, nc, l, h).transpose(1, 0, 3, 2)

    def body(carry, inp):
        c, n, m = carry                               # (B,H,dqk,dv) ...
        qc, kc, vc, ic, fc = inp                      # (B,H,L,*)
        b = jnp.cumsum(fc, axis=-1)                   # (B,H,L) inclusive
        a = ic - b                                    # i_k - b_k
        g = jnp.maximum(m[..., None], jax.lax.cummax(a, axis=2))  # (B,H,L)
        m_j = b + g
        w = jnp.exp(a[..., None, :] - g[..., :, None])            # (B,H,L_j,L_k)
        mask = jnp.tril(jnp.ones((l, l), bool))
        w = jnp.where(mask, w, 0.0)
        scores = jnp.einsum("bhjd,bhkd->bhjk", qc, kc)
        num = jnp.einsum("bhjk,bhkv->bhjv", scores * w, vc) \
            + jnp.exp(m[..., None] - g)[..., None] * \
            jnp.einsum("bhjd,bhdv->bhjv", qc, c)
        nvec = jnp.einsum("bhjk,bhkd->bhjd", w, kc) \
            + jnp.exp(m[..., None] - g)[..., None] * n[..., None, :]
        den = jnp.maximum(jnp.abs(jnp.einsum("bhjd,bhjd->bhj", qc, nvec)),
                          jnp.exp(-m_j))[..., None]
        y = num / den                                  # (B,H,L,dv)
        # chunk-end state
        g_l = g[..., -1]
        w_l = jnp.exp(a - g_l[..., None])              # (B,H,L)
        c = jnp.exp(m - g_l)[..., None, None] * c + \
            jnp.einsum("bhk,bhkd,bhkv->bhdv", w_l, kc, vc)
        n = jnp.exp(m - g_l)[..., None] * n + \
            jnp.einsum("bhk,bhkd->bhd", w_l, kc)
        return (c, n, b[..., -1] + g_l), y

    (c_f, n_f, m_f), ys = jax.lax.scan(body, (c0, n0, m0), (qs, ks, vs, ig, fg))
    y = ys.transpose(1, 0, 3, 2, 4).reshape(bsz, nc * l, h, dv)[:, :s]
    return y.astype(q.dtype), (c_f, n_f, m_f)


# mLSTM sequence-mix implementation: "scan" (faithful straightforward
# baseline — per-step recurrence) or "chunkwise" (the §Perf optimization:
# MXU-shaped intra-chunk matmuls, per-chunk state carry).  Env override for
# the dry-run A/B; see EXPERIMENTS.md §Perf HC1.
def _mlstm_impl() -> str:
    import os
    return os.environ.get("REPRO_MLSTM", "scan")


def mlstm_full(cfg: ModelConfig, p: dict, x: jax.Array,
               initial: Optional[dict] = None, return_state: bool = False):
    xc = _xc(cfg)
    d_in, h, dh = _mlstm_dims(cfg)
    hist = initial["conv"] if initial is not None else None
    st0 = (initial["c"], initial["n"], initial["m"]) if initial is not None else None
    q, k, v, ig, fg, z, conv_src = _mlstm_pre(cfg, p, x, hist)
    if _mlstm_impl() == "chunkwise" and x.shape[1] >= 8:
        y, state = mlstm_chunkwise(q, k, v, ig, fg, initial=st0)
    else:
        y, state = mlstm_chunk_ref(q, k, v, ig, fg, initial=st0)
    b, s = x.shape[0], x.shape[1]
    y = y.reshape(b, s, d_in)
    y = rmsnorm(y, p["out_norm"], cfg.norm_eps) * silu(z)
    y = shard(y, "batch", "act_seq", "act_inner")
    out = jnp.einsum("bsk,kd->bsd", y, p["w_down"])
    out = shard(out, "batch", "act_seq", "embed")
    if return_state:
        # conv_src is (prev history ++ chunk) — the stream the conv actually
        # consumed — so short chunks keep earlier history in the tail
        c_f, n_f, m_f = state
        return out, {"conv": conv_tail(conv_src, xc.conv_kernel - 1),
                     "c": c_f, "n": n_f, "m": m_f}
    return out


def mlstm_decode(cfg: ModelConfig, p: dict, x: jax.Array, cache: dict):
    d_in, h, dh = _mlstm_dims(cfg)
    xz = jnp.einsum("bd,dk->bk", x[:, 0], p["w_up"])
    xs, z = jnp.split(xz, 2, axis=-1)
    xc_t, conv_state = causal_conv1d_step(xs, cache["conv"], p["conv_w"],
                                          p["conv_b"])
    xc_t = silu(xc_t)
    b = x.shape[0]
    xch = xc_t.reshape(b, h, dh)
    xsh = xs.reshape(b, h, dh)
    q = jnp.einsum("bhk,hkj->bhj", xch, p["w_q"])
    k = jnp.einsum("bhk,hkj->bhj", xch, p["w_k"])
    v = jnp.einsum("bhk,hkj->bhj", xsh, p["w_v"])
    ig = jnp.einsum("bk,kh->bh", xc_t.astype(jnp.float32), p["w_i"]) + p["b_i"]
    fg = jax.nn.log_sigmoid(
        jnp.einsum("bk,kh->bh", xc_t.astype(jnp.float32), p["w_f"]) + p["b_f"])
    y, (c, n, m) = mlstm_step_ref(q, k, v, ig, fg,
                                  (cache["c"], cache["n"], cache["m"]))
    y = y.reshape(b, d_in)
    y = rmsnorm(y, p["out_norm"], cfg.norm_eps) * silu(z)
    out = jnp.einsum("bk,kd->bd", y, p["w_down"])[:, None, :]
    out = shard(out, "batch", "act_seq", "embed")
    return out, {"conv": conv_state, "c": c, "n": n, "m": m}


def mlstm_packed(cfg: ModelConfig, p: dict, x: jax.Array, cache: dict,
                 token_slot: jax.Array, token_active: jax.Array):
    """Token-packed dense-batch step (DESIGN.md §8): the up-projection runs
    dense over the (1, T) packed stream; the recurrent part is one
    ``lax.scan`` over tokens that gathers the token's slot state
    (conv tail + matrix memory (C, n, m)), advances it one step, and
    scatters it back — active-masked so padding never commits state.

    Under tensor parallelism (DESIGN.md §11) the block is sharded along
    *heads* (= contiguous ``d_in`` channel blocks): conv, per-head q/k/v
    and the (C, n, m) memory are local; the i/f gates contract over the
    full ``d_in`` so their projection is row-parallel (psum, then slice
    back to the local heads); the out-norm reduces over the full width via
    ``tp.rmsnorm_sharded``; ``w_down`` is row-parallel."""
    d_in, h, dh = _mlstm_dims(cfg)
    ws = tp.world()
    d_in_l, h_l = d_in // ws, h // ws        # local widths (== global at tp=1)
    xz = jnp.einsum("bsd,dk->bsk", x, p["w_up"])         # (1, T, 2*d_in_l)
    xs, z = jnp.split(xz, 2, axis=-1)

    def step(carry, inp):
        conv_c, c_c, n_c, m_c = carry
        xs_t, s_i, act = inp
        hist = jax.lax.dynamic_index_in_dim(conv_c, s_i, 0)
        c0 = jax.lax.dynamic_index_in_dim(c_c, s_i, 0)
        n0 = jax.lax.dynamic_index_in_dim(n_c, s_i, 0)
        m0 = jax.lax.dynamic_index_in_dim(m_c, s_i, 0)
        xc_t, new_hist = causal_conv1d_step(xs_t[None], hist, p["conv_w"],
                                            p["conv_b"])
        xc_t = silu(xc_t)                                # (1, d_in_l)
        xch = xc_t.reshape(1, h_l, dh)
        xsh = xs_t[None].reshape(1, h_l, dh)
        q = jnp.einsum("bhk,hkj->bhj", xch, p["w_q"])
        k = jnp.einsum("bhk,hkj->bhj", xch, p["w_k"])
        v = jnp.einsum("bhk,hkj->bhj", xsh, p["w_v"])
        # gates see the full inner width: row-parallel partial -> psum to
        # the replicated (1, h) gates, then slice the local head block
        ig = tp.shard_block(
            tp.psum(jnp.einsum("bk,kh->bh", xc_t.astype(jnp.float32),
                               p["w_i"])) + p["b_i"])
        fg = jax.nn.log_sigmoid(tp.shard_block(
            tp.psum(jnp.einsum("bk,kh->bh", xc_t.astype(jnp.float32),
                               p["w_f"])) + p["b_f"]))
        y_t, (c1, n1, m1) = mlstm_step_ref(q, k, v, ig, fg, (c0, n0, m0))
        conv_c = jax.lax.dynamic_update_index_in_dim(
            conv_c, jnp.where(act, new_hist, hist).astype(conv_c.dtype),
            s_i, 0)
        c_c = jax.lax.dynamic_update_index_in_dim(
            c_c, jnp.where(act, c1, c0), s_i, 0)
        n_c = jax.lax.dynamic_update_index_in_dim(
            n_c, jnp.where(act, n1, n0), s_i, 0)
        m_c = jax.lax.dynamic_update_index_in_dim(
            m_c, jnp.where(act, m1, m0), s_i, 0)
        return (conv_c, c_c, n_c, m_c), y_t.reshape(d_in_l)

    (conv_f, c_f, n_f, m_f), ys = jax.lax.scan(
        step, (cache["conv"], cache["c"], cache["n"], cache["m"]),
        (xs[0], token_slot, token_active))
    y = tp.rmsnorm_sharded(ys[None].astype(x.dtype), p["out_norm"],
                           cfg.norm_eps) * silu(z)
    y = shard(y, "batch", "act_seq", "act_inner")
    out = tp.row_parallel(y, p["w_down"])
    out = shard(out, "batch", "act_seq", "embed")
    return out, {"conv": conv_f, "c": c_f, "n": n_f, "m": m_f}


def mlstm_init_cache(cfg: ModelConfig, tp: int, batch: int) -> dict:
    xc = _xc(cfg)
    d_in, h, dh = _mlstm_dims(cfg)
    dt = jnp.dtype(cfg.dtype)
    return {"conv": jnp.zeros((batch, xc.conv_kernel - 1, d_in), dt),
            "c": jnp.zeros((batch, h, dh, dh), jnp.float32),
            "n": jnp.zeros((batch, h, dh), jnp.float32),
            "m": jnp.full((batch, h), -1e30, jnp.float32)}


def mlstm_cache_axes() -> dict:
    return {"conv": ("batch", None, "act_inner"),
            "c": ("batch", None, None, "act_dv"),
            "n": ("batch", None, None),
            "m": ("batch", None)}


# ---------------------------------------------------------------------------
# sLSTM
# ---------------------------------------------------------------------------
def slstm_defs(cfg: ModelConfig, tp: int) -> dict:
    d, dt = cfg.d_model, cfg.dtype
    xc = _xc(cfg)
    h = cfg.n_heads
    dh = d // h
    f_d = 2 * d                                  # post-FFN width (GLU)
    return {
        "conv_w": ParamDef((d, xc.slstm_conv_kernel), ("w_embed", None), dtype=dt),
        "conv_b": ParamDef((d,), ("w_embed",), init="zeros", dtype=dt),
        "w_gates": ParamDef((d, 4 * d), ("w_embed", None), dtype=dt),
        "r_gates": ParamDef((h, dh, 4 * dh), (None, None, None), dtype=dt,
                            fan_in_axes=(1,)),
        "b_gates": ParamDef((4 * d,), (None,), init="zeros", dtype="float32"),
        "out_norm": ParamDef((d,), ("w_embed",), init="ones", dtype=dt),
        "w_ffn_up": ParamDef((d, f_d), ("w_embed", "ff"), dtype=dt),
        "w_ffn_down": ParamDef((f_d // 2, d), ("ff", "w_embed"), dtype=dt),
    }


def _slstm_scan(cfg: ModelConfig, p: dict, xg: jax.Array, state: tuple):
    """xg: (B, S, 4D) gate preactivations (input part).  Sequential scan with
    block-diagonal recurrence.  Stabilized per xLSTM appendix."""
    b, s, _ = xg.shape
    d = cfg.d_model
    h = cfg.n_heads
    dh = d // h

    def step(carry, g_t):
        c, n, hprev, m = carry                   # (B,D) each
        hh = hprev.reshape(b, h, dh)
        rec = jnp.einsum("bhd,hdk->bhk", hh, p["r_gates"]).reshape(b, 4 * d)
        pre = (g_t + rec).astype(jnp.float32) + p["b_gates"]
        i_p, f_p, z_p, o_p = jnp.split(pre, 4, axis=-1)
        m_new = jnp.maximum(f_p + m, i_p)
        i = jnp.exp(i_p - m_new)
        f = jnp.exp(f_p + m - m_new)
        z = jnp.tanh(z_p)
        o = jax.nn.sigmoid(o_p)
        c = f * c + i * z
        n = f * n + i
        h_new = (o * c / jnp.maximum(n, 1e-6)).astype(xg.dtype)
        return (c, n, h_new, m_new), h_new

    carry, ys = jax.lax.scan(step, state, jnp.moveaxis(xg, 1, 0))
    return jnp.moveaxis(ys, 0, 1), carry


def slstm_full(cfg: ModelConfig, p: dict, x: jax.Array,
               initial: Optional[dict] = None, return_state: bool = False):
    xc = _xc(cfg)
    if initial is not None:
        ext = jnp.concatenate([initial["conv"], x], axis=1)
        xconv = causal_conv1d(ext, p["conv_w"], p["conv_b"])[:, initial["conv"].shape[1]:]
        state = (initial["c"], initial["n"], initial["h"], initial["m"])
    else:
        ext = x
        xconv = causal_conv1d(x, p["conv_w"], p["conv_b"])
        b, d = x.shape[0], cfg.d_model
        state = (jnp.zeros((b, d), jnp.float32), jnp.zeros((b, d), jnp.float32),
                 jnp.zeros((b, d), x.dtype), jnp.full((b, d), -1e30, jnp.float32))
    xconv = silu(xconv)
    # i,f gates see the conv features; z,o see the raw input (xLSTM paper)
    gi = jnp.einsum("bsd,dk->bsk", xconv, p["w_gates"][:, : 2 * cfg.d_model])
    gz = jnp.einsum("bsd,dk->bsk", x, p["w_gates"][:, 2 * cfg.d_model:])
    xg = jnp.concatenate([gi, gz], axis=-1)
    ys, carry = _slstm_scan(cfg, p, xg, state)
    y = rmsnorm(ys, p["out_norm"], cfg.norm_eps)
    # post up/down GLU FFN
    up = jnp.einsum("bsd,df->bsf", y, p["w_ffn_up"])
    u, g = jnp.split(up, 2, axis=-1)
    yf = shard(u * silu(g), "batch", "act_seq", "act_ff")
    out = jnp.einsum("bsf,fd->bsd", yf, p["w_ffn_down"])
    out = shard(out, "batch", "act_seq", "embed")
    if return_state:
        # ext is (prev history ++ chunk) — the stream the conv actually
        # consumed — so short chunks keep earlier history in the tail
        c, n, hs, m = carry
        return out, {"conv": conv_tail(ext, xc.slstm_conv_kernel - 1),
                     "c": c, "n": n, "h": hs, "m": m}
    return out


def slstm_decode(cfg: ModelConfig, p: dict, x: jax.Array, cache: dict):
    out, st = _slstm_step_impl(cfg, p, x, cache)
    return out, st


def _slstm_step_impl(cfg: ModelConfig, p: dict, x: jax.Array, cache: dict):
    xt = x[:, 0]
    xc_t, conv_state = causal_conv1d_step(xt, cache["conv"], p["conv_w"],
                                          p["conv_b"])
    xc_t = silu(xc_t)
    gi = jnp.einsum("bd,dk->bk", xc_t, p["w_gates"][:, : 2 * cfg.d_model])
    gz = jnp.einsum("bd,dk->bk", xt, p["w_gates"][:, 2 * cfg.d_model:])
    xg = jnp.concatenate([gi, gz], axis=-1)[:, None, :]
    state = (cache["c"], cache["n"], cache["h"], cache["m"])
    ys, (c, n, hs, m) = _slstm_scan(cfg, p, xg, state)
    y = rmsnorm(ys[:, 0], p["out_norm"], cfg.norm_eps)
    up = jnp.einsum("bd,df->bf", y, p["w_ffn_up"])
    u, g = jnp.split(up, 2, axis=-1)
    out = jnp.einsum("bf,fd->bd", u * silu(g), p["w_ffn_down"])[:, None, :]
    return shard(out, "batch", "act_seq", "embed"), {
        "conv": conv_state, "c": c, "n": n, "h": hs, "m": m}


def slstm_packed(cfg: ModelConfig, p: dict, x: jax.Array, cache: dict,
                 token_slot: jax.Array, token_active: jax.Array):
    """Token-packed dense-batch step (DESIGN.md §8): per-token slot-state
    scan for the sequential sLSTM recurrence (gather state, one step,
    active-masked scatter back); the post-recurrence norm + GLU FFN run
    dense over the packed stream.

    Under tensor parallelism (DESIGN.md §11 / §4) the tiny scalar
    recurrence runs *replicated* on every shard; only the GLU FFN is
    column/row-parallel (``w_ffn_up`` columns re-interleaved so each shard
    holds matching u‖g halves; ``w_ffn_down`` all-reduced)."""
    d = cfg.d_model

    def step(carry, inp):
        conv_c, c_c, n_c, h_c, m_c = carry
        x_t, s_i, act = inp                              # (D,), i32, bool
        hist = jax.lax.dynamic_index_in_dim(conv_c, s_i, 0)
        c0 = jax.lax.dynamic_index_in_dim(c_c, s_i, 0)
        n0 = jax.lax.dynamic_index_in_dim(n_c, s_i, 0)
        h0 = jax.lax.dynamic_index_in_dim(h_c, s_i, 0)
        m0 = jax.lax.dynamic_index_in_dim(m_c, s_i, 0)
        xc_t, new_hist = causal_conv1d_step(x_t[None], hist, p["conv_w"],
                                            p["conv_b"])
        xc_t = silu(xc_t)
        gi = jnp.einsum("bd,dk->bk", xc_t, p["w_gates"][:, : 2 * d])
        gz = jnp.einsum("bd,dk->bk", x_t[None], p["w_gates"][:, 2 * d:])
        xg = jnp.concatenate([gi, gz], axis=-1)[:, None, :]
        ys, (c1, n1, h1, m1) = _slstm_scan(cfg, p, xg, (c0, n0, h0, m0))
        conv_c = jax.lax.dynamic_update_index_in_dim(
            conv_c, jnp.where(act, new_hist, hist).astype(conv_c.dtype),
            s_i, 0)
        c_c = jax.lax.dynamic_update_index_in_dim(
            c_c, jnp.where(act, c1, c0), s_i, 0)
        n_c = jax.lax.dynamic_update_index_in_dim(
            n_c, jnp.where(act, n1, n0), s_i, 0)
        h_c = jax.lax.dynamic_update_index_in_dim(
            h_c, jnp.where(act, h1, h0), s_i, 0)
        m_c = jax.lax.dynamic_update_index_in_dim(
            m_c, jnp.where(act, m1, m0), s_i, 0)
        return (conv_c, c_c, n_c, h_c, m_c), ys[0, 0]

    carry0 = (cache["conv"], cache["c"], cache["n"], cache["h"], cache["m"])
    (conv_f, c_f, n_f, h_f, m_f), ys = jax.lax.scan(
        step, carry0, (x[0], token_slot, token_active))
    y = rmsnorm(ys[None], p["out_norm"], cfg.norm_eps)   # (1, T, D)
    up = jnp.einsum("bsd,df->bsf", y, p["w_ffn_up"])
    u, g = jnp.split(up, 2, axis=-1)
    yf = shard(u * silu(g), "batch", "act_seq", "act_ff")
    out = tp.row_parallel(yf, p["w_ffn_down"])
    out = shard(out, "batch", "act_seq", "embed")
    return out, {"conv": conv_f, "c": c_f, "n": n_f, "h": h_f, "m": m_f}


def slstm_init_cache(cfg: ModelConfig, tp: int, batch: int) -> dict:
    xc = _xc(cfg)
    d = cfg.d_model
    dt = jnp.dtype(cfg.dtype)
    return {"conv": jnp.zeros((batch, xc.slstm_conv_kernel - 1, d), dt),
            "c": jnp.zeros((batch, d), jnp.float32),
            "n": jnp.zeros((batch, d), jnp.float32),
            "h": jnp.zeros((batch, d), dt),
            "m": jnp.full((batch, d), -1e30, jnp.float32)}


def slstm_cache_axes() -> dict:
    return {"conv": ("batch", None, "embed"), "c": ("batch", None),
            "n": ("batch", None), "h": ("batch", None), "m": ("batch", None)}
