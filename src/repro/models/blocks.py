"""Per-layer block dispatch: LayerSpec -> param defs / forward / cache."""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import (ATTN, FFN_DENSE, FFN_MOE, FFN_MOE_DENSE,
                                FFN_NONE, MAMBA, MLSTM, SLSTM, LayerSpec,
                                ModelConfig)
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models import xlstm as xlstm_mod
from repro.models.layers import rmsnorm
from repro.models.param import ParamDef


def block_defs(cfg: ModelConfig, spec: LayerSpec, tp: int) -> dict:
    d, dt = cfg.d_model, cfg.dtype
    defs: dict = {"norm1": ParamDef((d,), ("w_embed",), init="ones", dtype=dt)}
    if spec.mixer == ATTN:
        defs["mixer"] = (attn.mla_defs(cfg, tp) if cfg.mla is not None
                         else attn.gqa_defs(cfg, tp))
    elif spec.mixer == MAMBA:
        defs["mixer"] = ssm_mod.mamba_defs(cfg, tp)
    elif spec.mixer == MLSTM:
        defs["mixer"] = xlstm_mod.mlstm_defs(cfg, tp)
    elif spec.mixer == SLSTM:
        defs["mixer"] = xlstm_mod.slstm_defs(cfg, tp)
    else:
        raise ValueError(spec.mixer)
    if spec.ffn != FFN_NONE:
        defs["norm2"] = ParamDef((d,), ("w_embed",), init="ones", dtype=dt)
        if spec.ffn == FFN_DENSE:
            defs["ffn"] = moe_mod._ffn_defs(d, cfg.d_ff, dt, cfg.ffn_gated)
        elif spec.ffn == FFN_MOE:
            defs["ffn"] = moe_mod.moe_defs(cfg)
        elif spec.ffn == FFN_MOE_DENSE:
            defs["ffn"] = moe_mod.moe_defs(cfg, dense_residual=True)
        else:
            raise ValueError(spec.ffn)
    return defs


def _ffn_apply(cfg: ModelConfig, spec: LayerSpec, p: dict, x: jax.Array):
    if spec.ffn == FFN_NONE:
        return x, jnp.zeros((), jnp.float32)
    h = rmsnorm(x, p["norm2"], cfg.norm_eps)
    if spec.ffn == FFN_DENSE:
        return x + moe_mod.dense_ffn(p["ffn"], h), jnp.zeros((), jnp.float32)
    y, aux = moe_mod.moe_ffn(cfg, p["ffn"], h,
                             dense_residual=(spec.ffn == FFN_MOE_DENSE))
    return x + y, aux


def block_full(cfg: ModelConfig, spec: LayerSpec, p: dict, x: jax.Array,
               positions: jax.Array, *, q_offset=0,
               initial: Optional[dict] = None, return_state: bool = False):
    """Train/prefill.  Returns (x, aux_loss[, state])."""
    h = rmsnorm(x, p["norm1"], cfg.norm_eps)
    state = None
    if spec.mixer == ATTN:
        kv_prefix = initial.get("kv_prefix") if initial else None
        fn = attn.mla_full if cfg.mla is not None else attn.gqa_full
        if return_state:
            y, kv = fn(cfg, p["mixer"], h, positions, q_offset=q_offset,
                       kv_prefix=kv_prefix, return_kv=True)
            state = {"kv": kv}
        else:
            y = fn(cfg, p["mixer"], h, positions, q_offset=q_offset,
                   kv_prefix=kv_prefix)
    elif spec.mixer == MAMBA:
        r = ssm_mod.mamba_full(cfg, p["mixer"], h, initial=initial,
                               return_state=return_state)
        y, state = r if return_state else (r, None)
    elif spec.mixer == MLSTM:
        r = xlstm_mod.mlstm_full(cfg, p["mixer"], h, initial=initial,
                                 return_state=return_state)
        y, state = r if return_state else (r, None)
    elif spec.mixer == SLSTM:
        r = xlstm_mod.slstm_full(cfg, p["mixer"], h, initial=initial,
                                 return_state=return_state)
        y, state = r if return_state else (r, None)
    else:
        raise ValueError(spec.mixer)
    x = x + y
    x, aux = _ffn_apply(cfg, spec, p, x)
    if return_state:
        return x, aux, state
    return x, aux


def block_decode(cfg: ModelConfig, spec: LayerSpec, p: dict, x: jax.Array,
                 positions: jax.Array, cache: dict, cache_len: jax.Array):
    """Single-token decode.  Returns (x, new_cache)."""
    h = rmsnorm(x, p["norm1"], cfg.norm_eps)
    if spec.mixer == ATTN:
        fn = attn.mla_decode if cfg.mla is not None else attn.gqa_decode
        y, new_cache = fn(cfg, p["mixer"], h, positions, cache, cache_len)
    elif spec.mixer == MAMBA:
        y, new_cache = ssm_mod.mamba_decode(cfg, p["mixer"], h, cache)
    elif spec.mixer == MLSTM:
        y, new_cache = xlstm_mod.mlstm_decode(cfg, p["mixer"], h, cache)
    elif spec.mixer == SLSTM:
        y, new_cache = xlstm_mod.slstm_decode(cfg, p["mixer"], h, cache)
    else:
        raise ValueError(spec.mixer)
    x = x + y
    x, _aux = _ffn_apply(cfg, spec, p, x)
    return x, new_cache


def block_chunk(cfg: ModelConfig, spec: LayerSpec, p: dict, x: jax.Array,
                positions: jax.Array, cache: dict, cache_len: jax.Array):
    """Incremental chunked prefill (DESIGN.md §7): run a multi-token chunk
    against the carried cache.  Attention writes the chunk's K/V (latents)
    at the ``cache_len`` offset and attends over the prefix; recurrent
    mixers resume from the cached state (cache format == state format).
    Returns (x, new_cache) — same contract as ``block_decode``."""
    h = rmsnorm(x, p["norm1"], cfg.norm_eps)
    if spec.mixer == ATTN:
        fn = attn.mla_prefill_chunk if cfg.mla is not None \
            else attn.gqa_prefill_chunk
        y, new_cache = fn(cfg, p["mixer"], h, positions, cache, cache_len)
    elif spec.mixer == MAMBA:
        y, new_cache = ssm_mod.mamba_full(cfg, p["mixer"], h, initial=cache,
                                          return_state=True)
    elif spec.mixer == MLSTM:
        y, new_cache = xlstm_mod.mlstm_full(cfg, p["mixer"], h, initial=cache,
                                            return_state=True)
    elif spec.mixer == SLSTM:
        y, new_cache = xlstm_mod.slstm_full(cfg, p["mixer"], h, initial=cache,
                                            return_state=True)
    else:
        raise ValueError(spec.mixer)
    x = x + y
    x, _aux = _ffn_apply(cfg, spec, p, x)
    return x, new_cache


def block_packed(cfg: ModelConfig, spec: LayerSpec, p: dict, x: jax.Array,
                 positions: jax.Array, cache: dict, token_slot: jax.Array,
                 token_wpos: jax.Array, token_active: jax.Array,
                 kv_bucket: Optional[int] = None, token_dst=None,
                 block_tables=None):
    """Token-packed dense-batch step (DESIGN.md §8): one (1, T) stream
    holding the iteration's decode tokens and all prefill-chunk tokens with
    per-token (slot, position) metadata, run against the *whole* slot cache.
    Attention scatters K/V at (slot, wpos), applies the segment mask, and
    reads only ``kv_bucket`` cache rows per slot (KV-length bucketing,
    DESIGN.md §9; ``None`` = full ``max_len``); recurrent mixers advance
    per-slot state with active-masking.  ``token_dst``/``block_tables``
    switch attention to block-table mode (DESIGN.md §12; attention-only —
    the engine rejects prefix caching for models with recurrent mixers).
    Returns (x, new_cache) over the full slot-state arrays."""
    h = rmsnorm(x, p["norm1"], cfg.norm_eps)
    if spec.mixer == ATTN:
        fn = attn.mla_packed if cfg.mla is not None else attn.gqa_packed
        y, new_cache = fn(cfg, p["mixer"], h, positions, cache, token_slot,
                          token_wpos, kv_bucket=kv_bucket,
                          token_dst=token_dst, block_tables=block_tables)
    elif spec.mixer == MAMBA:
        y, new_cache = ssm_mod.mamba_packed(cfg, p["mixer"], h, cache,
                                            token_slot, token_active)
    elif spec.mixer == MLSTM:
        y, new_cache = xlstm_mod.mlstm_packed(cfg, p["mixer"], h, cache,
                                              token_slot, token_active)
    elif spec.mixer == SLSTM:
        y, new_cache = xlstm_mod.slstm_packed(cfg, p["mixer"], h, cache,
                                              token_slot, token_active)
    else:
        raise ValueError(spec.mixer)
    x = x + y
    x, _aux = _ffn_apply(cfg, spec, p, x)
    return x, new_cache


def block_init_cache(cfg: ModelConfig, spec: LayerSpec, tp: int, batch: int,
                     max_len: int, kv_dtype: str | None = None) -> dict:
    if spec.mixer == ATTN:
        return (attn.mla_init_cache(cfg, tp, batch, max_len, kv_dtype)
                if cfg.mla is not None
                else attn.gqa_init_cache(cfg, tp, batch, max_len, kv_dtype))
    if spec.mixer == MAMBA:
        return ssm_mod.mamba_init_cache(cfg, tp, batch)
    if spec.mixer == MLSTM:
        return xlstm_mod.mlstm_init_cache(cfg, tp, batch)
    if spec.mixer == SLSTM:
        return xlstm_mod.slstm_init_cache(cfg, tp, batch)
    raise ValueError(spec.mixer)


def block_cache_axes(cfg: ModelConfig, spec: LayerSpec,
                     kv_dtype: str | None = None) -> dict:
    if spec.mixer == ATTN:
        return (attn.mla_cache_axes(kv_dtype) if cfg.mla is not None
                else attn.gqa_cache_axes(kv_dtype))
    if spec.mixer == MAMBA:
        return ssm_mod.mamba_cache_axes()
    if spec.mixer == MLSTM:
        return xlstm_mod.mlstm_cache_axes()
    if spec.mixer == SLSTM:
        return xlstm_mod.slstm_cache_axes()
    raise ValueError(spec.mixer)
