"""Parameter definition trees: single source of truth for shapes, logical
sharding axes and initialization.

A model definition is a nested dict of ``ParamDef``s.  From it we derive:
  * ``init_params``   — materialized arrays (jax.random, fan-in scaled)
  * ``param_shapes``  — ShapeDtypeStructs (dry-run lowering: zero allocation)
  * ``param_pspecs``  — PartitionSpecs via the logical-axis rule table
  * ``count_params``  — exact parameter counts (optionally filtered by path)
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import logical_to_pspec

INIT_NORMAL = "normal"       # truncated-normal, 1/sqrt(fan_in)
INIT_ZEROS = "zeros"
INIT_ONES = "ones"
INIT_SMALL = "small"         # fixed small std (router / gates)
INIT_A_LOG = "a_log"         # mamba A_log: log(1..d_state) broadcast
INIT_DT_BIAS = "dt_bias"     # mamba dt bias: softplus-inv of uniform dt


@dataclasses.dataclass(frozen=True)
class ParamDef:
    shape: tuple[int, ...]
    axes: tuple[Optional[str], ...]        # logical axes, len == len(shape)
    init: str = INIT_NORMAL
    dtype: str = "bfloat16"
    fan_in_axes: tuple[int, ...] = ()      # dims contracting in the matmul;
                                           # () => last-but-one heuristic

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)

    @property
    def fan_in(self) -> int:
        if self.fan_in_axes:
            return int(np.prod([self.shape[i] for i in self.fan_in_axes]))
        return int(self.shape[0]) if len(self.shape) > 1 else int(self.shape[0])


ParamTree = dict  # nested dict[str, ParamDef | ParamTree]


def _is_def(x) -> bool:
    return isinstance(x, ParamDef)


def map_defs(fn: Callable[[tuple[str, ...], ParamDef], object], tree: ParamTree,
             path: tuple[str, ...] = ()) -> dict:
    out = {}
    for k, v in tree.items():
        if _is_def(v):
            out[k] = fn(path + (k,), v)
        else:
            out[k] = map_defs(fn, v, path + (k,))
    return out


def _materialize(key: jax.Array, d: ParamDef) -> jax.Array:
    dt = jnp.dtype(d.dtype)
    if d.init == INIT_ZEROS:
        return jnp.zeros(d.shape, dt)
    if d.init == INIT_ONES:
        return jnp.ones(d.shape, dt)
    if d.init == INIT_SMALL:
        return (0.02 * jax.random.truncated_normal(key, -2, 2, d.shape, jnp.float32)).astype(dt)
    if d.init == INIT_A_LOG:
        # mamba: A = -exp(A_log); init A_log = log(arange(1, N+1)) per channel
        n = d.shape[-1]
        a = jnp.log(jnp.arange(1, n + 1, dtype=jnp.float32))
        return jnp.broadcast_to(a, d.shape).astype(dt)
    if d.init == INIT_DT_BIAS:
        dt_min, dt_max = 1e-3, 1e-1
        u = jax.random.uniform(key, d.shape, jnp.float32)
        dt_v = jnp.exp(u * (math.log(dt_max) - math.log(dt_min)) + math.log(dt_min))
        return (dt_v + jnp.log(-jnp.expm1(-dt_v))).astype(dt)  # softplus^-1
    std = 1.0 / math.sqrt(max(d.fan_in, 1))
    return (std * jax.random.truncated_normal(key, -2, 2, d.shape, jnp.float32)).astype(dt)


def init_params(tree: ParamTree, key: jax.Array) -> dict:
    """Materialize arrays; per-leaf keys derived by folding in a path digest
    (zlib.crc32 — deterministic across processes, unlike built-in hash)."""
    import zlib

    def leaf(path, d: ParamDef):
        sub = jax.random.fold_in(key, zlib.crc32("/".join(path).encode()) % (2**31))
        return _materialize(sub, d)
    return map_defs(leaf, tree)


def param_shapes(tree: ParamTree, mesh=None, rules=None) -> dict:
    """ShapeDtypeStructs (with shardings when a mesh is given)."""
    def leaf(path, d: ParamDef):
        sharding = None
        if mesh is not None:
            from jax.sharding import NamedSharding
            sharding = NamedSharding(mesh, logical_to_pspec(d.axes, mesh, rules))
        return jax.ShapeDtypeStruct(d.shape, jnp.dtype(d.dtype), sharding=sharding)
    return map_defs(leaf, tree)


def param_pspecs(tree: ParamTree, mesh, rules=None) -> dict:
    return map_defs(lambda p, d: logical_to_pspec(d.axes, mesh, rules), tree)


def count_params(tree: ParamTree,
                 select: Optional[Callable[[tuple[str, ...]], bool]] = None) -> int:
    total = 0

    def leaf(path, d: ParamDef):
        nonlocal total
        if select is None or select(path):
            total += int(np.prod(d.shape))
        return None

    map_defs(leaf, tree)
    return total


def stack_defs(tree: ParamTree, n: int, axis_name: Optional[str] = None) -> ParamTree:
    """Prepend a stacking dim of size n to every ParamDef (scan-over-layers)."""
    def leaf(path, d: ParamDef):
        return dataclasses.replace(
            d, shape=(n,) + d.shape, axes=(axis_name,) + d.axes,
            fan_in_axes=tuple(i + 1 for i in d.fan_in_axes) if d.fan_in_axes
            else tuple(i + 1 for i in _default_fan_in(d)))
    return map_defs(leaf, tree)


def _default_fan_in(d: ParamDef) -> tuple[int, ...]:
    # preserve the pre-stack fan-in heuristic (axis 0 of the original shape)
    return (0,) if len(d.shape) > 1 else ()
