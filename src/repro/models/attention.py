"""Attention blocks: GQA (with qk-norm) and DeepSeek-V2 MLA.

TP head layout
--------------
When tensor-parallel degree ``tp`` does not divide the head counts we use an
*effective layout* (see DESIGN.md §4):

  * MHA (group==1): pad q and kv heads together to the next multiple of tp.
  * GQA: replicate each kv head r = tp/gcd(kv, tp) times; distribute its g
    q-heads across the replicas in groups of g_eff = ceil(g/r), zero-padding
    the ragged remainder.  Heads are stored kv-major so each shard's q heads
    find their kv head locally.

Padding is numerically exact for inference (padded O-projection rows are
zero-init).  kv replication is exact for inference; for *training* with
tp ∤ kv the replicas are free parameters (slightly larger model) — documented
in DESIGN.md.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.distributed import tp
from repro.kernels import ops
from repro.models.layers import apply_rope, apply_rope_nohead, rmsnorm, shard
from repro.models.param import ParamDef
from repro.serving import kvquant


@dataclasses.dataclass(frozen=True)
class HeadLayout:
    n_heads: int          # original q heads
    n_kv: int             # original kv heads
    nh_eff: int
    kv_eff: int
    g_eff: int            # q heads per effective kv head
    replication: int      # kv replication factor r


def head_layout(n_heads: int, n_kv: int, tp: int) -> HeadLayout:
    assert n_heads % n_kv == 0, (n_heads, n_kv)
    g = n_heads // n_kv
    if g == 1:
        nh_eff = kv_eff = math.ceil(n_heads / tp) * tp
        return HeadLayout(n_heads, n_kv, nh_eff, kv_eff, 1, 1)
    r = tp // math.gcd(n_kv, tp)
    kv_eff = n_kv * r
    g_eff = math.ceil(g / r)
    return HeadLayout(n_heads, n_kv, kv_eff * g_eff, kv_eff, g_eff, r)


def qhead_permutation(hl: HeadLayout) -> tuple[list[int], list[int]]:
    """Map original q-head index -> effective slot (kv-major layout).

    Returns (slots, pad_slots): slots[i] = eff index of original q head i;
    pad_slots = eff indices that hold zero-padded heads.
    """
    g = hl.n_heads // hl.n_kv
    slots, used = [], set()
    for h in range(hl.n_heads):
        kv = h // g
        j = h % g                        # index within the kv group
        rep, within = divmod(j, hl.g_eff)
        eff_kv = kv * hl.replication + rep
        slot = eff_kv * hl.g_eff + within
        slots.append(slot)
        used.add(slot)
    pad = [s for s in range(hl.nh_eff) if s not in used]
    return slots, pad


# ---------------------------------------------------------------------------
# GQA
# ---------------------------------------------------------------------------
def gqa_defs(cfg: ModelConfig, tp: int) -> dict:
    hl = head_layout(cfg.n_heads, cfg.n_kv_heads, tp)
    d, hd = cfg.d_model, cfg.resolved_head_dim
    dt = cfg.dtype
    defs = {
        "wq": ParamDef((d, hl.nh_eff, hd), ("w_embed", "heads", "head_dim"), dtype=dt),
        "wk": ParamDef((d, hl.kv_eff, hd), ("w_embed", "kv_heads", "head_dim"), dtype=dt),
        "wv": ParamDef((d, hl.kv_eff, hd), ("w_embed", "kv_heads", "head_dim"), dtype=dt),
        "wo": ParamDef((hl.nh_eff, hd, d), ("heads", "head_dim", "w_embed"),
                       dtype=dt, fan_in_axes=(0, 1)),
    }
    if cfg.qk_norm:
        defs["q_norm"] = ParamDef((hd,), ("head_dim",), init="ones", dtype=dt)
        defs["k_norm"] = ParamDef((hd,), ("head_dim",), init="ones", dtype=dt)
    return defs


def _qkv(cfg: ModelConfig, p: dict, x: jax.Array, positions: jax.Array):
    """Shared projection path.  x: (B, S, D) -> q (B,S,He,hd), k/v (B,S,KVe,hd)."""
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    q = shard(q, "batch", "act_seq", "act_heads", None)
    k = shard(k, "batch", "act_seq", "act_kv_heads", None)
    v = shard(v, "batch", "act_seq", "act_kv_heads", None)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.norm_eps)
        k = rmsnorm(k, p["k_norm"], cfg.norm_eps)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def gqa_full(cfg: ModelConfig, p: dict, x: jax.Array, positions: jax.Array,
             *, q_offset=0, kv_prefix: Optional[tuple] = None,
             return_kv: bool = False):
    """Train / prefill attention over the whole chunk.

    kv_prefix: optional (k, v, prefix_len) earlier-cache tensors for chunked
    prefill — prepended to this chunk's K/V before the causal attention.
    """
    q, k, v = _qkv(cfg, p, x, positions)
    k_all, v_all = k, v
    if kv_prefix is not None:
        pk, pv, _plen = kv_prefix
        k_all = jnp.concatenate([pk, k], axis=1)
        v_all = jnp.concatenate([pv, v], axis=1)
    out = ops.flash_attention(q, k_all, v_all, causal=True, q_offset=q_offset)
    out = shard(out, "batch", "act_seq", "act_heads", None)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    y = shard(y, "batch", "act_seq", "embed")
    if return_kv:
        return y, (k, v)
    return y


def gqa_decode(cfg: ModelConfig, p: dict, x: jax.Array, positions: jax.Array,
               cache: dict, cache_len: jax.Array):
    """Single-token decode.  x: (B, 1, D); cache{k,v}: (B, S, KVe, hd);
    cache_len: (B,) valid positions *before* this token.  Writes the new
    token's KV at cache_len, then attends over cache_len+1 positions."""
    q, k_new, v_new = _qkv(cfg, p, x, positions)
    k_cache = _write_at(cache["k"], k_new[:, 0], cache_len)
    v_cache = _write_at(cache["v"], v_new[:, 0], cache_len)
    k_cache = shard(k_cache, "batch", "kv_seq", "act_kv_heads", None)
    v_cache = shard(v_cache, "batch", "kv_seq", "act_kv_heads", None)
    out = ops.decode_attention(q[:, 0], k_cache, v_cache, cache_len + 1)
    out = shard(out, "batch", "act_heads", None)
    y = jnp.einsum("bhk,hkd->bd", out, p["wo"])[:, None, :]
    y = shard(y, "batch", "act_seq", "embed")
    return y, {"k": k_cache, "v": v_cache}


def gqa_prefill_chunk(cfg: ModelConfig, p: dict, x: jax.Array,
                      positions: jax.Array, cache: dict, cache_len: jax.Array):
    """Incremental chunked prefill (DESIGN.md §7).  x: (B, S_chunk, D);
    cache_len: (B,) prefix tokens already in the cache.  Writes this chunk's
    K/V at the prefix offset, then attends the chunk's queries over the full
    cache with a ``q_offset`` causal mask — positions beyond
    cache_len + S_chunk are never written yet, so the mask excludes them.
    Each prompt token is projected exactly once across chunks (O(p) FLOPs
    instead of the recompute path's O(p²/chunk))."""
    q, k_new, v_new = _qkv(cfg, p, x, positions)
    k_cache = _write_seq_at(cache["k"], k_new, cache_len)
    v_cache = _write_seq_at(cache["v"], v_new, cache_len)
    k_cache = shard(k_cache, "batch", "kv_seq", "act_kv_heads", None)
    v_cache = shard(v_cache, "batch", "kv_seq", "act_kv_heads", None)
    out = ops.flash_attention(q, k_cache, v_cache, causal=True,
                              q_offset=cache_len)
    out = shard(out, "batch", "act_seq", "act_heads", None)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    y = shard(y, "batch", "act_seq", "embed")
    return y, {"k": k_cache, "v": v_cache}


def _flat_scatter(cache: jax.Array, new: jax.Array,
                  token_dst: jax.Array) -> jax.Array:
    """Block-table scatter (DESIGN.md §12): write each token's row at its
    *physical* flat row id (block_id · block_size + offset), computed on the
    host from the request's block table.  Padding rows carry ``N·S`` (out of
    bounds → dropped).  The leaf keeps its (N, S, ...) shape."""
    n, s = cache.shape[:2]
    flat = cache.reshape((n * s,) + cache.shape[2:])
    flat = flat.at[token_dst].set(new.astype(cache.dtype), mode="drop")
    return flat.reshape(cache.shape)


def _block_view(cache: jax.Array, block_tables: jax.Array,
                kv_bucket: Optional[int]) -> jax.Array:
    """Gather a block-table cache back into per-slot contiguous logical
    rows, (N, kv_bucket, ...) — the dense-read analogue of the Pallas
    kernel's index-map dereference (used by the MLA latent path, where the
    absorbed concat needs a materialized view anyway)."""
    n, s = cache.shape[:2]
    nb_cols = block_tables.shape[1]
    bs = s // nb_cols
    sweep = s if kv_bucket is None or kv_bucket > s else kv_bucket
    flat = cache.reshape((n * nb_cols, bs) + cache.shape[2:])
    view = flat[block_tables[:, :sweep // bs]]
    return view.reshape((n, sweep) + cache.shape[2:])


def gqa_packed(cfg: ModelConfig, p: dict, x: jax.Array, positions: jax.Array,
               cache: dict, token_slot: jax.Array, token_wpos: jax.Array,
               kv_bucket: Optional[int] = None, token_dst=None,
               block_tables=None):
    """Token-packed dense-batch step (DESIGN.md §8).  x: (1, T, D) — the
    iteration's decode tokens and *all* prefill-chunk tokens packed into one
    stream; positions: (1, T) absolute position of each token in its own
    request; cache{k,v}: (N_slots, S, KVe, hd) — the *whole* slot cache, not
    a per-request gather.  Scatters each token's K/V at ``(slot, wpos)``
    (``wpos == S`` for padding tokens → dropped), then runs segment-masked
    attention: token t attends rows [0, positions[t]] of its own slot only,
    which covers the carried prefix *and* same-segment tokens written by
    this very dispatch.

    ``kv_bucket`` (static, DESIGN.md §9): the engine's bound on this
    iteration's ``max(positions) + 1`` — attention reads only that many
    cache rows per slot, so its FLOPs/bytes scale with actual context, not
    ``max_len``.  The scatter still targets the full cache.

    Under tensor parallelism (DESIGN.md §11) the projections and the slot
    cache are sharded along (kv-)heads, attention is per-head local, and
    only the output projection reduces across shards
    (``tp.out_project`` — a nano-batch-chunked ring all-reduce).

    Block-table mode (DESIGN.md §12, ``token_dst``/``block_tables`` set):
    the same leaves are treated as physical block storage — K/V scatter at
    flat row ``token_dst[t]`` and attention gathers through the per-slot
    table, so requests can share immutable prefix blocks.  TP-safe: both
    reshapes fold the unsharded (slot, seq) axes only.

    int8 KV (DESIGN.md §15, scale leaves ``k_s``/``v_s`` present): each
    token's post-rope K/V row quantizes *in-program* at scatter time
    (symmetric per-(token, kv-head), f32 scale rides the same scatter), and
    attention dequantizes in-register after the int8 HBM read — the kernel
    receives the int8 leaves plus the scale tiles, never a dense f32 copy."""
    q, k_new, v_new = _qkv(cfg, p, x, positions)
    quantized = "k_s" in cache
    if quantized:
        k_val, k_s_new = kvquant.quantize_kv(k_new[0])
        v_val, v_s_new = kvquant.quantize_kv(v_new[0])
    else:
        k_val, v_val = k_new[0], v_new[0]
        k_scale = v_scale = None
    if block_tables is not None:
        k_cache = _flat_scatter(cache["k"], k_val, token_dst)
        v_cache = _flat_scatter(cache["v"], v_val, token_dst)
        if quantized:
            k_scale = _flat_scatter(cache["k_s"], k_s_new, token_dst)
            v_scale = _flat_scatter(cache["v_s"], v_s_new, token_dst)
    else:
        k_cache = cache["k"].at[token_slot, token_wpos].set(
            k_val.astype(cache["k"].dtype), mode="drop")
        v_cache = cache["v"].at[token_slot, token_wpos].set(
            v_val.astype(cache["v"].dtype), mode="drop")
        if quantized:
            k_scale = cache["k_s"].at[token_slot, token_wpos].set(
                k_s_new, mode="drop")
            v_scale = cache["v_s"].at[token_slot, token_wpos].set(
                v_s_new, mode="drop")
    k_cache = shard(k_cache, "batch", "kv_seq", "act_kv_heads", None)
    v_cache = shard(v_cache, "batch", "kv_seq", "act_kv_heads", None)
    if quantized:
        k_scale = shard(k_scale, "batch", "kv_seq", "act_kv_heads")
        v_scale = shard(v_scale, "batch", "kv_seq", "act_kv_heads")
    out = ops.packed_attention(q[0], k_cache, v_cache, token_slot,
                               positions[0] + 1, kv_bucket=kv_bucket,
                               block_tables=block_tables,
                               k_scale=k_scale, v_scale=v_scale)
    y = tp.out_project(out, p["wo"])[None]
    y = shard(y, "batch", "act_seq", "embed")
    new_cache = {"k": k_cache, "v": v_cache}
    if quantized:
        new_cache["k_s"], new_cache["v_s"] = k_scale, v_scale
    return y, new_cache


def _write_at(cache: jax.Array, new: jax.Array, idx: jax.Array) -> jax.Array:
    """cache: (B, S, ...); new: (B, ...); idx: (B,) — per-row dynamic write."""
    def one(c, n, i):
        return jax.lax.dynamic_update_slice(c, n[None], (i,) + (0,) * (c.ndim - 1))
    return jax.vmap(one)(cache, new, idx)


def _write_seq_at(cache: jax.Array, new: jax.Array, idx: jax.Array) -> jax.Array:
    """cache: (B, S, ...); new: (B, s, ...); idx: (B,) — write the s rows of
    each batch row at its own offset (partial-prefix write, chunked prefill)."""
    def one(c, n, i):
        return jax.lax.dynamic_update_slice(
            c, n.astype(c.dtype), (i,) + (0,) * (c.ndim - 1))
    return jax.vmap(one)(cache, new, idx)


def gqa_init_cache(cfg: ModelConfig, tp: int, batch: int, max_len: int,
                   kv_dtype: Optional[str] = None) -> dict:
    hl = head_layout(cfg.n_heads, cfg.n_kv_heads, tp)
    hd = cfg.resolved_head_dim
    shape = (batch, max_len, hl.kv_eff, hd)
    if kv_dtype == "int8":
        # int8 value leaves + f32 per-(token, kv-head) scale leaves
        # (DESIGN.md §15) — same (batch, seq, kv-head) leading layout, so
        # CoW / block-table / TP paths treat them like any other leaf
        return {"k": jnp.zeros(shape, jnp.int8),
                "v": jnp.zeros(shape, jnp.int8),
                "k_s": jnp.zeros(shape[:-1], jnp.float32),
                "v_s": jnp.zeros(shape[:-1], jnp.float32)}
    return {"k": jnp.zeros(shape, jnp.dtype(cfg.dtype)),
            "v": jnp.zeros(shape, jnp.dtype(cfg.dtype))}


def gqa_cache_axes(kv_dtype: Optional[str] = None) -> dict:
    axes = {"k": ("batch", "kv_seq", "act_kv_heads", None),
            "v": ("batch", "kv_seq", "act_kv_heads", None)}
    if kv_dtype == "int8":
        axes["k_s"] = ("batch", "kv_seq", "act_kv_heads")
        axes["v_s"] = ("batch", "kv_seq", "act_kv_heads")
    return axes


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2): low-rank latent KV; the cache holds only (c_kv, k_rope)
# per token.  *All* paths (train/prefill, chunked prefill, decode) run the
# absorbed form: W_uk is folded into the query and W_uv applied after the
# softmax, so attention runs entirely in the (rank + rope) latent — a GQA
# with a single shared kv "head".  One association order everywhere means
# prefill and decode agree to kernel precision; the earlier split (naive
# per-head prefill vs absorbed decode) rounded differently in bf16, and MoE
# routing amplified those ulps into expert flips
# (test_prefill_decode_consistency[deepseek-v2-236b]).
# ---------------------------------------------------------------------------
def mla_defs(cfg: ModelConfig, tp: int) -> dict:
    m = cfg.mla
    assert m is not None
    d, dt = cfg.d_model, cfg.dtype
    nh = math.ceil(cfg.n_heads / tp) * tp          # pad heads to tp multiple
    qk = m.qk_nope_dim + m.qk_rope_dim
    return {
        "wdq": ParamDef((d, m.q_lora_rank), ("w_embed", "lora"), dtype=dt),
        "q_ln": ParamDef((m.q_lora_rank,), ("lora",), init="ones", dtype=dt),
        "wuq": ParamDef((m.q_lora_rank, nh, qk), ("lora", "heads", "head_dim"), dtype=dt),
        "wdkv": ParamDef((d, m.kv_lora_rank), ("w_embed", "lora"), dtype=dt),
        "kv_ln": ParamDef((m.kv_lora_rank,), ("lora",), init="ones", dtype=dt),
        "wkr": ParamDef((d, m.qk_rope_dim), ("w_embed", "head_dim"), dtype=dt),
        "wuk": ParamDef((m.kv_lora_rank, nh, m.qk_nope_dim),
                        ("lora", "heads", "head_dim"), dtype=dt),
        "wuv": ParamDef((m.kv_lora_rank, nh, m.v_head_dim),
                        ("lora", "heads", "head_dim"), dtype=dt),
        "wo": ParamDef((nh, m.v_head_dim, d), ("heads", "head_dim", "w_embed"),
                       dtype=dt, fan_in_axes=(0, 1)),
    }


def _mla_q(cfg: ModelConfig, p: dict, x: jax.Array, positions: jax.Array):
    m = cfg.mla
    cq = rmsnorm(jnp.einsum("bsd,dr->bsr", x, p["wdq"]), p["q_ln"], cfg.norm_eps)
    q = jnp.einsum("bsr,rhk->bshk", cq, p["wuq"])
    q = shard(q, "batch", "act_seq", "act_heads", None)
    q_nope = q[..., : m.qk_nope_dim]
    q_rope = apply_rope(q[..., m.qk_nope_dim:], positions, cfg.rope_theta)
    return q_nope, q_rope


def _mla_latent(cfg: ModelConfig, p: dict, x: jax.Array, positions: jax.Array):
    m = cfg.mla
    c_kv = rmsnorm(jnp.einsum("bsd,dr->bsr", x, p["wdkv"]), p["kv_ln"], cfg.norm_eps)
    k_rope = apply_rope_nohead(jnp.einsum("bsd,dr->bsr", x, p["wkr"]),
                               positions, cfg.rope_theta)
    return c_kv, k_rope          # (B,S,rank), (B,S,rope_dim)


def _mla_q_absorbed(cfg: ModelConfig, p: dict, x: jax.Array,
                    positions: jax.Array) -> jax.Array:
    """Absorbed query: q_nope · W_uk folded into the latent.
    Returns (B, S, H, rank + rope_dim)."""
    q_nope, q_rope = _mla_q(cfg, p, x, positions)
    q_lat = jnp.einsum("bshk,rhk->bshr", q_nope, p["wuk"])
    return jnp.concatenate([q_lat, q_rope], axis=-1)


def _mla_unabsorb(p: dict, out_lat: jax.Array, dtype) -> jax.Array:
    """probs·c_kv latent context -> per-head values via W_uv.
    out_lat: (B[, S], H, rank) -> (B[, S], H, v_head_dim)."""
    return jnp.einsum("...hr,rhk->...hk", out_lat.astype(dtype), p["wuv"])


def mla_full(cfg: ModelConfig, p: dict, x: jax.Array, positions: jax.Array,
             *, q_offset=0, kv_prefix: Optional[tuple] = None,
             return_kv: bool = False):
    """Absorbed MLA for train/prefill: attention over the latent KV with a
    single shared kv head (group = n_heads)."""
    m = cfg.mla
    q_abs = _mla_q_absorbed(cfg, p, x, positions)        # (B,S,H,rank+rope)
    c_kv, k_rope = _mla_latent(cfg, p, x, positions)
    if kv_prefix is not None:
        pc, pr, _plen = kv_prefix
        c_kv_all = jnp.concatenate([pc, c_kv], axis=1)
        k_rope_all = jnp.concatenate([pr, k_rope], axis=1)
    else:
        c_kv_all, k_rope_all = c_kv, k_rope
    k_abs = jnp.concatenate([c_kv_all, k_rope_all], axis=-1)[:, :, None, :]
    v_lat = c_kv_all[:, :, None, :]                      # (B,S,1,rank)
    scale = (m.qk_nope_dim + m.qk_rope_dim) ** -0.5
    out_lat = ops.flash_attention(q_abs, k_abs, v_lat, causal=True,
                                  logit_scale=scale, q_offset=q_offset)
    out = _mla_unabsorb(p, out_lat, x.dtype)
    out = shard(out, "batch", "act_seq", "act_heads", None)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    y = shard(y, "batch", "act_seq", "embed")
    if return_kv:
        return y, (c_kv, k_rope)
    return y


def mla_decode(cfg: ModelConfig, p: dict, x: jax.Array, positions: jax.Array,
               cache: dict, cache_len: jax.Array):
    """Absorbed decode: scores and context computed in the latent, through
    the same decode_attention kernel the GQA path uses (KV head = 1)."""
    m = cfg.mla
    q_abs = _mla_q_absorbed(cfg, p, x, positions)        # (B,1,H,rank+rope)
    c_new, r_new = _mla_latent(cfg, p, x, positions)     # (B,1,rank/rope)
    ckv = _write_at(cache["c_kv"], c_new[:, 0], cache_len)
    krp = _write_at(cache["k_rope"], r_new[:, 0], cache_len)
    ckv = shard(ckv, "batch", "kv_seq", None)
    k_abs = jnp.concatenate([ckv, krp], axis=-1)[:, :, None, :]
    v_lat = ckv[:, :, None, :]
    scale = (m.qk_nope_dim + m.qk_rope_dim) ** -0.5
    out_lat = ops.decode_attention(q_abs[:, 0], k_abs, v_lat, cache_len + 1,
                                   logit_scale=scale)
    out = _mla_unabsorb(p, out_lat, x.dtype)
    out = shard(out, "batch", "act_heads", None)
    y = jnp.einsum("bhk,hkd->bd", out, p["wo"])[:, None, :]
    y = shard(y, "batch", "act_seq", "embed")
    return y, {"c_kv": ckv, "k_rope": krp}


def mla_prefill_chunk(cfg: ModelConfig, p: dict, x: jax.Array,
                      positions: jax.Array, cache: dict, cache_len: jax.Array):
    """Incremental chunked prefill for MLA (DESIGN.md §7): write the chunk's
    latents at the prefix offset, attend absorbed queries over the latent
    cache.  No per-head K/V is ever materialized — the prefix cost per chunk
    is O(S_cache · (rank + rope)), not O(S_cache · heads · head_dim)."""
    m = cfg.mla
    q_abs = _mla_q_absorbed(cfg, p, x, positions)        # (B,s,H,rank+rope)
    c_new, r_new = _mla_latent(cfg, p, x, positions)
    ckv = _write_seq_at(cache["c_kv"], c_new, cache_len)
    krp = _write_seq_at(cache["k_rope"], r_new, cache_len)
    ckv = shard(ckv, "batch", "kv_seq", None)
    k_abs = jnp.concatenate([ckv, krp], axis=-1)[:, :, None, :]
    v_lat = ckv[:, :, None, :]
    scale = (m.qk_nope_dim + m.qk_rope_dim) ** -0.5
    out_lat = ops.flash_attention(q_abs, k_abs, v_lat, causal=True,
                                  logit_scale=scale, q_offset=cache_len)
    out = _mla_unabsorb(p, out_lat, x.dtype)
    out = shard(out, "batch", "act_seq", "act_heads", None)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    y = shard(y, "batch", "act_seq", "embed")
    return y, {"c_kv": ckv, "k_rope": krp}


def mla_packed(cfg: ModelConfig, p: dict, x: jax.Array, positions: jax.Array,
               cache: dict, token_slot: jax.Array, token_wpos: jax.Array,
               kv_bucket: Optional[int] = None, token_dst=None,
               block_tables=None):
    """Token-packed step for MLA (DESIGN.md §8): scatter each token's
    latents at ``(slot, wpos)``, attend absorbed queries over the slot's
    latent cache with the segment/length mask.  Same absorbed association
    order as every other MLA path.  ``d_v != d_qk`` (latent rank vs
    rank + rope) is handled natively by the packed-attention kernel.

    ``kv_bucket`` (static, DESIGN.md §9) slices the latent views *before*
    the absorbed-key concat, so the materialized (N, S, rank + rope) key
    tensor also scales with the bucket, not ``max_len``.

    Under tensor parallelism (DESIGN.md §11) the latent path — ``c_kv`` /
    ``k_rope`` and their cache — is replicated (it is one shared kv
    "head"); the absorbed per-head projections are sharded along heads and
    the output projection reduces across shards (``tp.out_project``).

    Block-table mode (DESIGN.md §12): latents scatter at their flat
    physical rows and the bucket view is a per-slot *gather* through the
    block table instead of a slice — the absorbed concat then proceeds on
    the logical view, so the dense latent attention (one shared kv "head")
    needs no kernel-side table.

    int8 KV (DESIGN.md §15, ``c_kv_s``/``k_rope_s`` present): only the
    latent/rope leaves quantize (the cache stores nothing else) —
    per-(token,) symmetric scales scatter alongside, and the bucketed
    *views* dequantize right before the absorbed concat, so the int8 HBM
    read feeds the same flash kernel unchanged."""
    m = cfg.mla
    q_abs = _mla_q_absorbed(cfg, p, x, positions)        # (1,T,H,rank+rope)
    c_new, r_new = _mla_latent(cfg, p, x, positions)
    quantized = "c_kv_s" in cache
    if quantized:
        c_val, c_s_new = kvquant.quantize_kv(c_new[0])
        r_val, r_s_new = kvquant.quantize_kv(r_new[0])
    else:
        c_val, r_val = c_new[0], r_new[0]
        c_scale = r_scale = None
    if block_tables is not None:
        ckv = _flat_scatter(cache["c_kv"], c_val, token_dst)
        krp = _flat_scatter(cache["k_rope"], r_val, token_dst)
        if quantized:
            c_scale = _flat_scatter(cache["c_kv_s"], c_s_new, token_dst)
            r_scale = _flat_scatter(cache["k_rope_s"], r_s_new, token_dst)
        ckv = shard(ckv, "batch", "kv_seq", None)
        ckv_v = _block_view(ckv, block_tables, kv_bucket)
        krp_v = _block_view(krp, block_tables, kv_bucket)
        if quantized:
            c_s_v = _block_view(c_scale, block_tables, kv_bucket)
            r_s_v = _block_view(r_scale, block_tables, kv_bucket)
    else:
        ckv = cache["c_kv"].at[token_slot, token_wpos].set(
            c_val.astype(cache["c_kv"].dtype), mode="drop")
        krp = cache["k_rope"].at[token_slot, token_wpos].set(
            r_val.astype(cache["k_rope"].dtype), mode="drop")
        if quantized:
            c_scale = cache["c_kv_s"].at[token_slot, token_wpos].set(
                c_s_new, mode="drop")
            r_scale = cache["k_rope_s"].at[token_slot, token_wpos].set(
                r_s_new, mode="drop")
        ckv = shard(ckv, "batch", "kv_seq", None)
        ckv_v, krp_v = ckv, krp
        c_s_v, r_s_v = c_scale, r_scale
        if kv_bucket is not None and kv_bucket < ckv.shape[1]:
            ckv_v = jax.lax.slice_in_dim(ckv, 0, kv_bucket, axis=1)
            krp_v = jax.lax.slice_in_dim(krp, 0, kv_bucket, axis=1)
            if quantized:
                c_s_v = jax.lax.slice_in_dim(c_scale, 0, kv_bucket, axis=1)
                r_s_v = jax.lax.slice_in_dim(r_scale, 0, kv_bucket, axis=1)
    if quantized:
        ckv_v = kvquant.dequantize_kv(ckv_v, c_s_v, x.dtype)
        krp_v = kvquant.dequantize_kv(krp_v, r_s_v, x.dtype)
    k_abs = jnp.concatenate([ckv_v, krp_v], axis=-1)[:, :, None, :]
    v_lat = ckv_v[:, :, None, :]
    scale = (m.qk_nope_dim + m.qk_rope_dim) ** -0.5
    out_lat = ops.packed_attention(q_abs[0], k_abs, v_lat, token_slot,
                                   positions[0] + 1, logit_scale=scale)
    out = _mla_unabsorb(p, out_lat, x.dtype)             # (T,H,v_head)
    out = shard(out[None], "batch", "act_seq", "act_heads", None)[0]
    y = tp.out_project(out, p["wo"])[None]
    y = shard(y, "batch", "act_seq", "embed")
    new_cache = {"c_kv": ckv, "k_rope": krp}
    if quantized:
        new_cache["c_kv_s"], new_cache["k_rope_s"] = c_scale, r_scale
    return y, new_cache


def mla_init_cache(cfg: ModelConfig, tp: int, batch: int, max_len: int,
                   kv_dtype: Optional[str] = None) -> dict:
    m = cfg.mla
    if kv_dtype == "int8":
        return {"c_kv": jnp.zeros((batch, max_len, m.kv_lora_rank), jnp.int8),
                "k_rope": jnp.zeros((batch, max_len, m.qk_rope_dim), jnp.int8),
                "c_kv_s": jnp.zeros((batch, max_len), jnp.float32),
                "k_rope_s": jnp.zeros((batch, max_len), jnp.float32)}
    dt = jnp.dtype(cfg.dtype)
    return {"c_kv": jnp.zeros((batch, max_len, m.kv_lora_rank), dt),
            "k_rope": jnp.zeros((batch, max_len, m.qk_rope_dim), dt)}


def mla_cache_axes(kv_dtype: Optional[str] = None) -> dict:
    axes = {"c_kv": ("batch", "kv_seq", None),
            "k_rope": ("batch", "kv_seq", None)}
    if kv_dtype == "int8":
        axes["c_kv_s"] = ("batch", "kv_seq")
        axes["k_rope_s"] = ("batch", "kv_seq")
    return axes
