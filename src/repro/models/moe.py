"""Mixture-of-Experts FFN: GShard-style einsum dispatch with capacity.

Baseline (paper-era standard, GSPMD-shardable): top-k routing, tokens grouped
into dispatch groups of ``dispatch_group`` tokens, one-hot dispatch/combine
tensors of shape (G, S, E, C).  Experts are sharded over the ``model`` mesh
axis (expert parallelism); XLA inserts the all-to-alls.

The dispatch einsums carry real FLOPs (G·S·E·C·d) — this is *measured
honestly* in the roofline and is a hillclimb target (see EXPERIMENTS.md §Perf:
the optimized path uses a dense-gate matmul formulation that removes the C
dimension from the contraction).
"""
from __future__ import annotations

import math
import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig, MoEConfig
from repro.distributed import tp
from repro.models.layers import shard, silu
from repro.models.param import ParamDef


def moe_defs(cfg: ModelConfig, *, dense_residual: bool = False) -> dict:
    m = cfg.moe
    assert m is not None
    d, dt = cfg.d_model, cfg.dtype
    defs = {
        "router": ParamDef((d, m.num_experts), ("w_embed", "experts"),
                           init="small", dtype="float32"),
        "w_gate": ParamDef((m.num_experts, d, m.expert_d_ff),
                           ("experts", "w_embed", "ff"), dtype=dt, fan_in_axes=(1,)),
        "w_up": ParamDef((m.num_experts, d, m.expert_d_ff),
                         ("experts", "w_embed", "ff"), dtype=dt, fan_in_axes=(1,)),
        "w_down": ParamDef((m.num_experts, m.expert_d_ff, d),
                           ("experts", "ff", "w_embed"), dtype=dt, fan_in_axes=(1,)),
    }
    if m.num_shared_experts:
        defs["shared"] = _ffn_defs(d, m.shared_d_ff, dt)
    if dense_residual:
        defs["dense"] = _ffn_defs(d, cfg.d_ff, dt)
    return defs


def _ffn_defs(d: int, d_ff: int, dt: str, gated: bool = True) -> dict:
    defs = {
        "w_up": ParamDef((d, d_ff), ("w_embed", "ff"), dtype=dt),
        "w_down": ParamDef((d_ff, d), ("ff", "w_embed"), dtype=dt),
    }
    if gated:
        defs["w_gate"] = ParamDef((d, d_ff), ("w_embed", "ff"), dtype=dt)
    return defs


def dense_ffn(p: dict, x: jax.Array) -> jax.Array:
    """SwiGLU when w_gate present, else plain GELU MLP.  x: (..., D).

    Under tensor parallelism (DESIGN.md §11) ``w_up``/``w_gate`` are
    column-parallel (separate matrices — no fused-split issue) and
    ``w_down`` is row-parallel: ``tp.row_parallel`` launches the
    all-reduce per nano-batch group; identity einsum at tp=1."""
    u = jnp.einsum("...d,df->...f", x, p["w_up"])
    if "w_gate" in p:
        g = jnp.einsum("...d,df->...f", x, p["w_gate"])
        h = silu(g) * u
    else:
        h = jax.nn.gelu(u)
    h = shard(h, *(("batch",) + (None,) * (x.ndim - 2) + ("act_ff",)))
    y = tp.row_parallel(h, p["w_down"])
    return shard(y, *(("batch",) + (None,) * (x.ndim - 2) + ("embed",)))


def _capacity(m: MoEConfig, group_size: int) -> int:
    c = math.ceil(group_size * m.top_k / m.num_experts * m.capacity_factor)
    return max(4, c)


def route_topk(m: MoEConfig, router_logits: jax.Array
               ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """router_logits: (G, S, E) f32.  Returns (gates (G,S,K), idx (G,S,K),
    aux_loss scalar) with gates renormalized over the chosen k."""
    probs = jax.nn.softmax(router_logits.astype(jnp.float32), axis=-1)
    gates, idx = jax.lax.top_k(probs, m.top_k)
    gates = gates / jnp.maximum(gates.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance loss: E * sum_e f_e * p_e
    e = m.num_experts
    me = probs.mean(axis=(0, 1))                                  # (E,)
    one_hot_top1 = jax.nn.one_hot(idx[..., 0], e, dtype=jnp.float32)
    ce = one_hot_top1.mean(axis=(0, 1))
    aux = e * jnp.sum(me * ce)
    return gates, idx, aux


def moe_ffn(cfg: ModelConfig, p: dict, x: jax.Array, *,
            dense_residual: bool = False) -> tuple[jax.Array, jax.Array]:
    """x: (B, S, D) -> (y, aux_loss).  GShard einsum dispatch."""
    import os
    m = cfg.moe
    b, s, d = x.shape
    tokens = x.reshape(b * s, d)
    t = tokens.shape[0]
    # §Perf HC2 knob: dispatch-group size (capacity C scales with it)
    sg = min(int(os.environ.get("REPRO_MOE_GROUP", m.dispatch_group)), t)
    # pad to a multiple of the group size
    g = math.ceil(t / sg)
    pad = g * sg - t
    if pad:
        tokens = jnp.pad(tokens, ((0, pad), (0, 0)))
    grouped = tokens.reshape(g, sg, d)
    grouped = shard(grouped, "batch", None, "embed")

    logits = jnp.einsum("gsd,de->gse", grouped.astype(jnp.float32), p["router"])
    gates, idx, aux = route_topk(m, logits)

    c = _capacity(m, sg)
    e = m.num_experts
    # position of each (token, k) within its expert queue; earlier k has
    # priority (GShard).  mask_k: (G,S,E) one-hot of choice k.
    dispatch = jnp.zeros((g, sg, e, c), dtype=jnp.bfloat16)
    combine = jnp.zeros((g, sg, e, c), dtype=jnp.float32)
    prev_counts = jnp.zeros((g, 1, e), jnp.int32)
    for k in range(m.top_k):
        mask = jax.nn.one_hot(idx[..., k], e, dtype=jnp.int32)     # (G,S,E)
        pos = jnp.cumsum(mask, axis=1) - mask + prev_counts        # (G,S,E)
        keep = (pos < c) & (mask > 0)
        pos_oh = jax.nn.one_hot(pos, c, dtype=jnp.bfloat16) * keep[..., None]
        dispatch = dispatch + pos_oh
        combine = combine + pos_oh.astype(jnp.float32) * gates[..., k][..., None, None]
        prev_counts = prev_counts + mask.sum(axis=1, keepdims=True)

    # Manual expert parallelism under the TP packed step (DESIGN.md §11):
    # routing/dispatch were computed replicated; each shard processes its
    # local expert block (w_gate/w_up/w_down hold E/p experts) and the
    # combine over experts becomes a cross-shard partial sum -> psum.
    ctx = tp.active()
    if ctx is not None:
        e_loc = e // ctx.size
        start = jax.lax.axis_index(ctx.axis) * e_loc
        dispatch = jax.lax.dynamic_slice_in_dim(dispatch, start, e_loc, axis=2)
        combine = jax.lax.dynamic_slice_in_dim(combine, start, e_loc, axis=2)
    # dispatch: (G,S,E,C) x (G,S,D) -> (E,G,C,D), experts sharded on model
    expert_in = jnp.einsum("gsec,gsd->egcd", dispatch,
                           grouped.astype(jnp.bfloat16))
    expert_in = shard(expert_in, "act_experts", "batch", None, "embed")
    h = silu(jnp.einsum("egcd,edf->egcf", expert_in, p["w_gate"])) * \
        jnp.einsum("egcd,edf->egcf", expert_in, p["w_up"])
    expert_out = jnp.einsum("egcf,efd->egcd", h, p["w_down"])
    expert_out = shard(expert_out, "act_experts", "batch", None, "embed")
    y = tp.psum(jnp.einsum("gsec,egcd->gsd", combine.astype(jnp.bfloat16),
                           expert_out))
    y = y.reshape(g * sg, d)[:t].reshape(b, s, d).astype(x.dtype)

    if m.num_shared_experts:
        y = y + dense_ffn(p["shared"], x)
    if dense_residual:
        y = y + dense_ffn(p["dense"], x)
    return shard(y, "batch", "act_seq", "embed"), aux
