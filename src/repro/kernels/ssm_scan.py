"""Pallas TPU chunked Mamba selective-scan kernel.

Why a kernel: XLA lowers the time recurrence as a ``lax.scan`` whose (C, N)
state round-trips through HBM every step — the scan is *memory-bound* at
S·C·N·4 bytes of state traffic (this is exactly the memory-bound overlap
partner NanoFlow wants to co-schedule, see roofline).  This kernel keeps the
state in VMEM across the whole sequence sweep: HBM traffic drops to the
inputs/outputs only (S·C reads + writes), an ~N× reduction.

Grid: (B, channel_blocks, seq_chunks) — chunks minor, so the (Cb, N) state
scratch persists across a (batch, channel-block)'s sequence sweep.  Channels
are independent, so channel blocks parallelize freely (they become the
co-schedulable DMA/VPU stream on real hardware).

VMEM per step (f32): x,dt (Tc, Cb)·2 + b,c (Tc, N)·2 + h (Cb, N) + y (Tc, Cb)
  with Tc=128, Cb=512, N=16: ~0.8 MB.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu


def _kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, d_ref, h0_ref,
            y_ref, hout_ref, h_ref, *, chunk: int):
    ch = pl.program_id(2)
    nch = pl.num_programs(2)

    @pl.when(ch == 0)
    def _init():
        h_ref[...] = h0_ref[0].astype(jnp.float32)

    a = a_ref[...].astype(jnp.float32)                    # (Cb, N)
    d = d_ref[...].astype(jnp.float32)                    # (1, Cb)

    def step(t, h):
        xt = x_ref[0, t].astype(jnp.float32)              # (Cb,)
        dtt = dt_ref[0, t].astype(jnp.float32)            # (Cb,)
        bt = b_ref[0, t].astype(jnp.float32)              # (N,)
        ct = c_ref[0, t].astype(jnp.float32)              # (N,)
        da = jnp.exp(dtt[:, None] * a)                    # (Cb, N)
        h = da * h + (dtt * xt)[:, None] * bt[None, :]
        y = jnp.sum(h * ct[None, :], axis=1) + d[0] * xt
        y_ref[0, t] = y.astype(y_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_ref[...])
    h_ref[...] = h

    @pl.when(ch == nch - 1)
    def _fin():
        hout_ref[0] = h_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk", "block_c", "interpret"))
def ssm_scan(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
             c: jax.Array, d: jax.Array, h0: Optional[jax.Array] = None, *,
             chunk: int = 128, block_c: int = 512,
             interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """Selective scan (see kernels/ref.py:ssm_scan_ref for semantics).

    x, dt: (B, S, C); a: (C, N); b, c: (B, S, N); d: (C,); h0: (B, C, N).
    Returns (y (B, S, C), h_final (B, C, N) f32)."""
    bsz, s, cdim = x.shape
    n = a.shape[-1]
    if h0 is None:
        h0 = jnp.zeros((bsz, cdim, n), jnp.float32)

    chunk = min(chunk, max(8, s))
    block_c = min(block_c, max(8, cdim))
    s_pad = -(-s // chunk) * chunk
    c_pad = -(-cdim // block_c) * block_c
    if s_pad != s:
        pad = ((0, 0), (0, s_pad - s), (0, 0))
        x, dt = jnp.pad(x, pad), jnp.pad(dt, pad)
        b, c = jnp.pad(b, pad), jnp.pad(c, pad)
    if c_pad != cdim:
        x = jnp.pad(x, ((0, 0), (0, 0), (0, c_pad - cdim)))
        dt = jnp.pad(dt, ((0, 0), (0, 0), (0, c_pad - cdim)))
        a = jnp.pad(a, ((0, c_pad - cdim), (0, 0)))
        d = jnp.pad(d, (0, c_pad - cdim))
        h0 = jnp.pad(h0, ((0, 0), (0, c_pad - cdim), (0, 0)))

    grid = (bsz, c_pad // block_c, s_pad // chunk)
    y, h_fin = pl.pallas_call(
        functools.partial(_kernel, chunk=chunk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, block_c), lambda bb, db, ch: (bb, ch, db)),
            pl.BlockSpec((1, chunk, block_c), lambda bb, db, ch: (bb, ch, db)),
            pl.BlockSpec((block_c, n), lambda bb, db, ch: (db, 0)),
            pl.BlockSpec((1, chunk, n), lambda bb, db, ch: (bb, ch, 0)),
            pl.BlockSpec((1, chunk, n), lambda bb, db, ch: (bb, ch, 0)),
            pl.BlockSpec((1, block_c), lambda bb, db, ch: (0, db)),
            pl.BlockSpec((1, block_c, n), lambda bb, db, ch: (bb, db, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, block_c), lambda bb, db, ch: (bb, ch, db)),
            pl.BlockSpec((1, block_c, n), lambda bb, db, ch: (bb, db, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, s_pad, c_pad), x.dtype),
            jax.ShapeDtypeStruct((bsz, c_pad, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_c, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, a, b, c, d.reshape(1, -1), h0)
    return y[:, :s, :cdim], h_fin[:, :cdim]
