"""Fused GEMM + decode-attention Pallas kernel — NanoFlow's execution-unit
scheduling adapted to TPU (DESIGN.md §2).

The paper co-schedules a compute-bound GEMM kernel and a memory-bound decode
GEMV kernel on disjoint SM partitions.  A TPU core has no SM pool, but it
*does* have independent MXU pipelines and DMA engines: inside a single
``pallas_call``, each grid step is assigned BOTH one GEMM tile (MXU work) and
one decode-attention unit (a (batch-row, kv-seq-block) whose K/V block is a
pure DMA stream).  Pallas double-buffers block DMA across grid steps, so the
KV-cache stream of step g+1 is in flight while step g's GEMM tile occupies
the MXU — the same "keep the bottleneck resource busy" effect, with a
*static* partition instead of the paper's interference-prone multi-stream
launch.

The ``gemm_fraction`` knob (set by core/autosearch) picks the GEMM tile size,
i.e. the MXU-work : DMA-work ratio per grid step — the TPU analogue of the
paper's SM-count ratio.

Grid: (T,) with T = max(gemm_tiles, attn_units); attention units are ordered
seq-minor per batch row so the running-softmax scratch carries across a
row's kv sweep.

VMEM per step (bf16): x (bm, K) + w (K, bn) + out (bm, bn)
  + kv (1, bs, KV·D) ·2 + attn scratch f32.  With bm=bn=256, K=4096, bs=256,
  KV·D=1024: ≈ 4.5 MB — fits v5e VMEM with double buffering.
"""
from __future__ import annotations

import functools
import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

NEG_INF = -1e30


def _kernel(len_ref, x_ref, w_ref, q_ref, k_ref, v_ref,
            gemm_out_ref, attn_out_ref, m_ref, l_ref, acc_ref, *,
            scale: float, n_gemm: int, n_attn: int, n_sb: int, block_s: int,
            batch: int):
    g = pl.program_id(0)

    # ---- GEMM tile (MXU stream) ----
    @pl.when(g < n_gemm)
    def _gemm():
        gemm_out_ref[...] = jnp.dot(
            x_ref[...], w_ref[...],
            preferred_element_type=jnp.float32).astype(gemm_out_ref.dtype)

    # ---- decode-attention unit (DMA stream) ----
    @pl.when(g < n_attn)
    def _attn():
        row = g // n_sb
        sb = g % n_sb

        @pl.when(sb == 0)
        def _init():
            m_ref[...] = jnp.full_like(m_ref, NEG_INF)
            l_ref[...] = jnp.zeros_like(l_ref)
            acc_ref[...] = jnp.zeros_like(acc_ref)

        q = q_ref[0].astype(jnp.float32) * scale          # (KV, G, D)
        k = k_ref[0].astype(jnp.float32)                  # (Bs, KV, D)
        v = v_ref[0].astype(jnp.float32)
        s = jnp.einsum("hgd,shd->hgs", q, k)              # (KV, G, Bs)

        kpos = sb * block_s + jax.lax.broadcasted_iota(jnp.int32, s.shape, 2)
        valid = kpos < len_ref[row]
        s = jnp.where(valid, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[..., None] + \
            jnp.einsum("hgs,shd->hgd", p, v)
        m_ref[...] = m_new

        @pl.when(sb == n_sb - 1)
        def _finalize():
            denom = jnp.maximum(l_ref[...], 1e-30)[..., None]
            attn_out_ref[0] = (acc_ref[...] / denom).astype(attn_out_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "gemm_fraction", "block_m", "block_n", "block_s", "interpret"))
def fused_overlap(x: jax.Array, w: jax.Array, q: jax.Array,
                  k_cache: jax.Array, v_cache: jax.Array,
                  cache_len: jax.Array, *, gemm_fraction: float = 0.5,
                  block_m: int = 0, block_n: int = 256, block_s: int = 256,
                  interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """x: (M, K) @ w: (K, N) co-scheduled with decode attention over
    q (B, H, D) × cache (B, S, KV, D).  Returns (gemm_out, attn_out)."""
    m, kdim = x.shape
    _, n = w.shape
    b, h, d = q.shape
    _, s, kvh, _ = k_cache.shape
    group = h // kvh
    scale = d ** -0.5

    # gemm_fraction -> MXU tile size per grid step (the unit-ratio knob)
    if block_m == 0:
        block_m = max(64, int(512 * gemm_fraction) // 64 * 64)
    block_m = min(block_m, max(8, m))
    block_n = min(block_n, max(8, n))
    block_s = min(block_s, max(8, s))

    m_pad = -(-m // block_m) * block_m
    n_pad = -(-n // block_n) * block_n
    s_pad = -(-s // block_s) * block_s
    if m_pad != m:
        x = jnp.pad(x, ((0, m_pad - m), (0, 0)))
    if n_pad != n:
        w = jnp.pad(w, ((0, 0), (0, n_pad - n)))
    if s_pad != s:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, s_pad - s), (0, 0), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, s_pad - s), (0, 0), (0, 0)))

    n_mi, n_ni = m_pad // block_m, n_pad // block_n
    n_gemm = n_mi * n_ni
    n_sb = s_pad // block_s
    n_attn = b * n_sb
    t = max(n_gemm, n_attn)

    qf = q.reshape(b, kvh, group, d)

    def x_map(g):
        return (jnp.minimum(g // n_ni, n_mi - 1), 0)

    def w_map(g):
        return (0, jnp.minimum(g % n_ni, n_ni - 1))

    def out_map(g):
        return (jnp.minimum(g // n_ni, n_mi - 1),
                jnp.minimum(g % n_ni, n_ni - 1))

    def q_map(g):
        return (jnp.minimum(g // n_sb, b - 1), 0, 0, 0)

    def kv_map(g):
        return (jnp.minimum(g // n_sb, b - 1), g % n_sb, 0, 0)

    gemm_out, attn_out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, n_gemm=n_gemm, n_attn=n_attn,
                          n_sb=n_sb, block_s=block_s, batch=b),
        grid=(t,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),            # cache_len
            pl.BlockSpec((block_m, kdim), x_map),
            pl.BlockSpec((kdim, block_n), w_map),
            pl.BlockSpec((1, kvh, group, d), q_map),
            pl.BlockSpec((1, block_s, kvh, d), kv_map),
            pl.BlockSpec((1, block_s, kvh, d), kv_map),
        ],
        out_specs=[
            pl.BlockSpec((block_m, block_n), out_map),
            pl.BlockSpec((1, kvh, group, d), q_map),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((m_pad, n_pad), x.dtype),
            jax.ShapeDtypeStruct((b, kvh, group, d), q.dtype),
        ],
        scratch_shapes=[
            pltpu.VMEM((kvh, group), jnp.float32),
            pltpu.VMEM((kvh, group), jnp.float32),
            pltpu.VMEM((kvh, group, d), jnp.float32),
        ],
        interpret=interpret,
    )(cache_len, x, w, qf, k_cache, v_cache)

    return gemm_out[:m, :n], attn_out.reshape(b, h, d)
