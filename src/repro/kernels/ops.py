"""Jitted kernel entry points with implementation dispatch.

``impl`` selects the execution path:
  * ``"ref"``       — pure-jnp oracle (differentiable; the XLA/GSPMD path used
                      on CPU and inside the dry-run lowering)
  * ``"pallas"``    — TPU Pallas kernel (compiled; requires TPU backend)
  * ``"interpret"`` — Pallas kernel body interpreted on CPU (kernel tests)

Default comes from ``set_default_impl`` / env REPRO_KERNEL_IMPL, falling back
to "ref" on non-TPU backends and "pallas" on TPU.
"""
from __future__ import annotations

import contextlib
import os
from typing import Optional

import jax

from repro.kernels import ref as _ref

_DEFAULT: Optional[str] = None


def set_default_impl(impl: Optional[str]) -> None:
    global _DEFAULT
    _DEFAULT = impl


def default_impl() -> str:
    if _DEFAULT is not None:
        return _DEFAULT
    env = os.environ.get("REPRO_KERNEL_IMPL")
    if env:
        return env
    return "pallas" if jax.default_backend() == "tpu" else "ref"


def _resolve(impl: Optional[str]) -> str:
    return impl if impl is not None else default_impl()


# ---------------------------------------------------------------------------
# attention ref variants (§Perf HC3): explicit arguments with env fallback.
#
# These used to be read straight from the environment *at trace time inside
# jitted code* — a retrace footgun: flipping the env var between calls
# silently changes what an already-cached program means on the next compile.
# They are now explicit arguments (per-call kwarg > pinned module default >
# env).  ``ServeEngine`` resolves them ONCE at construction and pins them
# around its jitted bodies with ``attn_config``, so every retrace of an
# engine's programs sees the same values regardless of later env mutation.
# ---------------------------------------------------------------------------
_ATTN_FAST: Optional[bool] = None
_ATTN_STREAM: Optional[bool] = None


def attn_fast_default() -> bool:
    """No-upcast attention refs (see kernels/ref.py)."""
    if _ATTN_FAST is not None:
        return _ATTN_FAST
    return os.environ.get("REPRO_ATTN_FAST", "0") == "1"


def attn_stream_default() -> bool:
    """Streamed long-sequence flash ref (see kernels/ref.py)."""
    if _ATTN_STREAM is not None:
        return _ATTN_STREAM
    return os.environ.get("REPRO_ATTN_STREAM", "0") == "1"


@contextlib.contextmanager
def attn_config(*, fast: Optional[bool] = None, stream: Optional[bool] = None):
    """Pin the fast/stream defaults for the duration (engine trace bodies)."""
    global _ATTN_FAST, _ATTN_STREAM
    prev = (_ATTN_FAST, _ATTN_STREAM)
    if fast is not None:
        _ATTN_FAST = fast
    if stream is not None:
        _ATTN_STREAM = stream
    try:
        yield
    finally:
        _ATTN_FAST, _ATTN_STREAM = prev


def _attn_fast(explicit: Optional[bool] = None) -> bool:
    return explicit if explicit is not None else attn_fast_default()


def _attn_stream(explicit: Optional[bool] = None) -> bool:
    return explicit if explicit is not None else attn_stream_default()


# ---------------------------------------------------------------------------
def flash_attention(q, k, v, *, causal=True, logit_scale=None, q_offset=0,
                    impl: Optional[str] = None, fast: Optional[bool] = None,
                    stream: Optional[bool] = None):
    impl = _resolve(impl)
    # The Pallas kernel takes q_offset as a *static* int (chunked prefill
    # passes a traced per-row offset so one compiled program serves every
    # prefix depth) and assumes v's head dim equals q/k's (absorbed MLA has
    # d_qk = rank + rope but d_v = rank) — route both cases to the XLA ref
    # path, which runs on every backend including TPU.
    if impl != "ref" and (not isinstance(q_offset, int)
                          or v.shape[-1] != q.shape[-1]):
        impl = "ref"
    if impl == "ref":
        if _attn_stream(stream) and q.shape[1] > 512:
            return _ref.flash_attention_stream(
                q, k, v, causal=causal, logit_scale=logit_scale,
                q_offset=q_offset)
        fn = _ref.flash_attention_fast if _attn_fast(fast) \
            else _ref.flash_attention_ref
        return fn(q, k, v, causal=causal, logit_scale=logit_scale,
                  q_offset=q_offset)
    from repro.kernels import flash_attention as _fa
    return _fa.flash_attention(q, k, v, causal=causal, logit_scale=logit_scale,
                               q_offset=q_offset, interpret=(impl == "interpret"))


def decode_attention(q, k_cache, v_cache, cache_len, *, logit_scale=None,
                     impl: Optional[str] = None, fast: Optional[bool] = None):
    impl = _resolve(impl)
    # The Pallas kernel assumes v's head dim equals q/k's; absorbed MLA
    # attends with d_qk = rank + rope but d_v = rank — route the mismatched
    # case to the XLA ref path (correct on every backend).
    if impl != "ref" and v_cache.shape[-1] != q.shape[-1]:
        impl = "ref"
    if impl == "ref":
        fn = _ref.decode_attention_fast if _attn_fast(fast) \
            else _ref.decode_attention_ref
        return fn(q, k_cache, v_cache, cache_len, logit_scale=logit_scale)
    from repro.kernels import decode_attention as _da
    return _da.decode_attention(q, k_cache, v_cache, cache_len,
                                logit_scale=logit_scale,
                                interpret=(impl == "interpret"))


def packed_attention(q, k_cache, v_cache, token_slot, lengths, *,
                     logit_scale=None, kv_bucket: Optional[int] = None,
                     block_tables=None, k_scale=None, v_scale=None,
                     impl: Optional[str] = None, fast: Optional[bool] = None):
    """Segment-masked attention over a token-packed stream (DESIGN.md §8):
    token t attends rows [0, lengths[t]) of slot ``token_slot[t]``'s cache.

    ``kv_bucket`` (static) bounds the swept cache extent — the engine passes
    the iteration's KV-length bucket so work scales with actual context, not
    ``max_len`` (DESIGN.md §9).  The Pallas kernel gathers each token's slot
    rows block-wise via scalar-prefetch indexing and handles the absorbed-MLA
    ``d_v != d_qk`` case natively, so no silent ref downgrade here.

    ``block_tables`` (optional, DESIGN.md §12): block-table mode — the
    caches are physical block storage and every gather is routed through
    the per-slot table (index-map dereference in the Pallas kernel, dense
    per-slot gather in the refs).

    ``k_scale``/``v_scale`` (optional, (N_slots, S, KV) f32, DESIGN.md §15):
    int8 caches — every impl dequantizes after the int8 read (in-register
    in the Pallas kernel, dense in the refs)."""
    impl = _resolve(impl)
    if impl == "ref":
        fn = _ref.packed_attention_fast if _attn_fast(fast) \
            else _ref.packed_attention_ref
        return fn(q, k_cache, v_cache, token_slot, lengths,
                  logit_scale=logit_scale, kv_bucket=kv_bucket,
                  block_tables=block_tables, k_scale=k_scale,
                  v_scale=v_scale)
    from repro.kernels import packed_attention as _pa
    return _pa.packed_attention(q, k_cache, v_cache, token_slot, lengths,
                                logit_scale=logit_scale, kv_bucket=kv_bucket,
                                block_tables=block_tables, k_scale=k_scale,
                                v_scale=v_scale,
                                interpret=(impl == "interpret"))


def paged_decode_attention(q, k_pages, v_pages, page_table, cache_len, *,
                           logit_scale=None, impl: Optional[str] = None):
    impl = _resolve(impl)
    if impl == "ref":
        return _ref.paged_decode_attention_ref(q, k_pages, v_pages, page_table,
                                               cache_len, logit_scale=logit_scale)
    from repro.kernels import decode_attention as _da
    return _da.paged_decode_attention(q, k_pages, v_pages, page_table,
                                      cache_len, logit_scale=logit_scale,
                                      interpret=(impl == "interpret"))


def fused_overlap(x, w, q, k_cache, v_cache, cache_len, *,
                  gemm_fraction: float = 0.5, impl: Optional[str] = None):
    """NanoFlow signature op: GEMM co-scheduled with decode attention.

    ``gemm_fraction`` — fraction of grid steps assigned to GEMM tiles (the
    TPU analogue of the paper's SM-partition ratio; autosearch sets it)."""
    impl = _resolve(impl)
    if impl == "ref":
        return _ref.fused_overlap_ref(x, w, q, k_cache, v_cache, cache_len)
    from repro.kernels import fused_overlap as _fo
    return _fo.fused_overlap(x, w, q, k_cache, v_cache, cache_len,
                             gemm_fraction=gemm_fraction,
                             interpret=(impl == "interpret"))


def ssm_scan(x, dt, a, b, c, d, h0=None, *, impl: Optional[str] = None):
    impl = _resolve(impl)
    if impl == "ref":
        return _ref.ssm_scan_ref(x, dt, a, b, c, d, h0)
    from repro.kernels import ssm_scan as _ss
    return _ss.ssm_scan(x, dt, a, b, c, d, h0, interpret=(impl == "interpret"))
