"""Pallas TPU fused SwiGLU: silu(x@w_gate) * (x@w_up) in one kernel.

Why: the unfused form writes two (M, d_ff) intermediates to HBM and reads
them back for the elementwise combine — at d_ff=24576 (jamba) that is 3×
the FFN's activation traffic.  Fusing keeps both partial products in VMEM
accumulators; HBM sees only x, the weights, and the single output.

Grid: (m_blocks, n_blocks, k_blocks) — k minor, so the two f32 accumulators
persist across the contraction sweep; silu+mul applied once at the last k.

VMEM per step (bf16, bm=bn=256, bk=512):
  x (256,512) + wg,wu (512,256)·2 + acc f32 (256,256)·2 ≈ 1.3 MB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu


def _kernel(x_ref, wg_ref, wu_ref, o_ref, acc_g, acc_u):
    kb = pl.program_id(2)
    nkb = pl.num_programs(2)

    @pl.when(kb == 0)
    def _init():
        acc_g[...] = jnp.zeros_like(acc_g)
        acc_u[...] = jnp.zeros_like(acc_u)

    x = x_ref[...]
    acc_g[...] += jnp.dot(x, wg_ref[...], preferred_element_type=jnp.float32)
    acc_u[...] += jnp.dot(x, wu_ref[...], preferred_element_type=jnp.float32)

    @pl.when(kb == nkb - 1)
    def _finalize():
        g = acc_g[...]
        o_ref[...] = (g * jax.nn.sigmoid(g) * acc_u[...]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "interpret"))
def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array, *,
           block_m: int = 256, block_n: int = 256, block_k: int = 512,
           interpret: bool = False) -> jax.Array:
    """x: (M, K); w_gate/w_up: (K, N) -> (M, N)."""
    m, k = x.shape
    _, n = w_gate.shape
    assert w_up.shape == (k, n)

    bm, bn, bk = (min(block_m, max(8, m)), min(block_n, max(8, n)),
                  min(block_k, max(8, k)))
    mp, np_, kp = -(-m // bm) * bm, -(-n // bn) * bn, -(-k // bk) * bk
    if mp != m or kp != k:
        x = jnp.pad(x, ((0, mp - m), (0, kp - k)))
    if kp != k or np_ != n:
        w_gate = jnp.pad(w_gate, ((0, kp - k), (0, np_ - n)))
        w_up = jnp.pad(w_up, ((0, kp - k), (0, np_ - n)))

    out = pl.pallas_call(
        _kernel,
        grid=(mp // bm, np_ // bn, kp // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), x.dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.float32),
                        pltpu.VMEM((bm, bn), jnp.float32)],
        interpret=interpret,
    )(x, w_gate, w_up)
    return out[:m, :n]


def swiglu_ref(x: jax.Array, w_gate: jax.Array, w_up: jax.Array) -> jax.Array:
    g = jnp.dot(x, w_gate, preferred_element_type=jnp.float32)
    u = jnp.dot(x, w_up, preferred_element_type=jnp.float32)
    return (g * jax.nn.sigmoid(g) * u).astype(x.dtype)
