"""Pallas TPU decode attention (the paper's memory-bound GEMV op).

Dense variant: grid (B·KV, seq_blocks) — seq minor so the per-(batch, kv
head) running-softmax scratch persists across the KV-cache sweep.  All G
query heads of a kv head are processed together (they share the streamed
K/V block, amortizing the HBM read exactly like the GQA GEMV in the paper's
Table 2).

Paged variant: same schedule, but K/V live in a global page pool and the
BlockSpec index map dereferences a scalar-prefetch page table — the TPU
analogue of PagedAttention's block tables (DESIGN.md §2: page aggregation
happens at the index-map level; no gather materialization).

VMEM per step (bf16, Bk=256, D=128, G≤16):
  k,v (256, 128)·2 + q (G, 128) + acc f32 (G, 128) ≈ 0.2 MB.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

NEG_INF = -1e30
DEFAULT_BLOCK_K = 256


def _kernel(len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, block_k: int, batch: int):
    bkv = pl.program_id(0)
    sb = pl.program_id(1)
    nsb = pl.num_programs(1)
    b = bkv // (pl.num_programs(0) // batch)

    @pl.when(sb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale                  # (G, D)
    k = k_ref[0].astype(jnp.float32)                          # (Bk, D)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)   # (G, Bk)

    kpos = sb * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    valid = kpos < len_ref[b]
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + \
        jnp.dot(p, v_ref[0].astype(jnp.float32),
                preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(sb == nsb - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("logit_scale", "block_k",
                                             "interpret"))
def decode_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     cache_len: jax.Array, *,
                     logit_scale: Optional[float] = None,
                     block_k: int = DEFAULT_BLOCK_K,
                     interpret: bool = False) -> jax.Array:
    """q: (B, H, D); k_cache/v_cache: (B, S, KV, D); cache_len: (B,)."""
    b, h, d = q.shape
    _, s, kvh, _ = k_cache.shape
    group = h // kvh
    scale = logit_scale if logit_scale is not None else d ** -0.5

    block_k = min(block_k, max(8, s))
    s_pad = -(-s // block_k) * block_k
    if s_pad != s:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, s_pad - s), (0, 0), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, s_pad - s), (0, 0), (0, 0)))

    qf = q.reshape(b * kvh, group, d)
    kf = k_cache.transpose(0, 2, 1, 3).reshape(b * kvh, s_pad, d)
    vf = v_cache.transpose(0, 2, 1, 3).reshape(b * kvh, s_pad, d)

    grid = (b * kvh, s_pad // block_k)
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, block_k=block_k, batch=b),
        grid=grid,
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),   # cache_len
            pl.BlockSpec((1, group, d), lambda bk, sb: (bk, 0, 0)),
            pl.BlockSpec((1, block_k, d), lambda bk, sb: (bk, sb, 0)),
            pl.BlockSpec((1, block_k, d), lambda bk, sb: (bk, sb, 0)),
        ],
        out_specs=pl.BlockSpec((1, group, d), lambda bk, sb: (bk, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b * kvh, group, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((group,), jnp.float32),
            pltpu.VMEM((group,), jnp.float32),
            pltpu.VMEM((group, d), jnp.float32),
        ],
        interpret=interpret,
    )(cache_len, qf, kf, vf)
    return out.reshape(b, h, d)


# ---------------------------------------------------------------------------
# paged variant: page-table indirection in the BlockSpec index map
# ---------------------------------------------------------------------------
def _paged_kernel(page_table_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                  m_ref, l_ref, acc_ref, *, scale: float, page_size: int):
    bkv = pl.program_id(1)
    pi = pl.program_id(2)
    npi = pl.num_programs(2)
    b = pl.program_id(0)

    @pl.when(pi == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32) * scale               # (G, D)
    k = k_ref[0, 0].astype(jnp.float32)                       # (PS, D)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)   # (G, PS)

    kpos = pi * page_size + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    valid = (kpos < len_ref[b]) & (page_table_ref[b, pi] >= 0)
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + \
        jnp.dot(p, v_ref[0, 0].astype(jnp.float32),
                preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(pi == npi - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("logit_scale", "interpret"))
def paged_decode_attention(q: jax.Array, k_pages: jax.Array,
                           v_pages: jax.Array, page_table: jax.Array,
                           cache_len: jax.Array, *,
                           logit_scale: Optional[float] = None,
                           interpret: bool = False) -> jax.Array:
    """q: (B,H,D); pages: (NP, PS, KV, D); page_table: (B, MAXP) (-1 unused)."""
    b, h, d = q.shape
    np_, ps, kvh, _ = k_pages.shape
    maxp = page_table.shape[1]
    group = h // kvh
    scale = logit_scale if logit_scale is not None else d ** -0.5

    qf = q.reshape(b, kvh, group, d)
    # (NP, PS, KV, D) -> (KV, NP, PS, D): page dim indexable per kv head
    kf = k_pages.transpose(2, 0, 1, 3)
    vf = v_pages.transpose(2, 0, 1, 3)
    safe_table = jnp.maximum(page_table, 0)

    grid = (b, kvh, maxp)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,          # page_table, cache_len
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, group, d), lambda bb, kv, pi, pt, ln: (bb, kv, 0, 0)),
            pl.BlockSpec((1, 1, ps, d),
                         lambda bb, kv, pi, pt, ln: (kv, pt[bb, pi], 0, 0)),
            pl.BlockSpec((1, 1, ps, d),
                         lambda bb, kv, pi, pt, ln: (kv, pt[bb, pi], 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, group, d),
                               lambda bb, kv, pi, pt, ln: (bb, kv, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((group,), jnp.float32),
            pltpu.VMEM((group,), jnp.float32),
            pltpu.VMEM((group, d), jnp.float32),
        ],
    )
    out = pl.pallas_call(
        functools.partial(_paged_kernel, scale=scale, page_size=ps),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, kvh, group, d), q.dtype),
        interpret=interpret,
    )(safe_table, cache_len, qf,
      kf.reshape(kvh, np_, ps, d), vf.reshape(kvh, np_, ps, d))
    return out.reshape(b, h, d)
