"""Pure-jnp reference oracles for every Pallas kernel.

These are the *semantics* of each kernel: differentiable, shardable under
GSPMD, and used (a) as the model's XLA execution path on CPU / in the dry-run
and (b) as the ground truth for kernel `interpret=True` allclose sweeps.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def causal_qmask(sq: int, skv: int, q_offset: int | jax.Array) -> jax.Array:
    """(B|1, 1, 1, Sq, Skv) causal mask for chunked-prefill attention.

    ``q_offset`` — absolute position of q[0] relative to k[0] — may be a
    scalar (shared by all batch rows) or a per-row ``(B,)`` array (the
    engine's incremental prefill runs different slots at different prefix
    depths).  Broadcasts against ``(B, KV, G, Sq, Skv)`` scores.
    """
    qo = jnp.asarray(q_offset, jnp.int32).reshape(-1, 1)          # (B|1, 1)
    qpos = qo + jnp.arange(sq, dtype=jnp.int32)[None, :]          # (B|1, Sq)
    kpos = jnp.arange(skv, dtype=jnp.int32)
    return (qpos[:, :, None] >= kpos[None, None, :])[:, None, None]


# ---------------------------------------------------------------------------
# attention (prefill / train): causal GQA
# ---------------------------------------------------------------------------
def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                        causal: bool = True,
                        logit_scale: Optional[float] = None,
                        q_offset: int | jax.Array = 0) -> jax.Array:
    """q: (B, Sq, H, D); k, v: (B, Skv, KV, D) with H = KV * group.

    ``q_offset``: absolute position of q[0] relative to k[0] (chunked prefill
    attends to earlier cache positions non-causally); scalar or per-row (B,).
    """
    b, sq, h, d = q.shape
    _, skv, kv, _ = k.shape
    dv = v.shape[-1]                 # may differ from d (e.g. MLA)
    assert h % kv == 0, (h, kv)
    group = h // kv
    scale = logit_scale if logit_scale is not None else d ** -0.5

    qg = q.reshape(b, sq, kv, group, d).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kf) * scale  # (B,KV,G,Sq,Skv)
    if causal:
        scores = jnp.where(causal_qmask(sq, skv, q_offset), scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v.astype(jnp.float32))
    return out.reshape(b, sq, h, dv).astype(q.dtype)


# ---------------------------------------------------------------------------
# attention (decode): one new token vs a length-masked KV cache
# ---------------------------------------------------------------------------
def decode_attention_ref(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                         cache_len: jax.Array, *,
                         logit_scale: Optional[float] = None) -> jax.Array:
    """q: (B, H, D); k_cache/v_cache: (B, S, KV, D); cache_len: (B,) int32 —
    number of valid positions (the new token's KV must already be written, so
    positions [0, cache_len) are attended)."""
    b, h, d = q.shape
    _, s, kv, _ = k_cache.shape
    dv = v_cache.shape[-1]
    group = h // kv
    scale = logit_scale if logit_scale is not None else d ** -0.5

    qg = q.reshape(b, kv, group, d).astype(jnp.float32)
    scores = jnp.einsum("bhgd,bkhd->bhgk", qg, k_cache.astype(jnp.float32)) * scale
    valid = jnp.arange(s)[None, :] < cache_len[:, None]          # (B,S)
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgk,bkhv->bhgv", probs, v_cache.astype(jnp.float32))
    return out.reshape(b, h, dv).astype(q.dtype)


def _kv_bucket_view(k_cache: jax.Array, v_cache: jax.Array,
                    kv_bucket: Optional[int]):
    """Static slice of the slot caches to the iteration's KV-length bucket
    (DESIGN.md §9).  The caller guarantees ``max(lengths) <= kv_bucket``;
    rows at or beyond the bucket are never attended, so slicing them off is
    exact — and the einsums below then read/compute O(kv_bucket) per slot
    instead of O(max_len)."""
    if kv_bucket is not None and kv_bucket < k_cache.shape[1]:
        k_cache = jax.lax.slice_in_dim(k_cache, 0, kv_bucket, axis=1)
        v_cache = jax.lax.slice_in_dim(v_cache, 0, kv_bucket, axis=1)
    return k_cache, v_cache


def _block_gather_view(cache: jax.Array, block_tables: jax.Array,
                       kv_bucket: Optional[int]) -> jax.Array:
    """Per-slot contiguous view of a block-table cache (DESIGN.md §12).

    ``cache``: (N_slots, S, ...) physical storage whose *flat* row space
    (N·S rows) is carved into fixed-size blocks; ``block_tables``:
    (N_slots, S // block_size) int32 — physical block id backing each
    slot's logical block.  Gathers the first ``kv_bucket`` logical rows of
    every slot back into (N_slots, kv_bucket, ...), after which the dense
    packed-attention math is unchanged (the Pallas kernel instead gathers
    block-wise at the index-map level and never materializes this view)."""
    n, s = cache.shape[0], cache.shape[1]
    nb_cols = block_tables.shape[1]
    bs = s // nb_cols
    sweep = s if kv_bucket is None or kv_bucket > s else kv_bucket
    nbk = sweep // bs
    flat = cache.reshape((n * nb_cols, bs) + cache.shape[2:])
    view = flat[block_tables[:, :nbk]]              # (N, nbk, bs, ...)
    return view.reshape((n, nbk * bs) + cache.shape[2:])


def _dequant_views(k_cache, v_cache, k_scale, v_scale, block_tables,
                   kv_bucket):
    """Apply the bucket / block-table view to the caches (and scale leaves,
    when quantized), then dequantize to f32 for the dense sweep."""
    if block_tables is not None:
        k_cache = _block_gather_view(k_cache, block_tables, kv_bucket)
        v_cache = _block_gather_view(v_cache, block_tables, kv_bucket)
        if k_scale is not None:
            k_scale = _block_gather_view(k_scale, block_tables, kv_bucket)
            v_scale = _block_gather_view(v_scale, block_tables, kv_bucket)
    else:
        k_cache, v_cache = _kv_bucket_view(k_cache, v_cache, kv_bucket)
        if k_scale is not None:
            k_scale, v_scale = _kv_bucket_view(k_scale, v_scale, kv_bucket)
    if k_scale is not None:
        k_cache = k_cache.astype(jnp.float32) * k_scale[..., None]
        v_cache = v_cache.astype(jnp.float32) * v_scale[..., None]
    return k_cache, v_cache


def packed_attention_ref(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                         token_slot: jax.Array, lengths: jax.Array, *,
                         logit_scale: Optional[float] = None,
                         kv_bucket: Optional[int] = None,
                         block_tables: Optional[jax.Array] = None,
                         k_scale: Optional[jax.Array] = None,
                         v_scale: Optional[jax.Array] = None
                         ) -> jax.Array:
    """Segment-masked attention for the token-packed dense-batch step
    (DESIGN.md §8): every token of a packed ``(T,)`` stream attends its own
    slot's cache rows ``[0, lengths[t])`` and nothing else.

    q: (T, H, D) packed queries; k_cache/v_cache: (N_slots, S, KV, D/Dv)
    slot caches (the packed step scatters each token's K/V at its
    ``(slot, position)`` before calling this); token_slot: (T,) int32 slot
    per token; lengths: (T,) int32 = position + 1 per token; kv_bucket:
    static bound on ``max(lengths)`` — only that many cache rows are read
    (KV-length bucketing, DESIGN.md §9), ``None`` means the full cache.

    Segments never attend across each other: slot selection restricts each
    query to its own request's cache, and the length mask is exactly the
    causal mask because a segment's K/V occupies positions ``[0, pos]``.

    Shape strategy: scores/contexts are computed dense against *all* slots
    (over the kv_bucket rows) and selected per token, rather than gathering
    each token's ``(S, ...)`` cache — the caches are then read once per
    einsum instead of once per token (T-fold less traffic; N_slots is
    small, so the extra FLOPs are noise next to the dense GEMMs).  The
    Pallas kernel (kernels/packed_attention.py) gathers block-wise instead,
    through the same call sites.

    ``block_tables`` (optional, DESIGN.md §12): block-table mode — the
    caches are physical block storage and each slot's logical rows are
    gathered through its table before the dense sweep.

    ``k_scale``/``v_scale`` (optional, (N_slots, S, KV) f32, DESIGN.md §15):
    int8 caches — the same views apply to the scale leaves and the dense
    sweep dequantizes (``row * scale`` in f32) before the einsums; this is
    the XLA analogue of the Pallas kernel's in-register dequant.
    """
    k_cache, v_cache = _dequant_views(
        k_cache, v_cache, k_scale, v_scale, block_tables, kv_bucket)
    t, h, d = q.shape
    n, s, kv, _ = k_cache.shape
    dv = v_cache.shape[-1]
    group = h // kv
    scale = logit_scale if logit_scale is not None else d ** -0.5

    qg = q.reshape(t, kv, group, d).astype(jnp.float32)
    scores_all = jnp.einsum("tkgd,nskd->tnkgs", qg,
                            k_cache.astype(jnp.float32)) * scale
    idx = token_slot.reshape(t, 1, 1, 1, 1)
    scores = jnp.take_along_axis(scores_all, idx, axis=1)[:, 0]  # (T,KV,G,S)
    valid = jnp.arange(s)[None, :] < lengths[:, None]            # (T,S)
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx_all = jnp.einsum("tkgs,nskv->tnkgv", probs,
                         v_cache.astype(jnp.float32))
    out = jnp.take_along_axis(ctx_all, idx, axis=1)[:, 0]        # (T,KV,G,Dv)
    return out.reshape(t, h, dv).astype(q.dtype)


def packed_attention_fast(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                          token_slot: jax.Array, lengths: jax.Array, *,
                          logit_scale: Optional[float] = None,
                          kv_bucket: Optional[int] = None,
                          block_tables: Optional[jax.Array] = None,
                          k_scale: Optional[jax.Array] = None,
                          v_scale: Optional[jax.Array] = None
                          ) -> jax.Array:
    """No-upcast variant of ``packed_attention_ref`` (§Perf HC3): same
    math, bf16 einsum operands with f32 in-register accumulation (int8
    caches dequantize to f32 first — the scale multiply *is* the upcast)."""
    k_cache, v_cache = _dequant_views(
        k_cache, v_cache, k_scale, v_scale, block_tables, kv_bucket)
    t, h, d = q.shape
    n, s, kv, _ = k_cache.shape
    dv = v_cache.shape[-1]
    group = h // kv
    scale = logit_scale if logit_scale is not None else d ** -0.5

    qg = q.reshape(t, kv, group, d)
    scores_all = jnp.einsum("tkgd,nskd->tnkgs", qg, k_cache,
                            preferred_element_type=jnp.float32) * scale
    idx = token_slot.reshape(t, 1, 1, 1, 1)
    scores = jnp.take_along_axis(scores_all, idx, axis=1)[:, 0]
    valid = jnp.arange(s)[None, :] < lengths[:, None]
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v_cache.dtype)
    ctx_all = jnp.einsum("tkgs,nskv->tnkgv", probs, v_cache,
                         preferred_element_type=jnp.float32)
    out = jnp.take_along_axis(ctx_all, idx, axis=1)[:, 0]
    return out.reshape(t, h, dv).astype(q.dtype)


def paged_decode_attention_ref(q: jax.Array, k_pages: jax.Array,
                               v_pages: jax.Array, page_table: jax.Array,
                               cache_len: jax.Array, *,
                               logit_scale: Optional[float] = None) -> jax.Array:
    """Paged KV: k_pages/v_pages (NP, PS, KV, D) global page pool;
    page_table (B, MAXP) int32 page ids (-1 = unused); cache_len (B,)."""
    b = q.shape[0]
    np_, ps, kvh, d = k_pages.shape
    maxp = page_table.shape[1]
    safe = jnp.maximum(page_table, 0)
    k = k_pages[safe]                              # (B, MAXP, PS, KV, D)
    v = v_pages[safe]
    k = k.reshape(b, maxp * ps, kvh, d)
    v = v.reshape(b, maxp * ps, kvh, d)
    return decode_attention_ref(q, k, v, cache_len, logit_scale=logit_scale)


# ---------------------------------------------------------------------------
# "fast" attention variants (§Perf HC3): identical math, but the big K/V
# tensors are NOT pre-upcast with .astype(f32) — the einsums take bf16
# operands with preferred_element_type=f32 (MXU-style in-register
# accumulation), so XLA never materializes an f32 copy of the KV cache /
# activations.  Enabled via env REPRO_ATTN_FAST=1 (kernels/ops.py).
# ---------------------------------------------------------------------------
def flash_attention_fast(q: jax.Array, k: jax.Array, v: jax.Array, *,
                         causal: bool = True,
                         logit_scale: Optional[float] = None,
                         q_offset: int | jax.Array = 0) -> jax.Array:
    b, sq, h, d = q.shape
    _, skv, kv, _ = k.shape
    dv = v.shape[-1]
    group = h // kv
    scale = logit_scale if logit_scale is not None else d ** -0.5
    qg = q.reshape(b, sq, kv, group, d)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k,
                        preferred_element_type=jnp.float32) * scale
    if causal:
        scores = jnp.where(causal_qmask(sq, skv, q_offset), scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bhgqk,bkhv->bqhgv", probs, v,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, sq, h, dv).astype(q.dtype)


def decode_attention_fast(q: jax.Array, k_cache: jax.Array,
                          v_cache: jax.Array, cache_len: jax.Array, *,
                          logit_scale: Optional[float] = None) -> jax.Array:
    b, h, d = q.shape
    _, s, kv, _ = k_cache.shape
    dv = v_cache.shape[-1]
    group = h // kv
    scale = logit_scale if logit_scale is not None else d ** -0.5
    qg = q.reshape(b, kv, group, d)
    scores = jnp.einsum("bhgd,bkhd->bhgk", qg, k_cache,
                        preferred_element_type=jnp.float32) * scale
    valid = jnp.arange(s)[None, :] < cache_len[:, None]
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(v_cache.dtype)
    out = jnp.einsum("bhgk,bkhv->bhgv", probs, v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(b, h, dv).astype(q.dtype)


# ---------------------------------------------------------------------------
# streaming (flash-style) attention in pure XLA: lax.scan over KV blocks
# with running (m, l, acc).  Never materializes the (Sq, Skv) score matrix —
# peak intermediate is (Sq, block).  Differentiable (bwd recomputes per
# block).  This is the XLA-path analogue of the Pallas flash kernel, used
# for long-sequence prefill/train cells (REPRO_ATTN_STREAM=1).
# ---------------------------------------------------------------------------
def flash_attention_stream(q: jax.Array, k: jax.Array, v: jax.Array, *,
                           causal: bool = True,
                           logit_scale: Optional[float] = None,
                           q_offset: int | jax.Array = 0,
                           block: int = 1024) -> jax.Array:
    b, sq, h, d = q.shape
    _, skv, kv, _ = k.shape
    dv = v.shape[-1]
    group = h // kv
    scale = logit_scale if logit_scale is not None else d ** -0.5

    blk = min(block, skv)
    pad = (-skv) % blk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nb = (skv + pad) // blk

    qg = (q.reshape(b, sq, kv, group, d).astype(jnp.float32) * scale)
    kb = k.reshape(b, nb, blk, kv, d).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, nb, blk, kv, dv).transpose(1, 0, 2, 3, 4)
    qo = jnp.asarray(q_offset, jnp.int32).reshape(-1, 1)          # (B|1, 1)
    qpos = qo + jnp.arange(sq, dtype=jnp.int32)[None, :]          # (B|1, Sq)

    def body(carry, inp):
        m, l, acc = carry
        kc, vc, start = inp                              # (B,blk,KV,*), scalar
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, kc.astype(jnp.float32))
        kpos = start + jnp.arange(blk)[None, :]          # (1, blk)
        mask = jnp.broadcast_to(kpos < skv, qpos.shape[:1] + (sq, blk))
        if causal:
            mask = mask & (qpos[:, :, None] >= kpos[None])
        s = jnp.where(mask[:, None, None], s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[..., None])
        alpha = jnp.exp(m - m_new)
        l = l * alpha + jnp.sum(p, axis=-1)
        acc = acc * alpha[..., None] + \
            jnp.einsum("bhgqk,bkhv->bhgqv", p, vc.astype(jnp.float32))
        return (m_new, l, acc), None

    m0 = jnp.full((b, kv, group, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kv, group, sq), jnp.float32)
    a0 = jnp.zeros((b, kv, group, sq, dv), jnp.float32)
    starts = jnp.arange(nb) * blk
    (m, l, acc), _ = jax.lax.scan(body, (m0, l0, a0), (kb, vb, starts))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, dv).astype(q.dtype)


# ---------------------------------------------------------------------------
# fused overlap: dense GEMM co-scheduled with decode attention (NanoFlow's
# signature op pair).  Reference = the pair computed independently.
# ---------------------------------------------------------------------------
def fused_overlap_ref(x: jax.Array, w: jax.Array, q: jax.Array,
                      k_cache: jax.Array, v_cache: jax.Array,
                      cache_len: jax.Array) -> tuple[jax.Array, jax.Array]:
    gemm_out = jnp.dot(x, w, preferred_element_type=jnp.float32).astype(x.dtype)
    attn_out = decode_attention_ref(q, k_cache, v_cache, cache_len)
    return gemm_out, attn_out


# ---------------------------------------------------------------------------
# Mamba-1 selective scan
# ---------------------------------------------------------------------------
def ssm_scan_ref(x: jax.Array, dt: jax.Array, a: jax.Array, b: jax.Array,
                 c: jax.Array, d: jax.Array,
                 h0: Optional[jax.Array] = None) -> tuple[jax.Array, jax.Array]:
    """Selective state-space scan.

    x: (B, S, C) inner activations; dt: (B, S, C) positive step sizes;
    a: (C, N) negative-real state matrix; b, c: (B, S, N) input/output
    projections; d: (C,) skip.  Returns (y (B,S,C), h_final (B,C,N)).
    Discretization: h_t = exp(dt*a) h_{t-1} + dt * b_t * x_t ; y = (c_t·h) + d*x.
    """
    bsz, s, ch = x.shape
    n = a.shape[-1]
    if h0 is None:
        h0 = jnp.zeros((bsz, ch, n), jnp.float32)

    x32, dt32 = x.astype(jnp.float32), dt.astype(jnp.float32)
    b32, c32 = b.astype(jnp.float32), c.astype(jnp.float32)
    a32 = a.astype(jnp.float32)

    def step(h, inp):
        xt, dtt, bt, ct = inp                     # (B,C) (B,C) (B,N) (B,N)
        da = jnp.exp(dtt[..., None] * a32)        # (B,C,N)
        h = da * h + (dtt * xt)[..., None] * bt[:, None, :]
        y = jnp.einsum("bcn,bn->bc", h, ct)
        return h, y

    xs = (jnp.moveaxis(x32, 1, 0), jnp.moveaxis(dt32, 1, 0),
          jnp.moveaxis(b32, 1, 0), jnp.moveaxis(c32, 1, 0))
    h_final, ys = jax.lax.scan(step, h0, xs)
    y = jnp.moveaxis(ys, 0, 1) + x32 * d
    return y.astype(x.dtype), h_final


def ssm_step_ref(x_t: jax.Array, dt_t: jax.Array, a: jax.Array, b_t: jax.Array,
                 c_t: jax.Array, d: jax.Array,
                 h: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Single decode step.  x_t, dt_t: (B, C); b_t, c_t: (B, N); h: (B, C, N)."""
    da = jnp.exp(dt_t.astype(jnp.float32)[..., None] * a.astype(jnp.float32))
    h = da * h + (dt_t * x_t).astype(jnp.float32)[..., None] * b_t.astype(jnp.float32)[:, None, :]
    y = jnp.einsum("bcn,bn->bc", h, c_t.astype(jnp.float32)) + x_t.astype(jnp.float32) * d
    return y.astype(x_t.dtype), h


# ---------------------------------------------------------------------------
# mLSTM (xLSTM matrix memory) — chunkwise-parallel reference
# ---------------------------------------------------------------------------
def mlstm_chunk_ref(q: jax.Array, k: jax.Array, v: jax.Array, i_gate: jax.Array,
                    f_gate: jax.Array, *, chunk: int = 64,
                    initial: Optional[tuple] = None
                    ) -> tuple[jax.Array, tuple]:
    """Stabilized mLSTM over (B, S, H, D) q/k/v with (B, S, H) log-space gates.

    i_gate = log-input-gate (pre-exp), f_gate = log-sigmoid(forget preact).
    Sequential reference over time (the chunked Pallas kernel must match).
    Returns (y (B,S,H,Dv), (C, n, m) final state).
    """
    bsz, s, h, dqk = q.shape
    dv = v.shape[-1]
    scale = dqk ** -0.5
    if initial is None:
        c0 = jnp.zeros((bsz, h, dqk, dv), jnp.float32)
        n0 = jnp.zeros((bsz, h, dqk), jnp.float32)
        m0 = jnp.full((bsz, h), -jnp.inf, jnp.float32)
    else:
        c0, n0, m0 = initial

    q32 = q.astype(jnp.float32) * scale
    k32, v32 = k.astype(jnp.float32), v.astype(jnp.float32)
    ig, fg = i_gate.astype(jnp.float32), f_gate.astype(jnp.float32)

    def step(state, inp):
        c, n, m = state
        qt, kt, vt, it, ft = inp
        m_new = jnp.maximum(ft + m, it)                     # (B,H)
        f_sc = jnp.exp(ft + m - m_new)[..., None]
        i_sc = jnp.exp(it - m_new)[..., None]
        c = f_sc[..., None] * c + (i_sc * kt)[..., None] * vt[:, :, None, :]
        n = f_sc * n + i_sc * kt
        num = jnp.einsum("bhd,bhdv->bhv", qt, c)
        den = jnp.abs(jnp.einsum("bhd,bhd->bh", qt, n))
        den = jnp.maximum(den, jnp.exp(-m_new))[..., None]
        y = num / den
        return (c, n, m_new), y

    xs = (jnp.moveaxis(q32, 1, 0), jnp.moveaxis(k32, 1, 0),
          jnp.moveaxis(v32, 1, 0), jnp.moveaxis(ig, 1, 0),
          jnp.moveaxis(fg, 1, 0))
    state, ys = jax.lax.scan(step, (c0, n0, m0), xs)
    y = jnp.moveaxis(ys, 0, 1).astype(q.dtype)              # (B,S,H,Dv)
    return y, state


def mlstm_step_ref(q_t, k_t, v_t, i_t, f_t, state):
    """One decode step; shapes (B,H,D*) / (B,H); state = (C, n, m)."""
    c, n, m = state
    scale = q_t.shape[-1] ** -0.5
    qt = q_t.astype(jnp.float32) * scale
    kt, vt = k_t.astype(jnp.float32), v_t.astype(jnp.float32)
    it, ft = i_t.astype(jnp.float32), f_t.astype(jnp.float32)
    m_new = jnp.maximum(ft + m, it)
    f_sc = jnp.exp(ft + m - m_new)[..., None]
    i_sc = jnp.exp(it - m_new)[..., None]
    c = f_sc[..., None] * c + (i_sc * kt)[..., None] * vt[:, :, None, :]
    n = f_sc * n + i_sc * kt
    num = jnp.einsum("bhd,bhdv->bhv", qt, c)
    den = jnp.maximum(jnp.abs(jnp.einsum("bhd,bhd->bh", qt, n)),
                      jnp.exp(-m_new))[..., None]
    return (num / den).astype(q_t.dtype), (c, n, m_new)


# ---------------------------------------------------------------------------
# plain GEMM (oracle for block-tiled matmul kernel)
# ---------------------------------------------------------------------------
def gemm_ref(x: jax.Array, w: jax.Array) -> jax.Array:
    return jnp.dot(x, w, preferred_element_type=jnp.float32).astype(x.dtype)
