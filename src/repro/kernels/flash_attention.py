"""Pallas TPU flash attention (prefill / train path).

Grid: (batch·kv_heads·groups, q_blocks, kv_blocks) — kv minor, so the VMEM
scratch accumulators (m, l, acc) persist across the kv sweep of one q block
(standard TPU flash pattern).  Causality skips fully-masked kv blocks via the
index map + in-block masking.  GQA is handled by folding the q-head group
into the leading grid dim and mapping kv blocks to the shared kv head.

BlockSpec tiling (VMEM budget per grid step, bf16):
  q (1, Bq, D) + k,v (1, Bk, D) + acc f32 (Bq, D) + probs f32 (Bq, Bk)
  with Bq=Bk=256, D=128: ~0.6 MB — comfortably inside the ~16 MB VMEM,
  leaving room for double buffering; Bq/Bk are multiples of the MXU 128 dim.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

DEFAULT_BLOCK_Q = 256
DEFAULT_BLOCK_K = 256
NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, causal: bool, q_offset: int, block_q: int,
            block_k: int, seq_kv: int):
    qb = pl.program_id(1)
    kb = pl.program_id(2)
    nkb = pl.num_programs(2)

    @pl.when(kb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32) * scale          # (Bq, D)
    k = k_ref[0].astype(jnp.float32)                  # (Bk, D)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (Bq, Bk)

    qpos = qb * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) \
        + q_offset
    kpos = kb * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = kpos < seq_kv
    if causal:
        mask = mask & (qpos >= kpos)
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_cur = jnp.max(s, axis=-1)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
    acc_ref[...] = acc_ref[...] * alpha[:, None] + \
        jnp.dot(p, v_ref[0].astype(jnp.float32),
                preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(kb == nkb - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "logit_scale", "q_offset", "block_q", "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True,
                    logit_scale: Optional[float] = None,
                    q_offset: int = 0,
                    block_q: int = DEFAULT_BLOCK_Q,
                    block_k: int = DEFAULT_BLOCK_K,
                    interpret: bool = False) -> jax.Array:
    """q: (B, Sq, H, D); k, v: (B, Skv, KV, D).  Returns (B, Sq, H, D)."""
    b, sq, h, d = q.shape
    _, skv, kvh, _ = k.shape
    assert h % kvh == 0
    group = h // kvh
    scale = logit_scale if logit_scale is not None else d ** -0.5

    block_q = min(block_q, max(8, sq))
    block_k = min(block_k, max(8, skv))
    sq_pad = -(-sq // block_q) * block_q
    skv_pad = -(-skv // block_k) * block_k
    if sq_pad != sq:
        q = jnp.pad(q, ((0, 0), (0, sq_pad - sq), (0, 0), (0, 0)))
    if skv_pad != skv:
        k = jnp.pad(k, ((0, 0), (0, skv_pad - skv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, skv_pad - skv), (0, 0), (0, 0)))

    # fold batch/head into a single leading grid dim: (B*H, S, D)
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq_pad, d)
    kf = k.transpose(0, 2, 1, 3).reshape(b * kvh, skv_pad, d)
    vf = v.transpose(0, 2, 1, 3).reshape(b * kvh, skv_pad, d)

    grid = (b * h, sq_pad // block_q, skv_pad // block_k)

    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, causal=causal,
                          q_offset=q_offset, block_q=block_q,
                          block_k=block_k, seq_kv=skv),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda bh, qb, kb: (bh, qb, 0)),
            pl.BlockSpec((1, block_k, d),
                         lambda bh, qb, kb, _g=group: (bh // _g, kb, 0)),
            pl.BlockSpec((1, block_k, d),
                         lambda bh, qb, kb, _g=group: (bh // _g, kb, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda bh, qb, kb: (bh, qb, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq_pad, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),   # m (running max)
            pltpu.VMEM((block_q,), jnp.float32),   # l (running denom)
            pltpu.VMEM((block_q, d), jnp.float32), # acc
        ],
        interpret=interpret,
    )(qf, kf, vf)

    out = out.reshape(b, h, sq_pad, d).transpose(0, 2, 1, 3)
    return out[:, :sq]
