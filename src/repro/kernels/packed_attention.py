"""Pallas TPU packed attention (the token-packed serve step's hot op).

One program instance per ``(token, kv head, KV block)``: the BlockSpec index
map dereferences a scalar-prefetch ``token_slot`` table, so each packed
token's KV blocks are DMA'd from *its own slot's* cache rows — the slot
gather happens at the index-map level (exactly like the paged kernel's page
table) and the dense-vs-all-slots score matrix of the XLA ref is never
formed.  KV is minor in the grid so the per-(token, kv head) running-softmax
scratch persists across the cache sweep (flash-style online softmax), and
blocks entirely beyond the token's ``lengths`` are skipped with ``pl.when``
(the causal/segment mask is a pure length mask, DESIGN.md §8-§9).

``d_v`` may differ from ``d_qk`` (absorbed MLA attends with
d_qk = rank + rope but d_v = rank), so the MLA packed path runs this kernel
instead of silently falling back to the ref.

``kv_bucket`` statically bounds the swept cache extent (KV-length bucketing,
DESIGN.md §9): the kernel only touches ``kv_bucket`` rows per slot, so FLOPs
and HBM traffic scale with the iteration's actual context, not ``max_len``.

A bucket that is not a multiple of ``block_k`` gets a *masked partial last
block* rather than a padded cache copy: the grid's KV dimension is
``ceil(s / block_k)`` and the out-of-bounds tail of the final tile is
discarded by the existing length mask (scores) plus an explicit zero-mask on
the value rows — no O(cache) ``jnp.pad`` on the hot path (DESIGN.md §15).

int8 KV (DESIGN.md §15): when ``k_scale``/``v_scale`` are passed the k/v
tiles DMA *as stored* (int8) together with a small per-row f32 scale tile
(same index map, ~1/head_dim the bytes), and the kernel dequantizes
in-register — ``k_i8 * scale`` in f32 — before the flash math.  Attention
HBM traffic drops ~2× while the grid, the scalar-prefetch index maps and
the online-softmax scratch are unchanged.

VMEM per step (bf16, Bk=256, D=128, G≤16):
  k (Bk, Dqk) + v (Bk, Dv) + q (G, Dqk) + acc f32 (G, Dv) ≈ 0.2 MB.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

NEG_INF = -1e30
DEFAULT_BLOCK_K = 256


def _flash_step(len_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref, o_ref, m_ref,
                l_ref, acc_ref, *, scale: float, block_k: int,
                s_valid: Optional[int]):
    """Shared online-softmax body.  ``ks_ref``/``vs_ref`` (optional) hold the
    per-row dequant scales; ``s_valid`` (static) is the true KV extent when
    the last block is partial — rows >= s_valid are uninitialized DMA tail
    and must be zeroed out of the value accumulation (their *scores* are
    already masked: kpos >= s_valid >= lengths[t])."""
    t = pl.program_id(0)
    sb = pl.program_id(2)
    nsb = pl.num_programs(2)

    @pl.when(sb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # blocks entirely past the token's length contribute nothing — skip the
    # MXU work (the DMA was issued by the index map regardless)
    @pl.when(sb * block_k < len_ref[t])
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale               # (G, Dqk)
        k = k_ref[0, 0].astype(jnp.float32)                       # (Bk, Dqk)
        v = v_ref[0, 0].astype(jnp.float32)                       # (Bk, Dv)
        if ks_ref is not None:
            k = k * ks_ref[0, 0][:, None]
        if vs_ref is not None:
            v = v * vs_ref[0, 0][:, None]
        if s_valid is not None:
            vrow = sb * block_k + \
                jax.lax.broadcasted_iota(jnp.int32, v.shape, 0)
            v = jnp.where(vrow < s_valid, v, 0.0)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)   # (G, Bk)

        kpos = sb * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kpos < len_ref[t], s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + \
            jnp.dot(p, v, preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(sb == nsb - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


def _kernel(slot_ref, len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
            acc_ref, *, scale: float, block_k: int,
            s_valid: Optional[int] = None):
    _flash_step(len_ref, q_ref, k_ref, v_ref, None, None, o_ref, m_ref,
                l_ref, acc_ref, scale=scale, block_k=block_k, s_valid=s_valid)


def _kernel_quant(slot_ref, len_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref,
                  o_ref, m_ref, l_ref, acc_ref, *, scale: float, block_k: int,
                  s_valid: Optional[int] = None):
    _flash_step(len_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref, o_ref, m_ref,
                l_ref, acc_ref, scale=scale, block_k=block_k, s_valid=s_valid)


def _kernel_block(bt_ref, slot_ref, len_ref, q_ref, k_ref, v_ref, o_ref,
                  m_ref, l_ref, acc_ref, *, scale: float, block_k: int):
    # block-table mode: the physical-block dereference happened in the index
    # map (bt[slot[t] * nb_cols + sb]); the flash math is identical
    _flash_step(len_ref, q_ref, k_ref, v_ref, None, None, o_ref, m_ref,
                l_ref, acc_ref, scale=scale, block_k=block_k, s_valid=None)


def _kernel_block_quant(bt_ref, slot_ref, len_ref, q_ref, k_ref, v_ref,
                        ks_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref, *,
                        scale: float, block_k: int):
    _flash_step(len_ref, q_ref, k_ref, v_ref, ks_ref, vs_ref, o_ref, m_ref,
                l_ref, acc_ref, scale=scale, block_k=block_k, s_valid=None)


@functools.partial(jax.jit, static_argnames=("logit_scale", "kv_bucket",
                                             "block_k", "interpret"))
def packed_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     token_slot: jax.Array, lengths: jax.Array, *,
                     logit_scale: Optional[float] = None,
                     kv_bucket: Optional[int] = None,
                     block_tables: Optional[jax.Array] = None,
                     k_scale: Optional[jax.Array] = None,
                     v_scale: Optional[jax.Array] = None,
                     block_k: int = DEFAULT_BLOCK_K,
                     interpret: bool = False) -> jax.Array:
    """q: (T, H, Dqk) packed queries; k_cache: (N_slots, S, KV, Dqk);
    v_cache: (N_slots, S, KV, Dv); token_slot: (T,) int32 slot per token;
    lengths: (T,) int32 — token t attends rows [0, lengths[t]) of its slot.

    ``kv_bucket`` (static): the caller guarantees ``max(lengths) <=
    kv_bucket``; only the first ``kv_bucket`` cache rows are swept.
    Returns (T, H, Dv).

    ``block_tables`` (optional, (N_slots, S // block_size) int32,
    DESIGN.md §12): block-table mode — the caches are *physical block
    storage* (flat row space N·S carved into fixed-size blocks) and the
    scalar-prefetch gather goes through the table: grid step
    ``(t, kv, sb)`` DMAs physical block ``bt[slot[t], sb]`` instead of slot
    row-block ``sb``.  One extra prefetched operand, same grid, same flash
    math — the compile-cache bound (|T buckets| × |kv buckets|) is
    unchanged because the table is a traced operand of static shape.

    ``k_scale``/``v_scale`` (optional, (N_slots, S, KV) f32, DESIGN.md §15):
    int8 caches — k/v tiles dequantize in-register (``row * scale``) after
    the int8 HBM read; the scale tiles ride the same index maps.
    """
    t, h, d = q.shape
    n, s, kvh, _ = k_cache.shape
    dv = v_cache.shape[-1]
    if block_tables is not None:
        return _packed_attention_block(q, k_cache, v_cache, token_slot,
                                       lengths, block_tables,
                                       k_scale=k_scale, v_scale=v_scale,
                                       logit_scale=logit_scale,
                                       kv_bucket=kv_bucket,
                                       interpret=interpret)
    if kv_bucket is not None and kv_bucket < s:
        k_cache = jax.lax.slice_in_dim(k_cache, 0, kv_bucket, axis=1)
        v_cache = jax.lax.slice_in_dim(v_cache, 0, kv_bucket, axis=1)
        if k_scale is not None:
            k_scale = jax.lax.slice_in_dim(k_scale, 0, kv_bucket, axis=1)
            v_scale = jax.lax.slice_in_dim(v_scale, 0, kv_bucket, axis=1)
        s = kv_bucket
    group = h // kvh
    scale = logit_scale if logit_scale is not None else d ** -0.5

    # masked partial last block instead of an O(cache) pad (DESIGN.md §15):
    # the final tile's DMA tail past ``s`` is uninitialized — scores there
    # are length-masked and the value rows zero-masked in-kernel
    block_k = min(block_k, max(8, s))
    nsb = -(-s // block_k)
    s_valid = s if s % block_k else None

    qf = q.reshape(t, kvh, group, d)
    kf = k_cache.transpose(0, 2, 1, 3)        # (N, KV, S, Dqk)
    vf = v_cache.transpose(0, 2, 1, 3)        # (N, KV, S, Dv)

    grid = (t, kvh, nsb)
    in_specs = [
        pl.BlockSpec((1, 1, group, d),
                     lambda ti, kv, sb, slot, ln: (ti, kv, 0, 0)),
        pl.BlockSpec((1, 1, block_k, d),
                     lambda ti, kv, sb, slot, ln: (slot[ti], kv, sb, 0)),
        pl.BlockSpec((1, 1, block_k, dv),
                     lambda ti, kv, sb, slot, ln: (slot[ti], kv, sb, 0)),
    ]
    operands = [qf, kf, vf]
    kernel = _kernel
    if k_scale is not None:
        ksf = k_scale.transpose(0, 2, 1)      # (N, KV, S)
        vsf = v_scale.transpose(0, 2, 1)
        in_specs += [
            pl.BlockSpec((1, 1, block_k),
                         lambda ti, kv, sb, slot, ln: (slot[ti], kv, sb)),
            pl.BlockSpec((1, 1, block_k),
                         lambda ti, kv, sb, slot, ln: (slot[ti], kv, sb)),
        ]
        operands += [ksf, vsf]
        kernel = _kernel_quant
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                # token_slot, lengths
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, group, dv),
                               lambda ti, kv, sb, slot, ln: (ti, kv, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((group,), jnp.float32),      # m (running max)
            pltpu.VMEM((group,), jnp.float32),      # l (running denom)
            pltpu.VMEM((group, dv), jnp.float32),   # acc
        ],
    )
    out = pl.pallas_call(
        functools.partial(kernel, scale=scale, block_k=block_k,
                          s_valid=s_valid),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((t, kvh, group, dv), q.dtype),
        interpret=interpret,
    )(token_slot, lengths, *operands)
    return out.reshape(t, h, dv)


def _packed_attention_block(q, k_cache, v_cache, token_slot, lengths,
                            block_tables, *, k_scale, v_scale, logit_scale,
                            kv_bucket, interpret):
    """Block-table gather mode (DESIGN.md §12).  The KV grid dimension
    sweeps *logical* blocks 0..kv_bucket/bs; the index map dereferences the
    flattened table so each step's DMA lands on the request's physical
    block.  ``block_k`` is pinned to the block size — a DMA can't span two
    physical blocks that are not adjacent in memory."""
    t, h, d = q.shape
    n, s, kvh, _ = k_cache.shape
    dv = v_cache.shape[-1]
    nb_cols = block_tables.shape[1]
    bs = s // nb_cols
    sweep = s if kv_bucket is None or kv_bucket > s else kv_bucket
    assert sweep % bs == 0, (sweep, bs)
    group = h // kvh
    scale = logit_scale if logit_scale is not None else d ** -0.5

    qf = q.reshape(t, kvh, group, d)
    # physical block storage, KV-heads major so one (block, head) tile DMAs
    # contiguously: (N*S/bs, bs, KV, D) -> (NB, KV, bs, D)
    kf = k_cache.reshape(n * nb_cols, bs, kvh, d).transpose(0, 2, 1, 3)
    vf = v_cache.reshape(n * nb_cols, bs, kvh, dv).transpose(0, 2, 1, 3)
    bt = block_tables.reshape(-1).astype(jnp.int32)

    grid = (t, kvh, sweep // bs)
    in_specs = [
        pl.BlockSpec((1, 1, group, d),
                     lambda ti, kv, sb, bt, slot, ln: (ti, kv, 0, 0)),
        pl.BlockSpec((1, 1, bs, d),
                     lambda ti, kv, sb, bt, slot, ln:
                     (bt[slot[ti] * nb_cols + sb], kv, 0, 0)),
        pl.BlockSpec((1, 1, bs, dv),
                     lambda ti, kv, sb, bt, slot, ln:
                     (bt[slot[ti] * nb_cols + sb], kv, 0, 0)),
    ]
    operands = [qf, kf, vf]
    kernel = _kernel_block
    if k_scale is not None:
        ksf = k_scale.reshape(n * nb_cols, bs, kvh).transpose(0, 2, 1)
        vsf = v_scale.reshape(n * nb_cols, bs, kvh).transpose(0, 2, 1)
        in_specs += [
            pl.BlockSpec((1, 1, bs),
                         lambda ti, kv, sb, bt, slot, ln:
                         (bt[slot[ti] * nb_cols + sb], kv, 0)),
            pl.BlockSpec((1, 1, bs),
                         lambda ti, kv, sb, bt, slot, ln:
                         (bt[slot[ti] * nb_cols + sb], kv, 0)),
        ]
        operands += [ksf, vsf]
        kernel = _kernel_block_quant
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,                # block_tables, token_slot, lengths
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, group, dv),
                               lambda ti, kv, sb, bt, slot, ln: (ti, kv, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((group,), jnp.float32),      # m (running max)
            pltpu.VMEM((group,), jnp.float32),      # l (running denom)
            pltpu.VMEM((group, dv), jnp.float32),   # acc
        ],
    )
    out = pl.pallas_call(
        functools.partial(kernel, scale=scale, block_k=bs),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((t, kvh, group, dv), q.dtype),
        interpret=interpret,
    )(bt, token_slot, lengths, *operands)
    return out.reshape(t, h, dv)
