"""Pallas TPU packed attention (the token-packed serve step's hot op).

One program instance per ``(token, kv head, KV block)``: the BlockSpec index
map dereferences a scalar-prefetch ``token_slot`` table, so each packed
token's KV blocks are DMA'd from *its own slot's* cache rows — the slot
gather happens at the index-map level (exactly like the paged kernel's page
table) and the dense-vs-all-slots score matrix of the XLA ref is never
formed.  KV is minor in the grid so the per-(token, kv head) running-softmax
scratch persists across the cache sweep (flash-style online softmax), and
blocks entirely beyond the token's ``lengths`` are skipped with ``pl.when``
(the causal/segment mask is a pure length mask, DESIGN.md §8-§9).

``d_v`` may differ from ``d_qk`` (absorbed MLA attends with
d_qk = rank + rope but d_v = rank), so the MLA packed path runs this kernel
instead of silently falling back to the ref.

``kv_bucket`` statically bounds the swept cache extent (KV-length bucketing,
DESIGN.md §9): the kernel only touches ``kv_bucket`` rows per slot, so FLOPs
and HBM traffic scale with the iteration's actual context, not ``max_len``.

VMEM per step (bf16, Bk=256, D=128, G≤16):
  k (Bk, Dqk) + v (Bk, Dv) + q (G, Dqk) + acc f32 (G, Dv) ≈ 0.2 MB.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

NEG_INF = -1e30
DEFAULT_BLOCK_K = 256


def _kernel(slot_ref, len_ref, q_ref, k_ref, v_ref, o_ref, m_ref, l_ref,
            acc_ref, *, scale: float, block_k: int):
    t = pl.program_id(0)
    sb = pl.program_id(2)
    nsb = pl.num_programs(2)

    @pl.when(sb == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # blocks entirely past the token's length contribute nothing — skip the
    # MXU work (the DMA was issued by the index map regardless)
    @pl.when(sb * block_k < len_ref[t])
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale               # (G, Dqk)
        k = k_ref[0, 0].astype(jnp.float32)                       # (Bk, Dqk)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)   # (G, Bk)

        kpos = sb * block_k + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(kpos < len_ref[t], s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + \
            jnp.dot(p, v_ref[0, 0].astype(jnp.float32),
                    preferred_element_type=jnp.float32)
        m_ref[...] = m_new

    @pl.when(sb == nsb - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0, 0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("logit_scale", "kv_bucket",
                                             "block_k", "interpret"))
def packed_attention(q: jax.Array, k_cache: jax.Array, v_cache: jax.Array,
                     token_slot: jax.Array, lengths: jax.Array, *,
                     logit_scale: Optional[float] = None,
                     kv_bucket: Optional[int] = None,
                     block_k: int = DEFAULT_BLOCK_K,
                     interpret: bool = False) -> jax.Array:
    """q: (T, H, Dqk) packed queries; k_cache: (N_slots, S, KV, Dqk);
    v_cache: (N_slots, S, KV, Dv); token_slot: (T,) int32 slot per token;
    lengths: (T,) int32 — token t attends rows [0, lengths[t]) of its slot.

    ``kv_bucket`` (static): the caller guarantees ``max(lengths) <=
    kv_bucket``; only the first ``kv_bucket`` cache rows are swept.
    Returns (T, H, Dv).
    """
    t, h, d = q.shape
    n, s, kvh, _ = k_cache.shape
    dv = v_cache.shape[-1]
    if kv_bucket is not None and kv_bucket < s:
        k_cache = jax.lax.slice_in_dim(k_cache, 0, kv_bucket, axis=1)
        v_cache = jax.lax.slice_in_dim(v_cache, 0, kv_bucket, axis=1)
        s = kv_bucket
    group = h // kvh
    scale = logit_scale if logit_scale is not None else d ** -0.5

    block_k = min(block_k, max(8, s))
    s_pad = -(-s // block_k) * block_k
    if s_pad != s:
        k_cache = jnp.pad(k_cache, ((0, 0), (0, s_pad - s), (0, 0), (0, 0)))
        v_cache = jnp.pad(v_cache, ((0, 0), (0, s_pad - s), (0, 0), (0, 0)))

    qf = q.reshape(t, kvh, group, d)
    kf = k_cache.transpose(0, 2, 1, 3)        # (N, KV, S_pad, Dqk)
    vf = v_cache.transpose(0, 2, 1, 3)        # (N, KV, S_pad, Dv)

    grid = (t, kvh, s_pad // block_k)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,                # token_slot, lengths
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, group, d),
                         lambda ti, kv, sb, slot, ln: (ti, kv, 0, 0)),
            pl.BlockSpec((1, 1, block_k, d),
                         lambda ti, kv, sb, slot, ln: (slot[ti], kv, sb, 0)),
            pl.BlockSpec((1, 1, block_k, dv),
                         lambda ti, kv, sb, slot, ln: (slot[ti], kv, sb, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, group, dv),
                               lambda ti, kv, sb, slot, ln: (ti, kv, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((group,), jnp.float32),      # m (running max)
            pltpu.VMEM((group,), jnp.float32),      # l (running denom)
            pltpu.VMEM((group, dv), jnp.float32),   # acc
        ],
    )
    out = pl.pallas_call(
        functools.partial(_kernel, scale=scale, block_k=block_k),
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((t, kvh, group, dv), q.dtype),
        interpret=interpret,
    )(token_slot, lengths, qf, kf, vf)
    return out.reshape(t, h, dv)
