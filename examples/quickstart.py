"""Quickstart: the public API in ~60 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.configs import get_config
from repro.core import costmodel as cm
from repro.core.autosearch import autosearch, throughput_estimate
from repro.models import model
from repro.serving.config import EngineConfig
from repro.serving.engine import ServeEngine
from repro.serving.request import Request

# 1. Pick an architecture (any of the 10 assigned + llama2-70b + tiny-*).
cfg = get_config("tiny-toy")
print(f"model: {cfg.name}  params: {model.num_params(cfg)/1e6:.1f}M")

# 2. Initialize and run a forward pass.
params = model.init(cfg, jax.random.PRNGKey(0))
tokens = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)
logits, aux = model.forward_full(cfg, params, tokens)
print(f"logits: {logits.shape}")

# 3. NanoFlow: the analytical cost model + automatic parameter search.
big = get_config("llama2-70b")
w = cm.Workload(p=512, d=1024)
ms = cm.model_stats(big)
print(f"\nLLaMA-2-70B @ 8xA100: {cm.classify(cm.A100_80G, ms, w, 8)}")
print(f"optimal throughput (Eq.9): "
      f"{cm.optimal_throughput(cm.A100_80G, ms, 8):.0f} tok/s")
sched = autosearch(big, w, cm.A100_80G, 8, bdense=2048)
tp = throughput_estimate(big, sched, w, cm.A100_80G, 8, bdense=2048)
print(f"autosearch schedule: nano_kqv={sched.nano_kqv} "
      f"-> {tp*8:.0f} tok/s total "
      f"({100*tp*8/cm.optimal_throughput(cm.A100_80G, ms, 8):.0f}% of optimal)")
print(f"critical path: {' -> '.join(sched.critical_path)}")

# 4. Serve a batch of requests end-to-end (continuous batching + paged KV).
eng = ServeEngine(cfg, params, EngineConfig(max_slots=4, max_len=64,
                                               discrete_sizes=(32, 16, 8)))
rng = np.random.default_rng(0)
for i in range(6):
    eng.submit(Request(rid=i,
                       prompt=list(rng.integers(0, cfg.vocab_size, size=8)),
                       max_new_tokens=6))
done = eng.run()
print(f"\nserved {len(done)} requests in {eng.stats.iterations} iterations, "
      f"{eng.stats.total_tokens} tokens")
print(f"first output: {done[0].output}")
