"""NanoFlow §5.5: run the automatic parameter search for any architecture
and print the resulting overlapped schedule + resource timeline.

    PYTHONPATH=src python examples/autosearch_plan.py --arch deepseek-v2-236b
"""
import argparse

from benchmarks.resource_usage import occupancy, render
from repro.configs import get_config
from repro.core import costmodel as cm
from repro.core.autosearch import (autosearch, sequential_schedule,
                                   throughput_estimate)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama2-70b")
    ap.add_argument("--devices", type=int, default=256)
    ap.add_argument("--hw", default="TPUv5e", choices=sorted(cm.HARDWARE))
    ap.add_argument("--prefill", type=float, default=1024)
    ap.add_argument("--decode", type=float, default=512)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    hw = cm.HARDWARE[args.hw]
    w = cm.Workload(args.prefill, args.decode)
    ms = cm.model_stats(cfg)

    print(f"=== {args.arch} @ {args.devices}x{hw.name}, p={args.prefill} "
          f"d={args.decode} ===")
    print(f"classification: {cm.classify(hw, ms, w, args.devices)} "
          f"(T_R={cm.t_r(hw, ms, w, args.devices):.3f})")
    opt = cm.optimal_throughput(hw, ms, args.devices)
    print(f"optimal (Eq.9): {opt:.0f} tok/s total, "
          f"{opt/args.devices:.0f} tok/s/chip")

    nano = autosearch(cfg, w, hw, args.devices)
    seq = sequential_schedule(cfg, w, hw, args.devices)
    tp = throughput_estimate(cfg, nano, w, hw, args.devices)
    print(f"\nautosearch: nano_kqv={nano.nano_kqv} nano_dense={nano.nano_dense}")
    print(f"iter time: {nano.iter_time*1e3:.3f} ms/layer "
          f"(sequential {seq.iter_time*1e3:.3f} ms = "
          f"{seq.iter_time/nano.iter_time:.2f}x slower)")
    print(f"modeled throughput: {tp:.0f} tok/s/chip "
          f"({100*tp*args.devices/opt:.1f}% of optimal)")
    print(f"critical path: {' -> '.join(nano.critical_path)}")
    print("\nunit assignment (execution-unit scheduling):")
    for name, u in sorted(nano.unit_assignment.items()):
        node = nano.pipeline.nodes[name]
        print(f"  {name:10s} {node.kind:8s} units={u:.2f} "
              f"[{node.start*1e3:7.3f}, {node.end*1e3:7.3f}] ms")
    print("\nresource occupancy (one layer iteration):")
    print(render(occupancy(nano)))


if __name__ == "__main__":
    main()
