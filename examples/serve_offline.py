"""End-to-end serving driver (deliverable b): batched requests against a
small model with continuous batching, chunked prefill, discrete batching,
async EOS and KV offload — the paper's full serving path.

    PYTHONPATH=src python examples/serve_offline.py [--requests 24]
"""
import argparse

import jax
import numpy as np

from repro.configs import get_config
from repro.models import model
from repro.serving.config import EngineConfig
from repro.serving.engine import ServeEngine
from repro.serving.request import Request


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tiny-toy")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    params = model.init(cfg, jax.random.PRNGKey(args.seed))
    eng = ServeEngine(cfg, params, EngineConfig(
        max_slots=8, max_len=128, discrete_sizes=(64, 32, 16, 8),
        avg_decode_len=10))

    rng = np.random.default_rng(args.seed)
    for i in range(args.requests):
        plen = int(rng.integers(4, 48))
        eng.submit(Request(
            rid=i, prompt=list(rng.integers(0, cfg.vocab_size, size=plen)),
            max_new_tokens=int(rng.integers(4, 24)),
            eos_id=int(rng.integers(0, cfg.vocab_size)) if i % 3 == 0 else None))

    done = eng.run()
    st = eng.stats
    print(f"finished {len(done)}/{args.requests} in {st.iterations} iterations")
    print(f"tokens: {st.prefill_tokens} prefill + {st.decode_tokens} decode "
          f"= {st.total_tokens} @ {st.throughput:.1f} tok/s (CPU ref path)")
    print(f"dense-batch histogram (discrete batching): "
          f"{dict(sorted(st.dense_batch_hist.items()))}")
    kv = eng.kv.stats
    print(f"KV: {kv.aggregated_copies} offloads, "
          f"{kv.offload_bytes/1e6:.2f} MB D2H (page-aggregated), "
          f"host pool {kv.host_bytes/1e6:.2f} MB")


if __name__ == "__main__":
    main()
