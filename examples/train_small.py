"""Train a ~100M-parameter model for a few hundred steps (deliverable b),
with checkpoint/restart fault tolerance.

    PYTHONPATH=src python examples/train_small.py --steps 300
    PYTHONPATH=src python examples/train_small.py --steps 300 --inject-failure 120
    # ^ crashes at step 120; run the same command again to restore + finish.

(Default below uses 20 steps of tiny-100m on CPU to keep the example fast;
pass --steps 300 for the full run.)
"""
import argparse

from repro.configs import get_config
from repro.models import model as model_lib
from repro.training.data import DataConfig, synthetic_stream
from repro.training.optimizer import AdamWConfig
from repro.training.trainer import DriverConfig, TrainConfig, Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_small")
    ap.add_argument("--inject-failure", type=int, default=None)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--remat", default="none")
    args = ap.parse_args()

    cfg = get_config("tiny-100m")
    print(f"training {cfg.name}: {model_lib.num_params(cfg)/1e6:.1f}M params")
    tc = TrainConfig(
        remat=args.remat, grad_accum=args.grad_accum,
        opt=AdamWConfig(lr=3e-4, total_steps=args.steps,
                        warmup_steps=max(args.steps // 10, 1)))
    dc = DriverConfig(steps=args.steps, ckpt_dir=args.ckpt_dir, ckpt_every=50,
                      log_every=10, inject_failure_at=args.inject_failure)
    trainer = Trainer(cfg, tc, dc)
    if trainer.start_step:
        print(f"restored from checkpoint at step {trainer.start_step}")
    stream = synthetic_stream(DataConfig(batch=args.batch, seq_len=args.seq,
                                         vocab_size=cfg.vocab_size))
    for _ in range(trainer.start_step):
        next(stream)                     # deterministic data order on restart
    out = trainer.fit(stream)
    for row in out["history"]:
        print(f"step {row['step']:5d}  loss {row['loss']:.4f}  "
              f"gnorm {row['grad_norm']:.2f}  {row['sec']*1e3:.0f} ms/step")


if __name__ == "__main__":
    main()
