"""Packed-attention microbenchmark (DESIGN.md §9).

Sweeps the KV-length bucket for a fixed packed stream and reports measured
wall time against an analytic bytes + FLOPs model, for both execution
strategies of ``ops.packed_attention``:

  * ``ref``    — XLA dense-vs-all-slots (scores against every slot's bucket
                 rows, then per-token select): FLOPs carry an extra
                 ``N_slots`` factor but the caches are read once.
  * ``pallas`` / ``interpret`` — block-wise slot gather (each token DMAs
                 only its own slot's rows): minimal FLOPs, bytes carry a
                 per-token factor.

The point of the sweep: both time columns scale with ``kv_bucket``, not
``max_len`` — the §9 claim the engine A/B (offline_throughput) measures
end-to-end.  ``interpret`` runs the Pallas kernel body on CPU and is
orders of magnitude slower than compiled code; it is for correctness
spot-checks, so the default impl here is ``ref``.
"""
from __future__ import annotations

import argparse
import functools
import json

import jax
import jax.numpy as jnp
import numpy as np

try:
    from benchmarks.common import emit, time_fn
except ImportError:                      # run directly, not as a module
    from common import emit, time_fn
from repro.kernels import ops
from repro.serving.scheduler import default_kv_buckets


def cost_model(t: int, h: int, kv: int, d_qk: int, d_v: int, n_slots: int,
               kv_bucket: int, itemsize: int) -> dict:
    """Analytic FLOPs/bytes for one packed-attention call over S=kv_bucket
    rows per slot.  ``gather``: the Pallas kernel's per-token slot sweep.
    ``dense``: the XLA ref's all-slots einsum."""
    s = kv_bucket
    qk_flops = 2 * t * h * s * d_qk          # scores
    av_flops = 2 * t * h * s * d_v           # context
    cache_row = kv * (d_qk + d_v) * itemsize
    return {
        "gather_flops": qk_flops + av_flops,
        # each token streams its own slot's rows; q + out are T×H vectors
        "gather_bytes": (t * s * cache_row
                         + t * h * (d_qk + d_v) * itemsize),
        "dense_flops": n_slots * (qk_flops + av_flops),
        # caches read once; the (T, N, KV, G, S) score tensor round-trips
        "dense_bytes": (n_slots * s * cache_row
                        + 2 * t * n_slots * h * s * 4
                        + t * h * (d_qk + d_v) * itemsize),
    }


def run(impl: str = "ref", t: int = 64, n_slots: int = 8, max_len: int = 512,
        h: int = 8, kv: int = 2, d_qk: int = 64, d_v: int = 64,
        dtype: str = "bfloat16", iters: int = 5) -> list[dict]:
    dt = jnp.dtype(dtype)
    rng = np.random.default_rng(0)
    q = jnp.asarray(rng.normal(size=(t, h, d_qk)), dt)
    k_cache = jnp.asarray(rng.normal(size=(n_slots, max_len, kv, d_qk)), dt)
    v_cache = jnp.asarray(rng.normal(size=(n_slots, max_len, kv, d_v)), dt)
    slot = jnp.asarray(rng.integers(0, n_slots, size=t), jnp.int32)

    rows = []
    # sweep the same grid the engine actually launches (DESIGN.md §9)
    for b in default_kv_buckets(max_len):
        lengths = jnp.asarray(rng.integers(1, b + 1, size=t), jnp.int32)
        fn = jax.jit(functools.partial(
            ops.packed_attention, logit_scale=d_qk ** -0.5, kv_bucket=b,
            impl=impl))
        sec = time_fn(fn, q, k_cache, v_cache, slot, lengths, iters=iters)
        model = cost_model(t, h, kv, d_qk, d_v, n_slots, b, dt.itemsize)
        kind = "dense" if impl == "ref" else "gather"
        rows.append({
            "bench": "packed_attention",
            "case": f"{impl}/T{t}xN{n_slots}/kv{b}of{max_len}/{dtype}",
            "impl": impl,
            "kv_bucket": b,
            "us_per_call": round(sec * 1e6, 1),
            "model_gflops": round(model[f"{kind}_flops"] / 1e9, 4),
            "model_mbytes": round(model[f"{kind}_bytes"] / 1e6, 3),
            "achieved_gflop_s": round(model[f"{kind}_flops"] / sec / 1e9, 2),
            "achieved_gb_s": round(model[f"{kind}_bytes"] / sec / 1e9, 2),
        })
    # the §9 scaling check, attached to the smallest-bucket row: how much
    # faster the bucketed sweep is than the full-cache sweep
    full, small = rows[-1], rows[0]
    small["speedup_vs_full_sweep"] = round(
        full["us_per_call"] / max(small["us_per_call"], 1e-9), 2)
    return rows


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--impl", default="ref",
                    choices=["ref", "pallas", "interpret"])
    ap.add_argument("--tokens", type=int, default=64)
    ap.add_argument("--slots", type=int, default=8)
    ap.add_argument("--max-len", type=int, default=512)
    ap.add_argument("--heads", type=int, default=8)
    ap.add_argument("--kv-heads", type=int, default=2)
    ap.add_argument("--d-qk", type=int, default=64)
    ap.add_argument("--d-v", type=int, default=64,
                    help="set != --d-qk for the absorbed-MLA shape")
    ap.add_argument("--dtype", default="bfloat16")
    ap.add_argument("--json", default=None)
    args = ap.parse_args(argv)
    rows = run(impl=args.impl, t=args.tokens, n_slots=args.slots,
               max_len=args.max_len, h=args.heads, kv=args.kv_heads,
               d_qk=args.d_qk, d_v=args.d_v, dtype=args.dtype)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=2)
    for r in rows:
        extra = (f" [{r['speedup_vs_full_sweep']}x faster than the "
                 f"full-cache sweep]" if "speedup_vs_full_sweep" in r else "")
        emit(r["case"], r["us_per_call"],
             f"{r['achieved_gflop_s']} GFLOP/s {r['achieved_gb_s']} GB/s "
             f"(model {r['model_gflops']} GF, {r['model_mbytes']} MB)"
             + extra)


if __name__ == "__main__":
    main()
