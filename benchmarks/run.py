"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines.  Run:
    PYTHONPATH=src python -m benchmarks.run [--only fig10,roofline]
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

MODULES = [
    ("fig2_workload_class", "benchmarks.workload_class"),
    ("table2_cost_model", "benchmarks.cost_model_validation"),
    ("fig10_offline_throughput", "benchmarks.offline_throughput"),
    ("fig11_12_online_latency", "benchmarks.online_latency"),
    ("fig13_ablation", "benchmarks.ablation"),
    ("fig14_resource_usage", "benchmarks.resource_usage"),
    ("fig15_ported_models", "benchmarks.ported_models"),
    ("roofline", "benchmarks.roofline"),
    ("packed_attention", "benchmarks.packed_attention_bench"),
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated substring filters")
    args = ap.parse_args()
    filters = args.only.split(",") if args.only else None

    failures = 0
    for name, modname in MODULES:
        if filters and not any(f in name for f in filters):
            continue
        print(f"# === {name} ===", flush=True)
        t0 = time.time()
        try:
            mod = __import__(modname, fromlist=["main"])
            mod.main()
        except Exception:
            failures += 1
            print(f"# {name} FAILED:\n{traceback.format_exc()[-1500:]}",
                  flush=True)
        print(f"# {name} done in {time.time()-t0:.1f}s", flush=True)
    sys.exit(1 if failures else 0)


if __name__ == "__main__":
    main()
