"""Paper Fig. 10: offline throughput.

(a) Real engine run (tiny model, CPU ref path) with dataset-like length
    mixes — measures the *system* overheads (scheduling, batching, KV).
(b) Modeled v5e/A100 throughput: NanoFlow schedule vs sequential baseline vs
    Eq. 9 optimal for the paper's model and workloads — the paper's headline
    "% of optimal" numbers.
"""
from __future__ import annotations

import dataclasses

import jax
import numpy as np

from repro.configs import get_config
from repro.core import costmodel as cm
from repro.core.autosearch import (autosearch, sequential_schedule,
                                   throughput_estimate)
from repro.models import model
from repro.serving.engine import ServeEngine
from repro.serving.request import Request

WORKLOADS = [("const_512_1024", 512, 1024), ("const_1024_512", 1024, 512),
             ("sharegpt", 246, 322), ("lmsys", 102, 222),
             ("splitwise", 1155, 211)]


def modeled(arch: str, hw: cm.Hardware, n_dev: int, bdense: float = 2048
            ) -> list[dict]:
    cfg = get_config(arch)
    ms = cm.model_stats(cfg)
    opt = cm.optimal_throughput(hw, ms, n_dev) / n_dev
    rows = []
    for name, p, d in WORKLOADS:
        w = cm.Workload(p, d)
        nano = autosearch(cfg, w, hw, n_dev, bdense=bdense)
        seq = sequential_schedule(cfg, w, hw, n_dev, bdense=bdense)
        tp_n = throughput_estimate(cfg, nano, w, hw, n_dev, bdense=bdense)
        tp_s = throughput_estimate(cfg, seq, w, hw, n_dev, bdense=bdense)
        rows.append({
            "bench": "offline_throughput_model",
            "case": f"{arch}@{n_dev}x{hw.name}/{name}",
            "nanoflow_tok_s_dev": round(tp_n, 1),
            "sequential_tok_s_dev": round(tp_s, 1),
            "optimal_tok_s_dev": round(opt, 1),
            "pct_optimal": round(100 * tp_n / opt, 1),
            "speedup": round(tp_n / tp_s, 3),
        })
    return rows


def _submit_workload(eng, name: str, p: int, d: int, n_requests: int,
                     vocab: int, rid0: int) -> None:
    rng = np.random.default_rng(0)
    for i in range(n_requests):
        plen = max(2, int(rng.exponential(p))) if "like" in name else p
        dlen = max(2, int(rng.exponential(d))) if "like" in name else d
        eng.submit(Request(rid=rid0 + i,
                           prompt=list(rng.integers(0, vocab,
                                                    size=min(plen, 64))),
                           max_new_tokens=min(dlen, 32)))


def engine_measured(n_requests: int = 12) -> list[dict]:
    """Real engine runs, A/B-ing the incremental chunked-prefill path
    (O(p) model FLOPs per prompt, DESIGN.md §7) against the legacy
    prefix-recompute path (O(p²/chunk)).  Each mode runs the workload twice
    and reports the second pass, so XLA compile time (which differs between
    the modes' compile-cache footprints) doesn't pollute the A/B.
    ``prefill_flops_per_tok`` uses the 2·N_active forward rule scaled by the
    measured model-token expansion."""
    cfg = get_config("tiny-toy")
    params = model.init(cfg, jax.random.PRNGKey(0))
    flops_fwd = 2 * model.active_params(cfg)
    rows = []
    for name, p, d in [("sharegpt-like", 12, 16), ("const", 16, 8)]:
        per_mode: dict[str, dict] = {}
        for mode in ("incremental", "recompute"):
            eng = ServeEngine(cfg, params, max_slots=4, max_len=128,
                              discrete_sizes=(64, 32, 16, 8),
                              avg_decode_len=d, prefill_mode=mode)
            # warmup pass: same length mix -> compiles every program shape
            _submit_workload(eng, name, p, d, n_requests, cfg.vocab_size, 0)
            eng.run()
            warm = dataclasses.replace(eng.stats,
                                       dense_batch_hist=dict(
                                           eng.stats.dense_batch_hist))
            # measured pass
            _submit_workload(eng, name, p, d, n_requests, cfg.vocab_size,
                             n_requests)
            done = eng.run()
            st = eng.stats
            tokens = st.total_tokens - warm.total_tokens
            wall = st.wall_time - warm.wall_time
            prefill_tok = st.prefill_tokens - warm.prefill_tokens
            model_tok = st.prefill_model_tokens - warm.prefill_model_tokens
            expansion = model_tok / max(prefill_tok, 1)
            prefill_s = st.prefill_time - warm.prefill_time
            per_mode[mode] = {
                "bench": "offline_throughput_engine",
                "case": f"tiny-toy/{name}/{mode}",
                "finished": len(done),
                "tokens": tokens,
                "tok_s_cpu": round(tokens / max(wall, 1e-9), 1),
                "iters": st.iterations - warm.iterations,
                "_prefill_s_raw": prefill_s,       # unrounded, for the ratio
                "prefill_s": round(prefill_s, 3),
                "prefill_expansion": round(expansion, 3),
                "prefill_flops_per_tok": round(flops_fwd * expansion),
            }
        inc, rec = per_mode["incremental"], per_mode["recompute"]
        inc["prefill_speedup_vs_recompute"] = round(
            rec.pop("_prefill_s_raw") / max(inc.pop("_prefill_s_raw"), 1e-9),
            3)
        rows += [inc, rec]
    return rows


def run() -> list[dict]:
    out = modeled("llama2-70b", cm.A100_80G, 8)
    out += modeled("qwen3-8b", cm.TPU_V5E, 16)
    out += engine_measured()
    return out


def main() -> None:
    for r in run():
        if r["bench"] == "offline_throughput_model":
            print(f"fig10/{r['case']},0.0,"
                  f"nano={r['nanoflow_tok_s_dev']} seq={r['sequential_tok_s_dev']} "
                  f"opt={r['optimal_tok_s_dev']} ({r['pct_optimal']}% of optimal, "
                  f"{r['speedup']}x)")
        else:
            extra = ""
            if "prefill_speedup_vs_recompute" in r:
                extra = (f" prefill {r['prefill_s']}s "
                         f"({r['prefill_speedup_vs_recompute']}x vs recompute)")
            print(f"fig10/{r['case']},0.0,{r['tok_s_cpu']} tok/s CPU "
                  f"({r['tokens']} tokens, {r['iters']} iters, "
                  f"{r['prefill_expansion']}x prefill work, "
                  f"{r['prefill_flops_per_tok']/1e6:.1f} MFLOPs/tok){extra}")


if __name__ == "__main__":
    main()
