"""Paper Fig. 10: offline throughput.

(a) Real engine run (tiny model, CPU ref path) with dataset-like length
    mixes — measures the *system* overheads (scheduling, batching, KV).
(b) Modeled v5e/A100 throughput: NanoFlow schedule vs sequential baseline vs
    Eq. 9 optimal for the paper's model and workloads — the paper's headline
    "% of optimal" numbers.
"""
from __future__ import annotations

import dataclasses
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core import costmodel as cm
from repro.core.autosearch import (autosearch, sequential_schedule,
                                   throughput_estimate)
from repro.models import model
from repro.serving.config import EngineConfig
from repro.serving.engine import ServeEngine
from repro.serving.request import Request

WORKLOADS = [("const_512_1024", 512, 1024), ("const_1024_512", 1024, 512),
             ("sharegpt", 246, 322), ("lmsys", 102, 222),
             ("splitwise", 1155, 211)]


def modeled(arch: str, hw: cm.Hardware, n_dev: int, bdense: float = 2048
            ) -> list[dict]:
    cfg = get_config(arch)
    ms = cm.model_stats(cfg)
    opt = cm.optimal_throughput(hw, ms, n_dev) / n_dev
    rows = []
    for name, p, d in WORKLOADS:
        w = cm.Workload(p, d)
        nano = autosearch(cfg, w, hw, n_dev, bdense=bdense)
        seq = sequential_schedule(cfg, w, hw, n_dev, bdense=bdense)
        tp_n = throughput_estimate(cfg, nano, w, hw, n_dev, bdense=bdense)
        tp_s = throughput_estimate(cfg, seq, w, hw, n_dev, bdense=bdense)
        rows.append({
            "bench": "offline_throughput_model",
            "case": f"{arch}@{n_dev}x{hw.name}/{name}",
            "nanoflow_tok_s_dev": round(tp_n, 1),
            "sequential_tok_s_dev": round(tp_s, 1),
            "optimal_tok_s_dev": round(opt, 1),
            "pct_optimal": round(100 * tp_n / opt, 1),
            "speedup": round(tp_n / tp_s, 3),
        })
    return rows


def _submit_workload(eng, name: str, p: int, d: int, n_requests: int,
                     vocab: int, rid0: int, p_cap: int = 64,
                     d_cap: int = 32) -> None:
    rng = np.random.default_rng(0)
    for i in range(n_requests):
        plen = max(2, int(rng.exponential(p))) if "like" in name else p
        dlen = max(2, int(rng.exponential(d))) if "like" in name else d
        eng.submit(Request(rid=rid0 + i,
                           prompt=list(rng.integers(0, vocab,
                                                    size=min(plen, p_cap))),
                           max_new_tokens=min(dlen, d_cap)))


# step-mode A/B matrix (DESIGN.md §8-§10): the async pipelined packed step
# (scheduling overlaps device compute, sampled tokens synced one iteration
# late) vs the same step retired eagerly, vs the packed step sweeping the
# full max_len cache (the pre-§9 baseline), vs the legacy
# decode-then-per-chunk step, plus the O(p²/chunk) recompute baseline
ENGINE_MODES = [
    ("packed-async", {"step_mode": "packed", "async_depth": 1}),
    ("packed", {"step_mode": "packed", "async_depth": 0}),
    ("packed-dense-kv", {"step_mode": "packed", "async_depth": 0,
                         "kv_bucketing": False}),
    ("legacy", {"step_mode": "legacy"}),
    ("recompute", {"step_mode": "legacy", "prefill_mode": "recompute"}),
]


def engine_measured(n_requests: int = 16,
                    base: EngineConfig = EngineConfig()) -> list[dict]:
    """Real engine runs, A/B-ing the asynchronously pipelined packed step
    (DESIGN.md §10, ``async_depth=1`` — iteration i+1 is formed and
    launched before iteration i's sampled tokens are retrieved) against
    the eager kv-bucketed packed step, the same packed step sweeping the
    full ``max_len`` cache every iteration (the PR-2/DESIGN.md-§8
    baseline, ``kv_bucketing=False``), the legacy decode + per-chunk
    step, and the prefix-recompute baseline (O(p²/chunk), DESIGN.md §7).
    Each mode runs the workload twice and reports the second (warmed)
    pass, so XLA compile time — which differs between the modes'
    compile-cache footprints — doesn't pollute the A/B.  Reported per
    mode: tokens/s, dispatches/iteration, host syncs/iteration, prefill
    expansion, the packed step's bucketing-padding fraction, the
    kv-bucket histogram, the attention-sweep fraction (swept rows /
    max_len rows — the FLOPs/bytes saving of §9), and the §10 overlap
    split (blocking syncs/iteration, blocked/host/dispatch seconds,
    speculative overshoot tokens)."""
    cfg = get_config("tiny-toy")
    params = model.init(cfg, jax.random.PRNGKey(0))
    flops_fwd = 2 * model.active_params(cfg)
    rows = []
    # prompt:decode ratios scaled from the paper's workloads (splitwise
    # 1155:211 ≈ 5:1 prefill-heavy, sharegpt 246:322 decode-leaning); 8
    # slots so iterations carry several concurrent prefill chunks — the
    # dense-batch regime where the legacy step pays 1 + K dispatches.
    # "longctx-like" provisions a 512-token cache but serves mixed-length
    # contexts — the regime §9's kv bucketing targets: the dense baseline
    # sweeps slots × 512 rows every iteration regardless of actual context
    for name, p, d, max_len, p_cap, d_cap, n_req in [
            ("splitwise-like", 40, 8, 128, 64, 32, n_requests),
            ("sharegpt-like", 12, 16, 128, 64, 32, n_requests),
            ("longctx-like", 104, 12, 512, 160, 16, min(n_requests, 10))]:
        per_mode: dict[str, dict] = {}
        for mode, kwargs in ENGINE_MODES:
            # mode config on top of the CLI base (EngineConfig satellite:
            # one shared validated surface; the A/B matrix pins its own
            # axes, the base supplies attention toggles etc.)
            mode_kw = dict(step_mode=None, async_depth=None,
                           prefill_mode="incremental", kv_bucketing=True,
                           prefix_caching=False, tp=1)
            mode_kw.update(kwargs)
            ecfg = dataclasses.replace(
                base, max_slots=8, max_len=max_len,
                discrete_sizes=(64, 32, 16, 8), avg_decode_len=float(d),
                **mode_kw)
            eng = ServeEngine(cfg, params, ecfg)
            # warmup pass: the *identical* workload -> compiles every
            # (T bucket, kv bucket) program the measured pass will launch
            _submit_workload(eng, name, p, d, n_req, cfg.vocab_size, 0,
                             p_cap=p_cap, d_cap=d_cap)
            eng.run()
            warm = dataclasses.replace(
                eng.stats,
                dense_batch_hist=dict(eng.stats.dense_batch_hist),
                kv_bucket_hist=dict(eng.stats.kv_bucket_hist))
            warm_drop = eng.scheduler.dropped_tokens
            # measured pass
            _submit_workload(eng, name, p, d, n_req, cfg.vocab_size,
                             n_req, p_cap=p_cap, d_cap=d_cap)
            done = eng.run()
            st = dataclasses.replace(
                eng.stats,
                dense_batch_hist=dict(eng.stats.dense_batch_hist),
                kv_bucket_hist=dict(eng.stats.kv_bucket_hist))
            dropped = eng.scheduler.dropped_tokens - warm_drop
            tokens = st.total_tokens - warm.total_tokens
            wall = st.wall_time - warm.wall_time
            # second measured pass, best-of taken: single-core CPU wall
            # times swing 2-3x under scheduler noise — best-of-2 keeps the
            # mode-vs-mode ratios honest without a longer run (the slow
            # recompute baseline is left at one pass; it sits 20-60x off)
            if mode != "recompute":
                _submit_workload(eng, name, p, d, n_req, cfg.vocab_size,
                                 2 * n_req, p_cap=p_cap, d_cap=d_cap)
                eng.run()
                tok2 = eng.stats.total_tokens - st.total_tokens
                wall2 = eng.stats.wall_time - st.wall_time
                if tok2 / max(wall2, 1e-9) > tokens / max(wall, 1e-9):
                    tokens, wall = tok2, wall2
            iters = st.iterations - warm.iterations
            prefill_tok = st.prefill_tokens - warm.prefill_tokens
            model_tok = st.prefill_model_tokens - warm.prefill_model_tokens
            expansion = model_tok / max(prefill_tok, 1)
            pad = st.packed_pad_tokens - warm.packed_pad_tokens
            kv_hist = {b: st.kv_bucket_hist.get(b, 0)
                       - warm.kv_bucket_hist.get(b, 0)
                       for b in st.kv_bucket_hist}
            kv_rows = st.packed_attn_kv_rows - warm.packed_attn_kv_rows
            kv_iters = sum(kv_hist.values())
            per_mode[mode] = {
                "bench": "offline_throughput_engine",
                "case": f"tiny-toy/{name}/{mode}",
                "finished": len(done),
                "tokens": tokens,
                "tok_s_cpu": round(tokens / max(wall, 1e-9), 1),
                "_tok_s_raw": tokens / max(wall, 1e-9),
                "iters": iters,
                "dispatches_per_iter": round(
                    (st.model_dispatches - warm.model_dispatches)
                    / max(iters, 1), 3),
                "host_syncs_per_iter": round(
                    (st.host_syncs - warm.host_syncs) / max(iters, 1), 3),
                "prefill_expansion": round(expansion, 3),
                "prefill_flops_per_tok": round(flops_fwd * expansion),
                "pad_fraction": round(pad / max(tokens + pad, 1), 3),
                # DESIGN.md §9 observability: which kv buckets launched, and
                # the attention sweep as a fraction of the dense max_len
                # sweep (attention FLOPs/bytes scale with this)
                "packed_attn_kv_bucket": {str(b): n for b, n
                                          in sorted(kv_hist.items())},
                "attn_kv_sweep_frac": round(
                    kv_rows / max((tokens + pad) * eng.max_len, 1), 3)
                if kv_iters else None,
                # §10 host/device overlap split (measured pass): how often
                # the deferred sync actually stalled the host, and where
                # the wall clock went
                "async_depth": eng.async_depth,
                "blocking_syncs_per_iter": round(
                    (st.blocking_syncs - warm.blocking_syncs)
                    / max(iters, 1), 3),
                "blocked_sync_s": round(
                    st.blocked_sync_time - warm.blocked_sync_time, 3),
                "host_s": round(st.host_time - warm.host_time, 3),
                "dispatch_s": round(st.dispatch_time - warm.dispatch_time, 3),
                "overshoot_tokens": dropped,
            }
        pk, leg = per_mode["packed"], per_mode["legacy"]
        pk["speedup_vs_dense_kv"] = round(
            pk["_tok_s_raw"]
            / max(per_mode["packed-dense-kv"]["_tok_s_raw"], 1e-9), 3)
        pk["speedup_vs_legacy"] = round(
            pk["_tok_s_raw"] / max(leg["_tok_s_raw"], 1e-9), 3)
        pk["speedup_vs_recompute"] = round(
            pk["_tok_s_raw"] / max(per_mode["recompute"]["_tok_s_raw"], 1e-9),
            3)
        # §10 async-vs-eager axis: same packed program, same dispatch/sync
        # counts — the delta is the host/device overlap
        per_mode["packed-async"]["speedup_vs_eager"] = round(
            per_mode["packed-async"]["_tok_s_raw"]
            / max(pk["_tok_s_raw"], 1e-9), 3)
        for r in per_mode.values():
            r.pop("_tok_s_raw")
        rows += list(per_mode.values())
    return rows


def engine_tp_ab(tp: int, n_requests: int = 12) -> list[dict]:
    """Tensor-parallel axis (DESIGN.md §11): the async packed step at tp=1
    vs tp=N (shard_map over host-platform devices), warmed, same workload.
    On this CPU container tp>1 adds real ring collectives on one physical
    core — the interesting numbers are the A/B shape (still 1 dispatch + 1
    sync/iter) and the modeled collective bytes/iteration, not a speedup."""
    cfg = get_config("tiny-toy")
    params = model.init(cfg, jax.random.PRNGKey(0))
    name, p, d, max_len = "splitwise-like", 40, 8, 128
    rows = []
    raw = {}
    for tp_deg in (1, tp):
        eng = ServeEngine(cfg, params, EngineConfig(
            max_slots=8, max_len=max_len, discrete_sizes=(64, 32, 16, 8),
            avg_decode_len=float(d), step_mode="packed", async_depth=1,
            tp=tp_deg))
        _submit_workload(eng, name, p, d, n_requests, cfg.vocab_size, 0)
        eng.run()                                  # warmup: compiles all
        warm = dataclasses.replace(eng.stats)
        _submit_workload(eng, name, p, d, n_requests, cfg.vocab_size,
                         n_requests)
        done = eng.run()
        st = eng.stats
        tokens = st.total_tokens - warm.total_tokens
        wall = st.wall_time - warm.wall_time
        iters = st.iterations - warm.iterations
        raw[tp_deg] = tokens / max(wall, 1e-9)
        rows.append({
            "bench": "offline_throughput_engine",
            "case": f"tiny-toy/{name}/packed-tp{tp_deg}",
            "tp": tp_deg,
            "finished": len(done),
            "tokens": tokens,
            "tok_s_cpu": round(raw[tp_deg], 1),
            "iters": iters,
            "dispatches_per_iter": round(
                (st.model_dispatches - warm.model_dispatches)
                / max(iters, 1), 3),
            "host_syncs_per_iter": round(
                (st.host_syncs - warm.host_syncs) / max(iters, 1), 3),
            "prefill_expansion": round(
                (st.prefill_model_tokens - warm.prefill_model_tokens)
                / max(st.prefill_tokens - warm.prefill_tokens, 1), 3),
            "pad_fraction": round(
                (st.packed_pad_tokens - warm.packed_pad_tokens)
                / max(tokens + st.packed_pad_tokens
                      - warm.packed_pad_tokens, 1), 3),
            "tp_collective_bytes_per_iter": round(
                (st.tp_collective_bytes - warm.tp_collective_bytes)
                / max(iters, 1)),
        })
    rows[-1]["speedup_vs_tp1"] = round(raw[tp] / max(raw[1], 1e-9), 3)
    return rows


def engine_prefix_ab(n_requests: int = 12,
                     base: EngineConfig = EngineConfig()) -> list[dict]:
    """Shared-system-prompt workload (DESIGN.md §12): every request carries
    the same system prompt plus a short distinct user suffix — the regime
    cross-request prefix caching targets.  One priming request runs to
    completion first (sharing materializes across *non-concurrent*
    admissions: blocks register at first commit), then the measured wave of
    ``n_requests`` shared-prefix requests runs with ``prefix_caching`` off
    vs on.  Reported per mode: tokens/s, launched prefill FLOPs per prompt
    token (cached tokens are never launched, so this drops ~by the shared
    fraction), mean TTFT, the prefix-hit fraction, and the CoW copy count —
    while dispatches/iteration and host syncs/iteration must stay at the
    packed step's 1 + 1."""
    cfg = get_config("tiny-toy")
    params = model.init(cfg, jax.random.PRNGKey(0))
    flops_fwd = 2 * model.active_params(cfg)
    rng = np.random.default_rng(0)
    sys_len, sfx_len, d = 48, 8, 8
    system = [int(t) for t in rng.integers(0, cfg.vocab_size, size=sys_len)]
    sfx = rng.integers(0, cfg.vocab_size, size=(n_requests + 1, sfx_len))
    rows, raw = [], {}
    for pc in (False, True):
        ecfg = dataclasses.replace(
            base, max_slots=8, max_len=128, kv_block_size=16,
            discrete_sizes=(64, 32, 16, 8), avg_decode_len=float(d),
            step_mode="packed", async_depth=1, prefill_mode="incremental",
            kv_bucketing=True, kv_buckets=None, prefix_caching=pc, tp=1,
            total_pages=None, kv_budget_bytes=None)
        eng = ServeEngine(cfg, params, ecfg)
        # priming pass: completes one shared-prompt request (commits +
        # hash-registers its prefix blocks) and compiles every program the
        # measured wave launches
        eng.submit(Request(rid=0, prompt=system + [int(t) for t in sfx[0]],
                           max_new_tokens=d))
        eng.run()
        warm = eng.stats.snapshot()
        warm_kv = eng.kv.stats.snapshot()
        for i in range(1, n_requests + 1):
            eng.submit(Request(rid=i,
                               prompt=system + [int(t) for t in sfx[i]],
                               max_new_tokens=d,
                               arrival=time.perf_counter()))
        done = eng.run()
        st = eng.stats.snapshot()
        kvs = eng.kv.stats.snapshot()
        tokens = st["total_tokens"] - warm["total_tokens"]
        wall = st["wall_time"] - warm["wall_time"]
        iters = st["iterations"] - warm["iterations"]
        launched = (st["prefill_model_tokens"]
                    - warm["prefill_model_tokens"])
        prompt_tok = sum(r.prompt_len for r in done)
        hits = kvs["prefix_hit_tokens"] - warm_kv["prefix_hit_tokens"]
        ttft = [r.first_token_at - r.arrival for r in done
                if r.first_token_at is not None]
        mode = "prefix" if pc else "no-prefix"
        raw[mode] = {"flops": flops_fwd * launched / max(prompt_tok, 1),
                     "ttft": float(np.mean(ttft)) if ttft else 0.0,
                     "tok_s": tokens / max(wall, 1e-9)}
        rows.append({
            "bench": "offline_throughput_engine",
            "case": f"tiny-toy/shared-sysprompt/{mode}",
            "finished": len(done),
            "tokens": tokens,
            "tok_s_cpu": round(raw[mode]["tok_s"], 1),
            "iters": iters,
            "dispatches_per_iter": round(
                (st["model_dispatches"] - warm["model_dispatches"])
                / max(iters, 1), 3),
            "host_syncs_per_iter": round(
                (st["host_syncs"] - warm["host_syncs"]) / max(iters, 1), 3),
            "prefill_expansion": round(
                launched / max(st["prefill_tokens"]
                               - warm["prefill_tokens"], 1), 3),
            "prefill_flops_per_prompt_tok": round(raw[mode]["flops"]),
            "ttft_mean_ms": round(raw[mode]["ttft"] * 1e3, 1),
            "prefix_hit_frac": round(hits / max(prompt_tok, 1), 3),
            "cow_copies": kvs["cow_copies"] - warm_kv["cow_copies"],
            "evicted_blocks": (kvs["evicted_blocks"]
                               - warm_kv["evicted_blocks"]),
        })
    rows[-1]["prefill_flops_ratio_vs_no_prefix"] = round(
        raw["prefix"]["flops"] / max(raw["no-prefix"]["flops"], 1e-9), 3)
    rows[-1]["ttft_ratio_vs_no_prefix"] = round(
        raw["prefix"]["ttft"] / max(raw["no-prefix"]["ttft"], 1e-9), 3)
    return rows


def engine_spec_ab(n_requests: int = 10, spec_k: int = 4,
                   base: EngineConfig = EngineConfig()) -> list[dict]:
    """Speculative-decoding axis (DESIGN.md §13): the async packed step
    with ``spec_k`` n-gram drafts per decoding slot vs the plain engine,
    on a repetitive-text workload (motif-tiled prompts, long decodes) —
    the prompt-lookup drafter's target regime.  Greedy, so the two modes
    are token-exact by construction and the A/B isolates the schedule:
    committed tokens per model dispatch must exceed 1 for speculation to
    pay (each dispatch still sweeps the same weights — the §13 bet is
    amortizing that sweep over several committed tokens).  Reported:
    tokens/s, verify acceptance rate, accepted tokens per verify segment,
    committed decode tokens per dispatch, and the 1-dispatch /
    1-deferred-sync invariants."""
    cfg = get_config("tiny-toy")
    params = model.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    d = 40
    prompts = []
    for i in range(2 * n_requests):
        motif = [int(t) for t in rng.integers(0, cfg.vocab_size, size=4)]
        prompts.append((motif * 8)[:28 + (i % 4)])
    rows, raw = [], {}
    for k in (0, spec_k):
        ecfg = dataclasses.replace(
            base, max_slots=8, max_len=128, discrete_sizes=(64, 32, 16, 8),
            avg_decode_len=float(d), step_mode="packed", async_depth=1,
            prefill_mode="incremental", kv_bucketing=True,
            prefix_caching=False, tp=1, spec_k=k,
            drafter="ngram" if k else None, temperature=0.0, top_k=None)
        eng = ServeEngine(cfg, params, ecfg)
        # warmup: same prompt shapes -> compiles every (T, kv) program
        for i, p in enumerate(prompts[:n_requests]):
            eng.submit(Request(rid=i, prompt=list(p), max_new_tokens=d))
        eng.run()
        warm = eng.stats.snapshot()
        for i, p in enumerate(prompts[n_requests:]):
            eng.submit(Request(rid=n_requests + i, prompt=list(p),
                               max_new_tokens=d))
        done = eng.run()
        st = eng.stats.snapshot()
        tokens = st["total_tokens"] - warm["total_tokens"]
        wall = st["wall_time"] - warm["wall_time"]
        iters = st["iterations"] - warm["iterations"]
        disp = st["model_dispatches"] - warm["model_dispatches"]
        dec = st["decode_tokens"] - warm["decode_tokens"]
        segs = st["spec_verify_segments"] - warm["spec_verify_segments"]
        prop = st["spec_proposed_tokens"] - warm["spec_proposed_tokens"]
        acc = st["spec_accepted_tokens"] - warm["spec_accepted_tokens"]
        mode = f"spec-k{k}" if k else "no-spec"
        raw[mode] = {"tok_s": tokens / max(wall, 1e-9),
                     "dec_per_disp": dec / max(disp, 1)}
        rows.append({
            "bench": "offline_throughput_engine",
            "case": f"tiny-toy/repetitive/{mode}",
            "spec_k": k,
            "finished": len(done),
            "tokens": tokens,
            "tok_s_cpu": round(raw[mode]["tok_s"], 1),
            "iters": iters,
            "dispatches_per_iter": round(disp / max(iters, 1), 3),
            "host_syncs_per_iter": round(
                (st["host_syncs"] - warm["host_syncs"]) / max(iters, 1), 3),
            "decode_tokens_per_dispatch": round(raw[mode]["dec_per_disp"],
                                                3),
            "spec_verify_segments": segs,
            "spec_acceptance_rate": round(acc / prop, 3) if prop else None,
            "spec_accepted_per_verify": round((acc + segs) / segs, 3)
            if segs else None,
        })
    rows[-1]["speedup_vs_no_spec"] = round(
        raw[f"spec-k{spec_k}"]["tok_s"] / max(raw["no-spec"]["tok_s"], 1e-9),
        3)
    rows[-1]["decode_per_dispatch_vs_no_spec"] = round(
        raw[f"spec-k{spec_k}"]["dec_per_disp"]
        / max(raw["no-spec"]["dec_per_disp"], 1e-9), 3)
    return rows


def _kvdtype_logit_drift(cfg, max_len: int = 48) -> float:
    """Teacher-forced packed forward, native vs int8 cache, f32 weights —
    the max |Δlogit| sample reported in the A/B (and stashed into
    ``EngineStats.kv_quant_drift``).  f32 isolates quantization drift from
    bf16 accumulation noise."""
    import jax.numpy as jnp
    fcfg = dataclasses.replace(cfg, dtype="float32")
    params = model.init(fcfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompt = rng.integers(0, fcfg.vocab_size, size=24).astype(np.int32)
    t = len(prompt)
    tok = jnp.asarray(prompt)[None]
    pos = jnp.arange(t, dtype=jnp.int32)
    slot = jnp.zeros(t, jnp.int32)
    act = jnp.ones(t, jnp.int32)
    outs = {}
    for kd in (None, "int8"):
        cache = model.init_cache(fcfg, 1, 2, max_len, kd)
        logits, _ = model.forward_packed(fcfg, params, tok, cache, slot,
                                         pos, pos, act, kv_bucket=max_len)
        outs[kd] = np.asarray(logits, np.float32)
    return float(np.abs(outs[None] - outs["int8"]).max())


def engine_kvdtype_ab(n_requests: int = 10,
                      base: EngineConfig = EngineConfig()) -> list[dict]:
    """int8 KV-cache axis (DESIGN.md §15): the async packed step with the
    native bf16 cache vs the quantized int8 cache, at the SAME
    ``kv_budget_bytes`` — the quantized engine's pages budget admits ~2x
    the token rows, which is the whole point (Eq. 5: B_req scales with KV
    capacity).  head_dim 128 (the production shape) so the f32 scale
    overhead is 4/128 per element and the ratio clears 1.9x.  Reported per
    mode: tokens/s, device pages / max concurrent full-length slots at the
    fixed budget, attention HBM bytes/iteration from the cost-model byte
    rate (swept KV rows × eval_shape bytes/token-row), the bytes-saved
    counter, a teacher-forced max-logit-drift sample, and the greedy
    token-match fraction vs the native engine."""
    from repro.serving.engine import kv_bytes_per_token
    cfg = dataclasses.replace(get_config("tiny-toy"), head_dim=128)
    params = model.init(cfg, jax.random.PRNGKey(0))
    name, p, d, max_len = "sharegpt-like", 12, 8, 128
    budget = kv_bytes_per_token(cfg) * 8 * max_len   # 8 native-rate slots
    drift = _kvdtype_logit_drift(cfg)
    rows, raw, outs = [], {}, {}
    for kd in ("bf16", "int8"):
        ecfg = dataclasses.replace(
            base, max_slots=8, max_len=max_len,
            discrete_sizes=(64, 32, 16, 8), avg_decode_len=float(d),
            step_mode="packed", async_depth=1, prefill_mode="incremental",
            kv_bucketing=True, prefix_caching=False, tp=1, spec_k=0,
            total_pages=None, kv_budget_bytes=budget, kv_dtype=kd)
        eng = ServeEngine(cfg, params, ecfg)
        # warmup: identical workload -> compiles every (T, kv) program
        _submit_workload(eng, name, p, d, n_requests, cfg.vocab_size, 0)
        eng.run()
        warm = eng.stats.snapshot()
        _submit_workload(eng, name, p, d, n_requests, cfg.vocab_size,
                         n_requests)
        done = eng.run()
        if kd == "int8":
            eng.stats.kv_quant_drift = drift
        st = eng.stats.snapshot()
        outs[kd] = {r.rid: tuple(r.output) for r in done}
        tokens = st["total_tokens"] - warm["total_tokens"]
        wall = st["wall_time"] - warm["wall_time"]
        iters = st["iterations"] - warm["iterations"]
        kv_rows = st["packed_attn_kv_rows"] - warm["packed_attn_kv_rows"]
        pages = eng.kv.stats.device_pages_total
        raw[kd] = {"tok_s": tokens / max(wall, 1e-9), "pages": pages}
        rows.append({
            "bench": "offline_throughput_engine",
            "case": f"tiny-toy-hd128/{name}/kv-{kd}",
            "kv_dtype": kd,
            "finished": len(done),
            "tokens": tokens,
            "tok_s_cpu": round(raw[kd]["tok_s"], 1),
            "iters": iters,
            "dispatches_per_iter": round(
                (st["model_dispatches"] - warm["model_dispatches"])
                / max(iters, 1), 3),
            "host_syncs_per_iter": round(
                (st["host_syncs"] - warm["host_syncs"]) / max(iters, 1), 3),
            "kv_budget_bytes": budget,
            "kv_bytes_per_token": eng.kv.bytes_per_token,
            "device_pages_total": pages,
            "max_full_len_slots": pages * eng.kv.page_size // max_len,
            "attn_kv_bytes_per_iter": round(
                kv_rows * eng.kv.bytes_per_token / max(iters, 1)),
            "kv_quant_bytes_saved": (st["kv_quant_bytes_saved"]
                                     - warm["kv_quant_bytes_saved"]),
            "max_logit_drift_f32": round(drift, 5) if kd == "int8" else 0.0,
        })
    match = [rid for rid in outs["bf16"]
             if outs["bf16"][rid] == outs["int8"].get(rid)]
    rows[-1]["pages_ratio_vs_bf16"] = round(
        raw["int8"]["pages"] / max(raw["bf16"]["pages"], 1), 3)
    rows[-1]["speedup_vs_bf16"] = round(
        raw["int8"]["tok_s"] / max(raw["bf16"]["tok_s"], 1e-9), 3)
    rows[-1]["greedy_match_frac"] = round(
        len(match) / max(len(outs["bf16"]), 1), 3)
    return rows


def run(engine_only: bool = False, base: EngineConfig = EngineConfig(),
        tp: int = 1, tp_only: bool = False,
        spec_only: bool = False, kvdtype_only: bool = False) -> list[dict]:
    if tp_only:
        return engine_tp_ab(tp)
    if spec_only:
        return engine_spec_ab(base=base)
    if kvdtype_only:
        return engine_kvdtype_ab(base=base)
    out = [] if engine_only else (
        modeled("llama2-70b", cm.A100_80G, 8)
        + modeled("qwen3-8b", cm.TPU_V5E, 16))
    out += engine_measured(base=base)
    out += engine_prefix_ab(base=base)
    out += engine_spec_ab(base=base)
    out += engine_kvdtype_ab(base=base)
    if tp > 1:
        out += engine_tp_ab(tp)
    return out


def main(argv=None) -> None:
    import argparse
    import json

    ap = argparse.ArgumentParser()
    ap.add_argument("--engine-only", action="store_true",
                    help="skip the modeled-hardware rows (CI smoke)")
    ap.add_argument("--json", default=None,
                    help="also write the rows as a JSON artifact")
    ap.add_argument("--tp-only", action="store_true",
                    help="run only the tp=1-vs-tp=N A/B rows (DESIGN.md "
                         "§11; --tp forces N host-platform devices — CI "
                         "runs the tp axis as a separate invocation to keep "
                         "the baseline rows' environment unchanged)")
    ap.add_argument("--spec-only", action="store_true",
                    help="run only the speculative-decoding A/B rows "
                         "(DESIGN.md §13: n-gram drafts vs plain packed "
                         "engine on a repetitive-text workload)")
    ap.add_argument("--kvdtype-only", action="store_true",
                    help="run only the int8-KV A/B rows (DESIGN.md §15: "
                         "bf16 vs int8 cache at the same kv_budget_bytes — "
                         "pages admitted, tok/s, attention bytes/iter, "
                         "logit drift, greedy match)")
    # engine knobs are defined ONCE on EngineConfig (--tp, --attn-fast,
    # --attn-stream, ... — the same surface as launch/serve.py); the mode
    # matrices pin their own A/B axes on top of this base
    EngineConfig.add_args(ap)
    args = ap.parse_args(argv)
    if args.tp_only and args.tp <= 1:
        ap.error("--tp-only needs --tp N with N > 1")
    if args.tp > 1:
        # before the first jax operation: importing jax does not initialize
        # the backend, so the host-device flag still takes effect here
        from repro.launch.serve import ensure_host_devices
        ensure_host_devices(args.tp)
    rows = run(engine_only=args.engine_only,
               base=EngineConfig.from_args(args), tp=args.tp,
               tp_only=args.tp_only, spec_only=args.spec_only,
               kvdtype_only=args.kvdtype_only)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=2)
    for r in rows:
        if r["bench"] == "offline_throughput_model":
            print(f"fig10/{r['case']},0.0,"
                  f"nano={r['nanoflow_tok_s_dev']} seq={r['sequential_tok_s_dev']} "
                  f"opt={r['optimal_tok_s_dev']} ({r['pct_optimal']}% of optimal, "
                  f"{r['speedup']}x)")
        elif "spec_k" in r:
            extra = ""
            if "speedup_vs_no_spec" in r:
                extra = (f" [{r['speedup_vs_no_spec']}x vs no-spec, "
                         f"{r['decode_per_dispatch_vs_no_spec']}x "
                         f"decode/dispatch]")
            spec = ""
            if r["spec_acceptance_rate"] is not None:
                spec = (f", accept {r['spec_acceptance_rate']}, "
                        f"{r['spec_accepted_per_verify']} tok/verify")
            print(f"fig10/{r['case']},0.0,{r['tok_s_cpu']} tok/s CPU "
                  f"({r['tokens']} tokens, {r['iters']} iters, "
                  f"{r['dispatches_per_iter']} disp/it, "
                  f"{r['host_syncs_per_iter']} sync/it, "
                  f"{r['decode_tokens_per_dispatch']} decode tok/dispatch"
                  f"{spec}){extra}")
        elif "kv_dtype" in r:
            extra = ""
            if "pages_ratio_vs_bf16" in r:
                extra = (f" [{r['pages_ratio_vs_bf16']}x pages, "
                         f"{r['speedup_vs_bf16']}x tok/s vs bf16, "
                         f"greedy match {r['greedy_match_frac']}, "
                         f"drift {r['max_logit_drift_f32']}]")
            print(f"fig10/{r['case']},0.0,{r['tok_s_cpu']} tok/s CPU "
                  f"({r['tokens']} tokens, {r['iters']} iters, "
                  f"{r['dispatches_per_iter']} disp/it, "
                  f"{r['host_syncs_per_iter']} sync/it, "
                  f"{r['device_pages_total']} pages / "
                  f"{r['max_full_len_slots']} full-len slots @ fixed "
                  f"budget, {r['attn_kv_bytes_per_iter'] / 1e3:.1f} KB "
                  f"attn/it, saved {r['kv_quant_bytes_saved'] / 1e3:.0f} KB)"
                  f"{extra}")
        elif "prefix_hit_frac" in r:
            extra = ""
            if "prefill_flops_ratio_vs_no_prefix" in r:
                extra = (f" [{r['prefill_flops_ratio_vs_no_prefix']}x "
                         f"prefill FLOPs, {r['ttft_ratio_vs_no_prefix']}x "
                         f"TTFT vs no-prefix]")
            print(f"fig10/{r['case']},0.0,{r['tok_s_cpu']} tok/s CPU "
                  f"({r['tokens']} tokens, {r['iters']} iters, "
                  f"{r['dispatches_per_iter']} disp/it, "
                  f"{r['host_syncs_per_iter']} sync/it, "
                  f"{r['prefill_flops_per_prompt_tok']} prefill "
                  f"FLOPs/prompt tok, ttft {r['ttft_mean_ms']} ms, "
                  f"prefix hits {r['prefix_hit_frac']}, "
                  f"{r['cow_copies']} CoW){extra}")
        else:
            extra = ""
            if "speedup_vs_legacy" in r:
                extra = (f" [{r['speedup_vs_dense_kv']}x vs dense-kv, "
                         f"{r['speedup_vs_legacy']}x vs legacy, "
                         f"{r['speedup_vs_recompute']}x vs recompute]")
            if "speedup_vs_eager" in r:
                extra = (f" [depth {r['async_depth']}: "
                         f"{r['speedup_vs_eager']}x vs eager packed, "
                         f"{r['blocking_syncs_per_iter']} blocking sync/it, "
                         f"blocked {r['blocked_sync_s']}s "
                         f"host {r['host_s']}s, "
                         f"{r['overshoot_tokens']} overshoot]")
            if "tp" in r:
                extra = (f" [tp={r['tp']}: "
                         f"{r['tp_collective_bytes_per_iter'] / 1e3:.1f} KB "
                         f"collective/it"
                         + (f", {r['speedup_vs_tp1']}x vs tp1"
                            if "speedup_vs_tp1" in r else "") + "]")
            sweep = (f", kv sweep {r['attn_kv_sweep_frac']}x"
                     if r.get("attn_kv_sweep_frac") is not None else "")
            print(f"fig10/{r['case']},0.0,{r['tok_s_cpu']} tok/s CPU "
                  f"({r['tokens']} tokens, {r['iters']} iters, "
                  f"{r['dispatches_per_iter']} disp/it, "
                  f"{r['host_syncs_per_iter']} sync/it, "
                  f"{r['prefill_expansion']}x prefill work, "
                  f"pad {r['pad_fraction']}{sweep}){extra}")


if __name__ == "__main__":
    main()
