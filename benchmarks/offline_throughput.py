"""Paper Fig. 10: offline throughput.

(a) Real engine run (tiny model, CPU ref path) with dataset-like length
    mixes — measures the *system* overheads (scheduling, batching, KV).
(b) Modeled v5e/A100 throughput: NanoFlow schedule vs sequential baseline vs
    Eq. 9 optimal for the paper's model and workloads — the paper's headline
    "% of optimal" numbers.
"""
from __future__ import annotations

import jax
import numpy as np

from repro.configs import get_config
from repro.core import costmodel as cm
from repro.core.autosearch import (autosearch, sequential_schedule,
                                   throughput_estimate)
from repro.models import model
from repro.serving.engine import ServeEngine
from repro.serving.request import Request

WORKLOADS = [("const_512_1024", 512, 1024), ("const_1024_512", 1024, 512),
             ("sharegpt", 246, 322), ("lmsys", 102, 222),
             ("splitwise", 1155, 211)]


def modeled(arch: str, hw: cm.Hardware, n_dev: int, bdense: float = 2048
            ) -> list[dict]:
    cfg = get_config(arch)
    ms = cm.model_stats(cfg)
    opt = cm.optimal_throughput(hw, ms, n_dev) / n_dev
    rows = []
    for name, p, d in WORKLOADS:
        w = cm.Workload(p, d)
        nano = autosearch(cfg, w, hw, n_dev, bdense=bdense)
        seq = sequential_schedule(cfg, w, hw, n_dev, bdense=bdense)
        tp_n = throughput_estimate(cfg, nano, w, hw, n_dev, bdense=bdense)
        tp_s = throughput_estimate(cfg, seq, w, hw, n_dev, bdense=bdense)
        rows.append({
            "bench": "offline_throughput_model",
            "case": f"{arch}@{n_dev}x{hw.name}/{name}",
            "nanoflow_tok_s_dev": round(tp_n, 1),
            "sequential_tok_s_dev": round(tp_s, 1),
            "optimal_tok_s_dev": round(opt, 1),
            "pct_optimal": round(100 * tp_n / opt, 1),
            "speedup": round(tp_n / tp_s, 3),
        })
    return rows


def engine_measured(n_requests: int = 12) -> list[dict]:
    cfg = get_config("tiny-toy")
    params = model.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    rows = []
    for name, p, d in [("sharegpt-like", 12, 16), ("const", 16, 8)]:
        eng = ServeEngine(cfg, params, max_slots=4, max_len=128,
                          discrete_sizes=(64, 32, 16, 8), avg_decode_len=d)
        for i in range(n_requests):
            plen = max(2, int(rng.exponential(p))) if "like" in name else p
            dlen = max(2, int(rng.exponential(d))) if "like" in name else d
            eng.submit(Request(rid=i,
                               prompt=list(rng.integers(0, cfg.vocab_size,
                                                        size=min(plen, 64))),
                               max_new_tokens=min(dlen, 32)))
        done = eng.run()
        st = eng.stats
        rows.append({
            "bench": "offline_throughput_engine",
            "case": f"tiny-toy/{name}",
            "finished": len(done),
            "tokens": st.total_tokens,
            "tok_s_cpu": round(st.throughput, 1),
            "iters": st.iterations,
        })
    return rows


def run() -> list[dict]:
    out = modeled("llama2-70b", cm.A100_80G, 8)
    out += modeled("qwen3-8b", cm.TPU_V5E, 16)
    out += engine_measured()
    return out


def main() -> None:
    for r in run():
        if r["bench"] == "offline_throughput_model":
            print(f"fig10/{r['case']},0.0,"
                  f"nano={r['nanoflow_tok_s_dev']} seq={r['sequential_tok_s_dev']} "
                  f"opt={r['optimal_tok_s_dev']} ({r['pct_optimal']}% of optimal, "
                  f"{r['speedup']}x)")
        else:
            print(f"fig10/{r['case']},0.0,{r['tok_s_cpu']} tok/s CPU "
                  f"({r['tokens']} tokens, {r['iters']} iters)")


if __name__ == "__main__":
    main()
