"""Paper Fig. 11/12: online latency vs request rate + latency CDF.

Poisson arrivals against the real engine (tiny model).  The *shape* of the
latency-vs-rate curve (flat then hockey-stick at saturation) and the tight
CDF under discrete batching are the paper's claims; absolute numbers are CPU
proxies."""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import model
from repro.serving.engine import ServeEngine
from repro.serving.request import Request


def run_rate(rate: float, n_requests: int = 24, seed: int = 0) -> dict:
    cfg = get_config("tiny-toy")
    params = model.init(cfg, jax.random.PRNGKey(0))
    eng = ServeEngine(cfg, params, max_slots=4, max_len=96,
                      discrete_sizes=(32, 16, 8), avg_decode_len=6)
    rng = np.random.default_rng(seed)
    reqs = [Request(rid=i, prompt=list(rng.integers(0, cfg.vocab_size,
                                                    size=int(rng.integers(4, 16)))),
                    max_new_tokens=int(rng.integers(3, 9)))
            for i in range(n_requests)]
    arrivals = np.cumsum(rng.exponential(1.0 / rate, size=n_requests))
    t0 = time.perf_counter()
    done, i = [], 0
    while len(done) < n_requests and time.perf_counter() - t0 < 120:
        now = time.perf_counter() - t0
        while i < n_requests and arrivals[i] <= now:
            # absolute stamp: finished_at (commit time) is absolute
            # perf_counter, so finished_at - arrival is a real latency
            reqs[i].arrival = t0 + arrivals[i]
            eng.submit(reqs[i])
            i += 1
        plan = eng.scheduler.plan()
        if plan is None:
            # oldest in-flight commit may unblock planning (§10)
            done += eng.drain(max_retire=1)
            if i < n_requests:
                time.sleep(min(arrivals[i] - now, 0.01))
            continue
        done += eng.step(plan)
    done += eng.drain()
    norm = [((r.finished_at or 0) - r.arrival) / max(len(r.output), 1)
            for r in done]
    st = eng.stats
    flops_fwd = 2 * model.active_params(cfg)
    return {
        "bench": "online_latency", "rate": rate, "finished": len(done),
        "p50_ms": round(float(np.percentile(norm, 50)) * 1e3, 1),
        "p90_ms": round(float(np.percentile(norm, 90)) * 1e3, 1),
        "p99_ms": round(float(np.percentile(norm, 99)) * 1e3, 1),
        # incremental chunked prefill keeps this at 1.0 (linear work);
        # the recompute path would inflate it (DESIGN.md §7)
        "prefill_expansion": round(st.prefill_expansion, 3),
        "prefill_flops_per_tok": round(flops_fwd * st.prefill_expansion),
    }


def run() -> list[dict]:
    return [run_rate(r) for r in (2.0, 6.0, 16.0)]


def main() -> None:
    rows = run()
    for r in rows:
        print(f"fig11/rate{r['rate']},{r['p50_ms']*1e3:.0f},"
              f"p50={r['p50_ms']}ms/tok p99={r['p99_ms']}ms/tok "
              f"finished={r['finished']} "
              f"prefill={r['prefill_flops_per_tok']/1e6:.1f}MFLOPs/tok"
              f"({r['prefill_expansion']}x)")
    # Fig. 12: CDF tightness at the highest sustainable rate
    r = rows[-1]
    ratio = r["p99_ms"] / max(r["p50_ms"], 1e-9)
    print(f"fig12/p99_over_p50,{ratio:.3f},paper: 1.07x at 90% max throughput")


if __name__ == "__main__":
    main()
