"""Paper Fig. 11/12 + DESIGN.md §14: online latency under Poisson and
bursty arrivals, single replica vs pool, with a chaos smoke.

The *shape* of the latency-vs-rate curve (flat, then hockey-stick at
saturation) and the tight CDF under discrete batching are the paper's
claims; absolute numbers are CPU proxies.  Per workload class this reports
TTFT and TPOT p50/p95/p99 over *finished* requests only — an unfinished
request contributes to the ``finished``/``shed`` counts, never a fabricated
latency (the old ``finished_at or 0`` fallback produced negative
latencies).  The arrival loop lives in ``ReplicaPool.run_online``: it
sleeps only when idle, never busy-waits, and never over-sleeps past the
next arrival.

Modes:
  * default        — pool-vs-single A/B across workload classes
                     (``--json BENCH_8.json`` commits the artifact)
  * --chaos-smoke  — 2 replicas, seeded kill of replica 1 mid-stream;
                     asserts zero lost responses (completed + shed ==
                     submitted) in the JSON row
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import model
from repro.serving.config import EngineConfig, PoolConfig
from repro.serving.engine import ServeEngine
from repro.serving.faults import FaultPlan
from repro.serving.pool import ReplicaPool
from repro.serving.request import Request


def _fixture():
    cfg = get_config("tiny-toy")
    params = model.init(cfg, jax.random.PRNGKey(0))
    ecfg = EngineConfig(max_slots=4, max_len=96, discrete_sizes=(32, 16, 8),
                        avg_decode_len=6.0)
    return cfg, params, ecfg


def make_workload(kind: str, n: int, rate: float, vocab: int,
                  seed: int = 0) -> tuple[list[Request], list[float]]:
    """Arrival offsets for one class.

    ``poisson``: exponential inter-arrivals at ``rate`` req/s.
    ``bursty``:  on/off process — bursts of 4 back-to-back arrivals at 4x
    rate separated by idle gaps, same long-run mean rate (the ScaleLLM-style
    workload where p99 separates systems that p50 cannot)."""
    rng = np.random.default_rng(seed)
    reqs = [Request(rid=i,
                    prompt=list(rng.integers(0, vocab,
                                             size=int(rng.integers(4, 16)))),
                    max_new_tokens=int(rng.integers(3, 9)))
            for i in range(n)]
    if kind == "poisson":
        offsets = np.cumsum(rng.exponential(1.0 / rate, size=n))
    elif kind == "bursty":
        offsets, t, burst = [], 0.0, 4
        while len(offsets) < n:
            for _ in range(min(burst, n - len(offsets))):
                t += rng.exponential(1.0 / (4.0 * rate))
                offsets.append(t)
            t += rng.exponential(burst * 0.75 / rate)   # off period
        offsets = np.asarray(offsets[:n])
    else:
        raise ValueError(f"unknown workload class {kind!r}")
    return reqs, list(map(float, offsets))


def _pct(xs: list[float], q: float) -> float:
    return round(float(np.percentile(xs, q)) * 1e3, 2) if xs else None


def run_class(kind: str, replicas: int, rate: float, n: int, seed: int,
              fault_plan: str = "", timeout_s: float = 120.0) -> dict:
    cfg, params, ecfg = _fixture()

    def mk():
        return ServeEngine(cfg, params, ecfg)

    engines = [mk() for _ in range(replicas)]
    for k, eng in enumerate(engines):
        # warm the jit caches before the clock starts: a cold engine's
        # first iterations are compile time, not serving latency, and
        # would swamp the pool-vs-single comparison
        for j in range(4):
            eng.submit(Request(rid=10_000 + 10 * k + j,
                               prompt=list(range(2, 14)), max_new_tokens=6))
        eng.run()
    pool = ReplicaPool(
        engines, PoolConfig(replicas=replicas),
        fault_plan=FaultPlan.parse(fault_plan) if fault_plan else None)
    reqs, offsets = make_workload(kind, n, rate, cfg.vocab_size, seed)
    t0 = time.perf_counter()
    results = pool.run_online(reqs, offsets, duration=timeout_s)
    wall = time.perf_counter() - t0

    done = list(results.values())
    ttft = [r.first_token_at - r.arrival for r in done
            if r.first_token_at is not None]
    tpot = [(r.finished_at - r.first_token_at) / (len(r.output) - 1)
            for r in done
            if r.finished_at is not None and r.first_token_at is not None
            and len(r.output) > 1]
    snap = pool.snapshot()
    return {
        "bench": "online_latency", "class": kind, "replicas": replicas,
        "rate": rate, "submitted": snap["submitted"],
        "finished": len(done), "shed": snap["shed_requests"],
        "lost": snap["submitted"] - len(done) - snap["shed_requests"],
        "ttft_p50_ms": _pct(ttft, 50), "ttft_p95_ms": _pct(ttft, 95),
        "ttft_p99_ms": _pct(ttft, 99),
        "tpot_p50_ms": _pct(tpot, 50), "tpot_p95_ms": _pct(tpot, 95),
        "tpot_p99_ms": _pct(tpot, 99),
        "faults_injected": snap["faults_injected"],
        "redispatched_requests": snap["redispatched_requests"],
        "redispatched_tokens": snap["redispatched_tokens"],
        "retries": snap["retries"],
        "wall_s": round(wall, 2),
    }


def run_ab(n: int, rate: float, seed: int) -> list[dict]:
    """Pool-vs-single A/B per workload class (BENCH_8 artifact rows)."""
    rows = []
    for kind in ("poisson", "bursty"):
        for replicas in (1, 2):
            rows.append(run_class(kind, replicas, rate, n, seed))
    return rows


def run_chaos_smoke(n: int, rate: float, seed: int) -> dict:
    """Seeded kill of replica 1-of-2 mid-stream: the pool must account for
    every submitted request (zero lost responses)."""
    row = run_class("poisson", 2, rate, n, seed, fault_plan="kill@25:r1")
    row["bench"] = "online_latency_chaos"
    assert row["faults_injected"] >= 1, "fault plan never fired"
    assert row["lost"] == 0, f"lost {row['lost']} responses after kill"
    return row


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--rate", type=float, default=8.0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--json", default=None, help="artifact path")
    ap.add_argument("--chaos-smoke", action="store_true")
    args = ap.parse_args()

    if args.chaos_smoke:
        rows = [run_chaos_smoke(args.requests, args.rate, args.seed)]
    else:
        rows = run_ab(args.requests, args.rate, args.seed)
    for r in rows:
        print(f"{r['bench']}/{r.get('class', '')}/r{r['replicas']},"
              f"{r['ttft_p50_ms']},"
              f"ttft p50={r['ttft_p50_ms']}ms p99={r['ttft_p99_ms']}ms "
              f"tpot p99={r['tpot_p99_ms']}ms/tok "
              f"finished={r['finished']}/{r['submitted']} "
              f"shed={r['shed']} lost={r['lost']}")
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=2)


if __name__ == "__main__":
    main()
