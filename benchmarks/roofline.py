"""Roofline analysis (deliverable g): three terms per (arch × shape × mesh)
from the compiled dry-run artifacts in results/dryrun/.

  compute term    = HLO_FLOPs_per_dev / peak_FLOP/s          (197 TF bf16)
  memory term     = HLO_bytes_per_dev / HBM_bw               (819 GB/s)
  collective term = collective_bytes_per_dev / link_bw       (50 GB/s)

(cost_analysis reports per-device quantities of the SPMD-partitioned module,
so dividing by per-chip peaks == the global formula divided by chips.)

Plus: MODEL_FLOPS = 6·N_active·D (train) or 2·N_active·D (inference) per
token over the cell's tokens, and the usefulness ratio
MODEL_FLOPS / (HLO_FLOPs·n_dev) — catches remat/dispatch/padding waste.

Caveat (documented): the CPU backend upcasts bf16 GEMM/scan operands to f32
(wrapped converts in the HLO), so the raw memory term is an *upper bound*;
native-bf16 TPU execution reads ≈half for those streams.  We report both the
raw term and a corrected term (raw − 2·upcast_bytes, floored at the analytic
parameter+cache traffic).
"""
from __future__ import annotations

import glob
import json

from repro.configs import SHAPES, get_config
from repro.core import costmodel as cm
from repro.models.model import active_params

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9


def model_flops(arch: str, shape_name: str) -> float:
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n_act = active_params(cfg)
    if shape.step == "train":
        per_token = 6 * n_act
        tokens = shape.global_batch * shape.seq_len
    elif shape.step == "prefill":
        per_token = 2 * n_act
        tokens = shape.global_batch * shape.seq_len
    else:  # decode: one token per sequence
        per_token = 2 * n_act
        tokens = shape.global_batch
    return per_token * tokens


def analytic_memory_floor(arch: str, shape_name: str, n_dev: int) -> float:
    """Minimum per-device HBM traffic: weights once + KV/state + activations
    in/out (bf16)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    ms = cm.model_stats(cfg)
    w_bytes = ms.p_model * 2 / n_dev                     # weights, fully sharded
    if shape.step == "train":
        w_bytes *= 3                                     # fwd + bwd(dW) + opt
        act = shape.global_batch * shape.seq_len * cfg.d_model * 2 \
            * cfg.n_layers / n_dev * 2
        kv = 0.0
    elif shape.step == "prefill":
        act = shape.global_batch * shape.seq_len * cfg.d_model * 2 \
            * cfg.n_layers / n_dev * 2
        kv = shape.global_batch * shape.seq_len * ms.kv_per_token * 2 / n_dev
    else:
        act = shape.global_batch * cfg.d_model * 2 * cfg.n_layers / n_dev * 2
        kv = shape.global_batch * shape.seq_len * ms.kv_per_token * 2 / n_dev
    return w_bytes + act + kv


def analyze(path: str) -> dict:
    d = json.load(open(path))
    if not d.get("ok"):
        return {"arch": d["arch"], "shape": d["shape"],
                "mesh": d.get("mesh"), "ok": False, "error": d.get("error")}
    n_dev = d["n_devices"]
    flops = d["flops_per_device"]
    raw_bytes = d["bytes_per_device"]
    upcast = d["collectives"].get("upcast_bytes", 0)
    floor = analytic_memory_floor(d["arch"], d["shape"], n_dev)
    corr_bytes = max(raw_bytes - 2 * upcast, floor)
    coll = d["collectives"]["total_bytes"]

    t_c = flops / PEAK_FLOPS
    t_m_raw = raw_bytes / HBM_BW
    t_m = corr_bytes / HBM_BW
    t_n = coll / LINK_BW
    terms = {"compute": t_c, "memory": t_m, "network": t_n}
    dom = max(terms, key=terms.get)
    mf = model_flops(d["arch"], d["shape"])
    useful = mf / max(flops * n_dev, 1.0)
    bound_time = max(terms.values())
    return {
        "arch": d["arch"], "shape": d["shape"], "mesh": d["mesh"],
        "variant": d.get("variant", "baseline"), "remat": d.get("remat"),
        "ok": True, "n_devices": n_dev,
        "t_compute_s": t_c, "t_memory_s": t_m, "t_memory_raw_s": t_m_raw,
        "t_network_s": t_n,
        "dominant": dom,
        "model_flops": mf, "hlo_flops_global": flops * n_dev,
        "useful_ratio": useful,
        "roofline_fraction": t_c / bound_time if bound_time else 0.0,
        "temp_gb": d["memory"]["temp_gb"] if d.get("memory") else None,
        "arg_gb": d["memory"]["argument_gb"] if d.get("memory") else None,
    }


ADVICE = {
    ("memory",): "dominant=memory: cut HBM traffic (kernel fusion — Pallas "
                 "flash/scan keep working set in VMEM; drop f32 upcasts).",
    ("network",): "dominant=network: reshard to cut collective bytes "
                  "(dispatch layout, collective-matmul overlap, DP over TP).",
    ("compute",): "dominant=compute: at roofline when useful_ratio→1; else "
                  "remove wasted FLOPs (remat policy, dispatch einsums, "
                  "head padding).",
}


def advice(row: dict) -> str:
    base = ADVICE[(row["dominant"],)]
    if row["useful_ratio"] < 0.5 and row["dominant"] == "compute":
        base += f" (useful_ratio={row['useful_ratio']:.2f} — mostly waste)"
    return base


def run(pattern: str = "results/dryrun/*__baseline*.json") -> list[dict]:
    rows = [analyze(p) for p in sorted(glob.glob(pattern))]
    return [r for r in rows if r.get("ok")]


def markdown_table(rows: list[dict]) -> str:
    hdr = ("| arch | shape | mesh | Tc (s) | Tm (s) | Tm-raw | Tn (s) | "
           "dominant | useful | frac |")
    sep = "|" + "---|" * 10
    out = [hdr, sep]
    for r in rows:
        out.append(
            f"| {r['arch']} | {r['shape']} | {r['mesh']} "
            f"| {r['t_compute_s']:.3g} | {r['t_memory_s']:.3g} "
            f"| {r['t_memory_raw_s']:.3g} | {r['t_network_s']:.3g} "
            f"| **{r['dominant']}** | {r['useful_ratio']:.2f} "
            f"| {r['roofline_fraction']:.2f} |")
    return "\n".join(out)


def main() -> None:
    rows = run()
    for r in rows:
        print(f"roofline/{r['arch']}/{r['shape']}/{r['mesh']},"
              f"{max(r['t_compute_s'], r['t_memory_s'], r['t_network_s'])*1e6:.0f},"
              f"Tc={r['t_compute_s']:.3g}s Tm={r['t_memory_s']:.3g}s "
              f"Tn={r['t_network_s']:.3g}s dom={r['dominant']} "
              f"useful={r['useful_ratio']:.2f} frac={r['roofline_fraction']:.2f}")


if __name__ == "__main__":
    main()
