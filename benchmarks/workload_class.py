"""Paper Fig. 2: workload classification via T_R (Eq. 8)."""
from __future__ import annotations

from repro.configs import get_config
from repro.core import costmodel as cm

CASES = [
    ("llama2-70b", cm.A100_80G, 8),
    ("qwen3-8b", cm.A100_80G, 1),
    ("qwen3-8b", cm.TPU_V5E, 16),
    ("jamba-1.5-large-398b", cm.TPU_V5E, 256),
    ("arctic-480b", cm.TPU_V5E, 256),
    ("deepseek-v2-236b", cm.TPU_V5E, 256),
]


def run() -> list[dict]:
    rows = []
    for arch, hw, n in CASES:
        ms = cm.model_stats(get_config(arch))
        for wname in ("splitwise", "lmsys", "sharegpt"):
            w = cm.WORKLOADS[wname]
            rows.append({
                "bench": "workload_class",
                "case": f"{arch}@{n}x{hw.name}/{wname}",
                "t_r": round(cm.t_r(hw, ms, w, n), 4),
                "class": cm.classify(hw, ms, w, n),
            })
    return rows


def main() -> None:
    for r in run():
        print(f"workload_class/{r['case']},0.0,T_R={r['t_r']} {r['class']}")


if __name__ == "__main__":
    main()
