"""Paper Table 2: per-operation cost model vs measurement.

Two parts:
  (a) the paper's own setting (LLaMA-2-70B, 8×A100, B_dense=2048) —
      analytic rows must reproduce the published GFLOP/GB/ms numbers;
  (b) CPU micro-measurement of a scaled-down op set — wall-times must
      *rank* the ops the same way the model's dominant-resource times do
      (the validation the paper does with real GPU profiles).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import time_fn
from repro.configs import get_config
from repro.core import costmodel as cm


def paper_table() -> list[dict]:
    cfg = get_config("llama2-70b")
    rows = cm.table2(cfg, cm.Workload(512, 1024), cm.A100_80G, 8, bdense=2048)
    out = []
    for r in rows:
        out.append({"bench": "table2", "op": r["op"],
                    "gflops": round(r["gflops"], 1),
                    "mem_gb": round(r["mem_gb"], 1),
                    "net_gb": round(r["net_gb"], 1),
                    "t_max_ms": round(max(r["t_compute_ms"], r["t_mem_ms"],
                                          r["t_net_ms"]), 2),
                    "bound": r["bound"]})
    return out


def cpu_proxy() -> list[dict]:
    """Tiny GEMM vs decode-GEMV on CPU: the measured time ratio must agree
    in *direction* with the model (GEMM compute-bound, GEMV memory-bound)."""
    d, ff, b, s, kv, hd = 512, 1408, 8, 2048, 4, 64
    key = jax.random.PRNGKey(0)
    x = jax.random.normal(key, (256, d), jnp.float32)
    w = jax.random.normal(key, (d, ff), jnp.float32)
    gemm = jax.jit(lambda a, b_: a @ b_)
    t_gemm = time_fn(gemm, x, w)

    q = jax.random.normal(key, (b, 8, hd), jnp.float32)
    kc = jax.random.normal(key, (b, s, kv, hd), jnp.float32)
    vc = jax.random.normal(key, (b, s, kv, hd), jnp.float32)
    clen = jnp.full((b,), s, jnp.int32)
    from repro.kernels.ref import decode_attention_ref
    dec = jax.jit(lambda *a: decode_attention_ref(*a))
    t_dec = time_fn(dec, q, kc, vc, clen)

    gemm_flops = 2 * 256 * d * ff
    dec_bytes = 2 * b * s * kv * hd * 4
    return [{
        "bench": "table2_cpu_proxy",
        "gemm_us": round(t_gemm * 1e6, 1),
        "decode_us": round(t_dec * 1e6, 1),
        "gemm_gflops_per_s": round(gemm_flops / t_gemm / 1e9, 2),
        "decode_gb_per_s": round(dec_bytes / t_dec / 1e9, 2),
    }]


def run() -> list[dict]:
    return paper_table() + cpu_proxy()


def main() -> None:
    for r in paper_table():
        print(f"table2/{r['op']},{r['t_max_ms']*1e3:.1f},"
              f"{r['gflops']}GF {r['mem_gb']}GB {r['net_gb']}GBnet {r['bound']}")
    for r in cpu_proxy():
        print(f"table2/cpu_gemm,{r['gemm_us']},{r['gemm_gflops_per_s']} GF/s")
        print(f"table2/cpu_decode,{r['decode_us']},{r['decode_gb_per_s']} GB/s")


if __name__ == "__main__":
    main()
