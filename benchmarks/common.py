"""Shared benchmark helpers: CSV emission + tiny-model fixtures."""
from __future__ import annotations

import time

import jax
import numpy as np


def emit(name: str, us_per_call: float, derived: str = "") -> None:
    print(f"{name},{us_per_call:.3f},{derived}")


def time_fn(fn, *args, iters: int = 3, warmup: int = 1) -> float:
    """Median wall-time (seconds) of a jitted callable."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return float(np.median(times))
