"""Paper Fig. 15: porting NanoFlow across models — % of optimal throughput
(Eq. 9) achieved by the autosearch schedule for every assigned architecture
on the production mesh, input 1024 / output 512 (the paper's setting)."""
from __future__ import annotations

from repro.configs import get_config
from repro.core import costmodel as cm
from repro.core.autosearch import (autosearch, sequential_schedule,
                                   throughput_estimate)

ARCHS = [
    "llama2-70b",                 # the paper's model (A100 + v5e)
    "jamba-1.5-large-398b", "xlstm-1.3b", "qwen3-4b", "minitron-4b",
    "qwen3-8b", "starcoder2-7b", "llava-next-34b", "musicgen-medium",
    "arctic-480b", "deepseek-v2-236b",
]


def serving_slice(cfg, hw: cm.Hardware) -> int:
    """Right-size the replica: smallest power-of-two chip count where the
    weights use <=40% of HBM (KV gets the rest) — the paper's own setup
    serves the 8B on one GPU and the 70B on eight."""
    from repro.models.model import num_params
    need = num_params(cfg) * 2 / (0.4 * hw.mem_size)
    n = 1
    while n < need:
        n *= 2
    return n


def run(hw: cm.Hardware = cm.TPU_V5E) -> list[dict]:
    w = cm.Workload(1024, 512)
    rows = []
    for arch in ARCHS:
        cfg = get_config(arch)
        ms = cm.model_stats(cfg)
        n_dev = serving_slice(cfg, hw)
        opt = cm.optimal_throughput(hw, ms, n_dev) / n_dev
        nano = autosearch(cfg, w, hw, n_dev)
        seq = sequential_schedule(cfg, w, hw, n_dev)
        tp = throughput_estimate(cfg, nano, w, hw, n_dev)
        tp_seq = throughput_estimate(cfg, seq, w, hw, n_dev)
        rows.append({
            "bench": "ported_models", "arch": arch, "n_dev": n_dev,
            "tok_s_dev": round(tp, 1), "seq_tok_s_dev": round(tp_seq, 1),
            "optimal": round(opt, 1),
            "pct_optimal": round(100 * tp / opt, 1),
            "vs_seq": round(tp / tp_seq, 3),
            "nano_kqv": nano.nano_kqv,
        })
    return rows


def main() -> None:
    for r in run():
        print(f"fig15/{r['arch']}@{r['n_dev']}chips,0.0,{r['tok_s_dev']} "
              f"tok/s/chip = {r['pct_optimal']}% of optimal "
              f"({r['vs_seq']}x vs sequential, nano_kqv={r['nano_kqv']})")


if __name__ == "__main__":
    main()
