"""Paper Fig. 13: ablation — non-overlap vs nano-batch-only vs NanoFlow,
plus the offload overhead.

Model-level ablation uses the same schedule machinery the paper's numbers
come from; the offload overhead is measured on the real engine."""
from __future__ import annotations

import time

import jax
import numpy as np

from repro.configs import get_config
from repro.core import costmodel as cm
from repro.core.autosearch import autosearch, sequential_schedule
from repro.models import model
from repro.serving.engine import ServeEngine
from repro.serving.request import Request


def modeled() -> list[dict]:
    cfg = get_config("llama2-70b")
    rows = []
    for name, p, d in [("prefill_only_512_0", 512, 1), ("decode_heavy_512_1024", 512, 1024)]:
        w = cm.Workload(p, d)
        seq = sequential_schedule(cfg, w, cm.A100_80G, 8, bdense=2048)
        nano_only = sequential_schedule(cfg, w, cm.A100_80G, 8, bdense=2048,
                                        nano_split=4)
        nano = autosearch(cfg, w, cm.A100_80G, 8, bdense=2048)
        rows.append({
            "bench": "ablation", "case": name,
            "non_overlap_ms": round(seq.iter_time * 1e3, 4),
            "nano_batch_only_ms": round(nano_only.iter_time * 1e3, 4),
            "nanoflow_ms": round(nano.iter_time * 1e3, 4),
            "nano_only_overhead": round(nano_only.iter_time / seq.iter_time - 1, 3),
            "overlap_speedup": round(seq.iter_time / nano.iter_time, 3),
        })
    return rows


def offload_overhead() -> list[dict]:
    cfg = get_config("tiny-toy")
    params = model.init(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)

    def run_engine(do_offload: bool) -> float:
        eng = ServeEngine(cfg, params, max_slots=4, max_len=64,
                          discrete_sizes=(32, 16, 8), avg_decode_len=5)
        if not do_offload:
            eng.kv.offload = lambda rid, data: eng.kv.free(rid)  # type: ignore
        for i in range(10):
            eng.submit(Request(rid=i,
                               prompt=list(rng.integers(0, 64, size=10)),
                               max_new_tokens=5))
        t0 = time.perf_counter()
        eng.run()
        return time.perf_counter() - t0

    t_off = run_engine(True)
    t_no = run_engine(False)
    return [{"bench": "ablation_offload",
             "with_offload_s": round(t_off, 3),
             "without_offload_s": round(t_no, 3),
             "overhead": round(t_off / t_no - 1, 4)}]


def run() -> list[dict]:
    return modeled() + offload_overhead()


def main() -> None:
    for r in modeled():
        print(f"fig13/{r['case']},{r['nanoflow_ms']*1e3:.1f},"
              f"seq={r['non_overlap_ms']}ms nano-only={r['nano_batch_only_ms']}ms "
              f"nanoflow={r['nanoflow_ms']}ms speedup={r['overlap_speedup']}x "
              f"(paper: 1.07-1.17x; nano-only overhead {r['nano_only_overhead']}, paper 0.132)")
    for r in offload_overhead():
        print(f"fig13/offload,{r['with_offload_s']*1e6:.0f},"
              f"overhead={r['overhead']*100:.1f}% (paper: 3.0%)")


if __name__ == "__main__":
    main()
