"""Paper Fig. 14: per-resource occupancy over one layer iteration,
NanoFlow schedule vs non-overlap baseline (text timeline from the op
schedule that autosearch produced)."""
from __future__ import annotations

from repro.configs import get_config
from repro.core import costmodel as cm
from repro.core.autosearch import (Schedule, autosearch, efficiency,
                                   sequential_schedule)

BUCKETS = 40


def occupancy(sched: Schedule) -> dict[str, list[float]]:
    t_total = sched.iter_time
    out = {k: [0.0] * BUCKETS for k in ("compute", "memory", "network")}
    for n in sched.pipeline.nodes.values():
        rate = efficiency(n.kind, n.units)
        for b in range(BUCKETS):
            t0, t1 = b * t_total / BUCKETS, (b + 1) * t_total / BUCKETS
            ov = max(0.0, min(n.end, t1) - max(n.start, t0))
            out[n.kind][b] += rate * ov / (t1 - t0)
    return {k: [min(v, 1.0) for v in vs] for k, vs in out.items()}


def render(occ: dict[str, list[float]]) -> str:
    sym = " .:-=+*#%@"
    lines = []
    for k in ("compute", "memory", "network"):
        cells = "".join(sym[min(int(v * (len(sym) - 1) + 0.5), len(sym) - 1)]
                        for v in occ[k])
        lines.append(f"  {k:8s}|{cells}|")
    return "\n".join(lines)


def run() -> list[dict]:
    cfg = get_config("llama2-70b")
    w = cm.Workload(512, 1024)
    nano = autosearch(cfg, w, cm.A100_80G, 8, bdense=2048)
    seq = sequential_schedule(cfg, w, cm.A100_80G, 8, bdense=2048)
    rows = []
    for name, sched in (("nanoflow", nano), ("non_overlap", seq)):
        occ = occupancy(sched)
        avg_c = sum(occ["compute"]) / BUCKETS
        rows.append({"bench": "resource_usage", "case": name,
                     "compute_busy": round(avg_c, 3),
                     "timeline": render(occ)})
    return rows


def main() -> None:
    for r in run():
        print(f"fig14/{r['case']},0.0,compute_busy={r['compute_busy']}")
        print(r["timeline"])


if __name__ == "__main__":
    main()
